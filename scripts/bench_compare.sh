#!/usr/bin/env bash
# Diff two BENCH_*.json files (flat {"name": value} objects as written by
# benchsuite::BenchJson) and print per-row speedup, old/new:
#
#   scripts/bench_compare.sh BENCH_offline.before.json BENCH_offline.json
#   scripts/bench_compare.sh BENCH_scheduler.before.json BENCH_scheduler.json
#   scripts/bench_compare.sh BENCH_router.before.json BENCH_router.json
#   scripts/bench_compare.sh BENCH_prefill.before.json BENCH_prefill.json
#   scripts/bench_compare.sh BENCH_faults.before.json BENCH_faults.json
#   scripts/bench_compare.sh BENCH_tiers.before.json BENCH_tiers.json
#
# Values are ns/op for the perf_* benches and seconds / tokens-per-second
# for BENCH_scheduler.json and BENCH_router.json (`*_p50_s`/`*_p99_s`/
# `*_ttft_p99_s`/`*_tpot_p50_s`/`*_tput` rows — for latency rows
# speedup > 1 still means the new run is faster; for `_tput` rows the
# ratio is old/new throughput, so < 1 means the new run moves MORE
# tokens). BENCH_router.json additionally carries `*_hit_*` GPU-hit
# ratios in [0,1] (higher is better: ratio < 1 means the new run hits
# more) and BENCH_scheduler.json carries `cancel_{off,on}_prefetch_mb`
# prefetch-traffic totals (lower is less dead PCIe traffic).
# BENCH_prefill.json rows are per chunk-size point (`chunk16_*`,
# `chunk_inf_*`, `continuous_*`): `*_decode_p99_s` is the pure-decode
# iteration-latency tail chunking exists to cap. Rows present
# BENCH_faults.json rows are per failure-probability point (`f00_*`,
# `f15_*`, ...): `*_goodput_tps` is within-SLO tokens/s and behaves like
# `_tput` (ratio < 1 means the new run is better); `*_shed`/`*_timeout`/
# `*_retries`/`*_demand_failures` are counts (lower is better, so
# speedup > 1 means fewer); `failover_*_requests` must stay equal
# between the clean and crashed runs. BENCH_tiers.json rows are per
# (tier shape, GPU-tier policy) point: `<shape>_<policy>` is a GPU hit
# ratio in [0,1] and behaves like `*_hit_*` (higher is better, so
# ratio < 1 means the new run hits more); `<shape>_<policy>_stall_s` is
# total demand-stall seconds (lower is better). Rows present
# in only one file print with a '-' placeholder. `*_speedup_*` rows are
# already ratios; the old/new columns still show them, the speedup column
# then compares the ratios themselves.
set -euo pipefail
if [ $# -ne 2 ]; then
    echo "usage: $0 OLD.json NEW.json" >&2
    exit 2
fi
python3 - "$1" "$2" <<'EOF'
import json, sys

with open(sys.argv[1]) as f:
    old = json.load(f)
with open(sys.argv[2]) as f:
    new = json.load(f)

names = sorted(set(old) | set(new))
w = max(len(n) for n in names) if names else 3
print(f"{'row'.ljust(w)}  {'old':>14}  {'new':>14}  {'speedup':>8}")
print(f"{'-' * w}  {'-' * 14}  {'-' * 14}  {'-' * 8}")
for n in names:
    o, v = old.get(n), new.get(n)
    so = f"{o:14.1f}" if o is not None else f"{'-':>14}"
    sv = f"{v:14.1f}" if v is not None else f"{'-':>14}"
    if o is None or v is None or v == 0:
        sp = f"{'-':>8}"
    else:
        sp = f"{o / v:7.2f}x"
    print(f"{n.ljust(w)}  {so}  {sv}  {sp}")
EOF
