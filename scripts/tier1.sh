#!/usr/bin/env bash
# Tier-1 verification + hot-path smoke bench.
#
#   scripts/tier1.sh
#
# Runs the repo's tier-1 gate (release build + full test suite) and then the
# §Perf hot-path micro-benchmarks in smoke mode, which also emits the
# machine-readable BENCH_hotpath.json (name → ns/op) used by
# EXPERIMENTS.md §Perf. Drop MOE_BENCH_SMOKE for full-length measurements.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release"
cargo build --release

echo "== tier-1: cargo test -q"
cargo test -q

echo "== perf_hotpath (smoke mode -> BENCH_hotpath.json)"
MOE_BENCH_SMOKE=1 cargo bench --bench perf_hotpath

echo "== done; hot-path numbers:"
cat BENCH_hotpath.json
