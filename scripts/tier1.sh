#!/usr/bin/env bash
# Tier-1 verification + smoke benches.
#
#   scripts/tier1.sh
#
# Runs the repo's static gate first — `moelint`, the determinism & hot-path
# source lint (exit 0 clean, 1 findings, 2 usage/IO error; any nonzero
# aborts the gate — see rust/src/lint/ and EXPERIMENTS.md §Lint) — then the
# tier-1 gate (release build + full test suite), the §Perf hot-path
# micro-benchmarks, the offline-path benchmarks and the
# scheduler comparison in smoke mode (emitting BENCH_hotpath.json,
# BENCH_offline.json and BENCH_scheduler.json — diff runs with
# scripts/bench_compare.sh), and a determinism re-check that pins the
# parallel offline layer to its serial results with MOE_POOL_THREADS=1.
# Drop MOE_BENCH_SMOKE for full-length measurements.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: moelint (determinism & hot-path lint)"
cargo run --release --bin moelint

echo "== tier-1: cargo build --release"
cargo build --release

echo "== tier-1: cargo test -q"
cargo test -q

echo "== perf_hotpath (smoke mode -> BENCH_hotpath.json)"
MOE_BENCH_SMOKE=1 cargo bench --bench perf_hotpath

echo "== perf_offline (smoke mode -> BENCH_offline.json)"
MOE_BENCH_SMOKE=1 cargo bench --bench perf_offline

echo "== perf_scheduler (smoke mode -> BENCH_scheduler.json)"
# static vs continuous batching on the same Poisson trace; asserts the
# overload-point p99 improvement before writing the JSON; also records
# the retired-prefetch cancellation traffic delta (cancel_* rows)
MOE_BENCH_SMOKE=1 cargo bench --bench perf_scheduler

echo "== perf_router (smoke mode -> BENCH_router.json)"
# routing policies over the same mixed-task overload trace; asserts
# task-affinity beats round-robin on GPU hit ratio AND p99 at N=2
MOE_BENCH_SMOKE=1 cargo bench --bench perf_router

echo "== perf_prefill (smoke mode -> BENCH_prefill.json)"
# chunked prefill vs continuous on the same mixed-length overload trace;
# asserts the ∞-chunk point replays continuous bitwise, that the best
# finite chunk caps decode p99, and that it stays within the tokens/s band
MOE_BENCH_SMOKE=1 cargo bench --bench perf_prefill

echo "== perf_faults (smoke mode -> BENCH_faults.json)"
# goodput under a transfer-failure-probability sweep on the same overload
# trace; asserts an empty fault plan replays the fault-free stack bitwise,
# that goodput holds the no-cliff band at the mid fault point, and that a
# replica crash loses zero requests via warm failover
MOE_BENCH_SMOKE=1 cargo bench --bench perf_faults

echo "== perf_events (smoke mode -> BENCH_events.json)"
# discrete-event router calendar vs the retired lockstep polling loop on a
# flash-crowd trace; asserts the calendar replays lockstep bitwise at every
# swept replica count (incl. under link faults + a replica crash) and that
# the N=16 point beats lockstep on host wall-clock by >= 2x — the repo's
# first host-time regression surface
MOE_BENCH_SMOKE=1 cargo bench --bench perf_events

echo "== perf_tiers (smoke mode -> BENCH_tiers.json)"
# per-tier eviction policy zoo across memory-hierarchy shapes (incl. the
# SSD IOPS point); asserts the activation-aware policy matches or beats
# every non-oracle baseline on GPU hit ratio at the paper-default shape
MOE_BENCH_SMOKE=1 cargo bench --bench perf_tiers

echo "== determinism re-check: parallel differential suite at MOE_POOL_THREADS=1"
# the suite pins explicit pool sizes internally (and now also the
# scheduler differential: continuous at max_batch=1 == static, bitwise);
# forcing the env-derived default pool serial covers the remaining
# (from_env) code path
MOE_POOL_THREADS=1 cargo test -q --test parallel

echo "== serving-API differential suite (Scheduler trait / Router redesign)"
# 1-replica round-robin router == bare continuous (bitwise), router
# replays deterministic across pools, preempt/resume demand equality
cargo test -q --test scheduler

echo "== done; bench numbers:"
cat BENCH_hotpath.json
cat BENCH_offline.json
cat BENCH_scheduler.json
cat BENCH_router.json
cat BENCH_prefill.json
cat BENCH_faults.json
cat BENCH_events.json
cat BENCH_tiers.json
