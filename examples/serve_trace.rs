//! End-to-end serving driver (the DESIGN.md headline validation): load the
//! small **real** MoE through the PJRT runtime and serve batched requests
//! arriving on a Poisson process, reporting latency and throughput. All
//! three layers compose here: L1 Pallas kernels (router + expert FFN) inside
//! L2 HLO artifacts executed by the L3 rust coordinator with activation-aware
//! offloading.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_trace
//! ```

use moe_infinity::engine::{real::tiny_spec, RealMoeEngine};
use moe_infinity::memory::TierConfig;
use moe_infinity::metrics::LatencyRecorder;
use moe_infinity::model::weights::TinyConfig;
use moe_infinity::prefetch::PredictorKind;
use moe_infinity::util::{fmt_secs, Rng};
use moe_infinity::workload::ArrivalProcess;

const N_TASKS: usize = 4;
const PROMPT_LEN: usize = 8;
const GEN_TOKENS: usize = 12;
const RPS: f64 = 2.0;
const DURATION: f64 = 20.0;
const MAX_WAIT: f64 = 0.25;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let cfg = TinyConfig::from_manifest(&artifacts)?;
    let spec = tiny_spec(&cfg);
    let mut tier = TierConfig::default_for(&spec, spec.total_bytes() / 3, spec.total_bytes());
    tier.gpu_capacity = (spec.total_experts() / 3).max(2);

    let mut engine = RealMoeEngine::new(
        &artifacts,
        7,
        N_TASKS,
        tier,
        PredictorKind::ActivationAware { refine: true },
    )?;
    println!(
        "model: {} layers x {} experts (d_model {}), expert {}B",
        cfg.n_layers,
        cfg.n_experts,
        cfg.d_model,
        spec.expert_bytes()
    );

    let mut rng = Rng::new(123);
    let per = cfg.vocab / N_TASKS;
    let mut mk_prompt = |rng: &mut Rng| -> Vec<i32> {
        let task = rng.below(N_TASKS);
        (0..PROMPT_LEN)
            .map(|_| (task * per + rng.below(per)) as i32)
            .collect()
    };

    // offline tracing phase (paper §4.2)
    let trace_sets: Vec<Vec<Vec<i32>>> = (0..8)
        .map(|_| (0..cfg.batch).map(|_| mk_prompt(&mut rng)).collect())
        .collect();
    engine.build_eamc(&trace_sets, GEN_TOKENS, 16)?;
    println!(
        "EAMC: {} patterns from {} traced sequences",
        engine.eamc().len(),
        8 * cfg.batch
    );

    // request stream
    let arrivals = ArrivalProcess::Poisson { rps: RPS }.timestamps(DURATION, &mut rng);
    let prompts: Vec<Vec<i32>> = arrivals.iter().map(|_| mk_prompt(&mut rng)).collect();
    println!(
        "replaying {} requests over {DURATION}s at {RPS} rps ...",
        arrivals.len()
    );

    // serving loop: batch up to the compiled batch size or MAX_WAIT
    let mut token_lat = LatencyRecorder::new();
    let mut request_lat = LatencyRecorder::new();
    let mut served = 0usize;
    let mut engine_free = 0.0f64;
    let mut idx = 0usize;
    let mut total_tokens = 0u64;
    let mut recall_sum = 0.0;
    let mut batches = 0usize;
    while idx < arrivals.len() {
        let window_end = arrivals[idx] + MAX_WAIT;
        let fill = arrivals
            .get(idx + cfg.batch - 1)
            .copied()
            .unwrap_or(f64::INFINITY);
        let dispatch = fill.min(window_end).max(arrivals[idx]).max(engine_free);
        let mut end = idx;
        while end < arrivals.len() && end - idx < cfg.batch && arrivals[end] <= dispatch {
            end += 1;
        }
        let batch: Vec<Vec<i32>> = prompts[idx..end].to_vec();
        let out = engine.generate(&batch, GEN_TOKENS)?;
        let lats = out.token_latencies();
        let service: f64 = lats.iter().sum();
        for (bi, _) in batch.iter().enumerate() {
            let queue = dispatch - arrivals[idx + bi];
            let mut mean = 0.0;
            for (i, &l) in lats.iter().enumerate() {
                let tl = if i == 0 { l + queue } else { l };
                token_lat.record(tl);
                mean += tl;
            }
            request_lat.record(mean / lats.len() as f64);
            total_tokens += (PROMPT_LEN + GEN_TOKENS) as u64;
        }
        recall_sum += out.recall();
        batches += 1;
        served += batch.len();
        engine_free = dispatch + service;
        idx = end;
    }

    println!("\n== serve_trace report (real model, PJRT CPU) ==");
    println!("requests served  : {served} in {batches} batches");
    println!("tokens processed : {total_tokens}");
    println!("mean token lat   : {}", fmt_secs(token_lat.mean()));
    println!("p50 token lat    : {}", fmt_secs(token_lat.p50()));
    println!("p99 token lat    : {}", fmt_secs(token_lat.p99()));
    println!("mean request lat : {}", fmt_secs(request_lat.mean()));
    println!(
        "throughput       : {:.1} tokens/s (virtual makespan {})",
        total_tokens as f64 / engine_free,
        fmt_secs(engine_free)
    );
    println!(
        "prefetch recall  : {:.0}%",
        recall_sum / batches as f64 * 100.0
    );
    Ok(())
}
