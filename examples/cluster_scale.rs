//! Expert-parallel cluster scaling (paper §7, Fig. 13): latency scales down
//! and throughput scales up with node count.
//!
//! ```sh
//! cargo run --release --example cluster_scale
//! ```

use moe_infinity::benchsuite::{build_eamc, tier_with, Table};
use moe_infinity::cache::CacheKind;
use moe_infinity::cluster::{ClusterModel, Placement};
use moe_infinity::engine::{ComputeModel, EngineConfig, SimEngine};
use moe_infinity::model::ModelSpec;
use moe_infinity::util::fmt_secs;
use moe_infinity::workload::{DatasetPreset, Workload};

fn main() {
    let spec = ModelSpec::preset("switch-large-128").unwrap();
    let dataset = DatasetPreset::by_name("mixed").unwrap();

    // placement sanity: balanced across nodes
    for n in [1, 2, 4, 6] {
        let p = Placement::round_robin(&spec, n);
        let load = p.load(0);
        println!("{} node(s): experts/node in layer 0 = {:?}", n, &load[..load.len().min(6)]);
    }

    let mut table = Table::new(&["nodes", "mean token latency", "throughput (tokens/s)"]);
    for nodes in [1usize, 2, 3, 4, 6] {
        let eamc = build_eamc(&spec, &dataset, 240, 100, 5);
        // gpu_capacity is PER GPU; MemorySim scales by n_gpus. V100-16GB
        // minus dense/KV/runtime leaves ~40 switch-large experts per GPU.
        let mut tier = tier_with(
            &spec,
            40,
            spec.total_experts(),
            6.0,
            16.0,
            CacheKind::Activation,
        );
        tier.n_gpus = 4 * nodes;
        let mut engine = SimEngine::new(
            spec.clone(),
            tier,
            eamc,
            ComputeModel::v100(),
            EngineConfig::default(),
        )
        .with_cluster(ClusterModel::new(nodes));

        let mut w = Workload::new(&spec, dataset.clone(), 5);
        let mut lat_sum = 0.0;
        let mut lat_n = 0;
        let mut tokens = 0u64;
        let t0 = engine.now();
        for _ in 0..10 {
            let seqs: Vec<_> = (0..4).map(|_| w.gen_sequence()).collect();
            tokens += seqs.iter().map(|s| s.total_tokens() as u64).sum::<u64>();
            let r = engine.run_batch(&seqs, engine.now());
            lat_sum += r.token_latencies.iter().sum::<f64>();
            lat_n += r.token_latencies.len();
        }
        let makespan = engine.now() - t0;
        table.row(&[
            nodes.to_string(),
            fmt_secs(lat_sum / lat_n as f64),
            format!("{:.0}", tokens as f64 / makespan),
        ]);
    }
    table.print("Cluster scalability (switch-large-128, 4 V100/node)");
}
