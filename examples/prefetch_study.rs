//! Prefetch-strategy study: compare the paper's activation-aware predictor
//! against the ZeRO-Infinity (TopK-by-id) and BrainStorm (Traced-TopK)
//! baselines on prediction accuracy and end-to-end serving recall.
//!
//! ```sh
//! cargo run --release --example prefetch_study
//! ```

use moe_infinity::benchsuite::{build_eamc, prediction_accuracy, tier_with, Table};
use moe_infinity::cache::CacheKind;
use moe_infinity::engine::{ComputeModel, EngineConfig, SimEngine};
use moe_infinity::model::ModelSpec;
use moe_infinity::prefetch::PredictorKind;
use moe_infinity::trace::Eamc;
use moe_infinity::workload::{DatasetPreset, Workload};

fn main() {
    let spec = ModelSpec::preset("switch-base-64").unwrap();
    let dataset = DatasetPreset::by_name("mmlu").unwrap();
    let eamc = build_eamc(&spec, &dataset, 240, 60, 7);

    let strategies = [
        ("activation-aware", PredictorKind::ActivationAware { refine: true }),
        ("one-shot (no refine)", PredictorKind::ActivationAware { refine: false }),
        ("traced-topk (BrainStorm)", PredictorKind::TracedTopK { k: 8 }),
        ("topk-by-id (ZeRO)", PredictorKind::TopK { k: 8 }),
        ("none (on-demand)", PredictorKind::NoPrefetch),
    ];

    let mut table = Table::new(&["strategy", "pred. accuracy", "serving recall", "mean token lat"]);
    for (name, kind) in strategies {
        let mut w = Workload::new(&spec, dataset.clone(), 7);
        let acc = prediction_accuracy(&spec, kind, &eamc, &mut w, 12);

        // end-to-end recall under the memory simulator
        let mut w2 = Workload::new(&spec, dataset.clone(), 7);
        let eamc2 = build_eamc(&spec, &dataset, 240, 60, 7);
        let mut engine = SimEngine::new(
            spec.clone(),
            tier_with(&spec, spec.total_experts() / 2, spec.total_experts(), 6.0, 32.0, CacheKind::Activation),
            eamc2,
            ComputeModel::a5000(),
            EngineConfig {
                predictor: kind,
                ..Default::default()
            },
        );
        let mut hits = 0u64;
        let mut demands = 0u64;
        let mut lat_sum = 0.0;
        let mut lat_n = 0usize;
        for _ in 0..12 {
            let seq = w2.gen_sequence();
            let r = engine.run_batch(&[seq], engine.now());
            hits += r.gpu_hits;
            demands += r.demands;
            lat_sum += r.token_latencies.iter().sum::<f64>();
            lat_n += r.token_latencies.len();
        }
        table.row(&[
            name.to_string(),
            format!("{:.1}%", acc * 100.0),
            format!("{:.1}%", hits as f64 / demands as f64 * 100.0),
            format!("{:.2}ms", lat_sum / lat_n as f64 * 1e3),
        ]);
    }
    table.print("Prefetch strategies (switch-base-64, mmlu)");
}
