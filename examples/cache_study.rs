//! Cache-policy study: replay a serving access trace through every cache
//! policy (paper §8.4) and report hit ratios, including the Belady ORACLE
//! upper bound.
//!
//! ```sh
//! cargo run --release --example cache_study
//! ```

use moe_infinity::benchsuite::Table;
use moe_infinity::cache::{
    ActivationPolicy, CacheCtx, CacheKind, ExpertCache, GdsfPolicy, LfuDaPolicy, LfuPolicy,
    LruPolicy, NeighborPolicy, OraclePolicy, Policy, SlruPolicy,
};
use moe_infinity::engine::SimEngine;
use moe_infinity::model::{ExpertKey, ModelSpec};
use moe_infinity::trace::Eam;
use moe_infinity::workload::{DatasetPreset, Workload};

fn main() {
    let spec = ModelSpec::preset("switch-base-64").unwrap();
    let dataset = DatasetPreset::by_name("mixed").unwrap();
    let mut w = Workload::new(&spec, dataset, 11);

    // access trace: the exact demand order the engine would issue
    let batches: Vec<Vec<_>> = (0..30).map(|_| vec![w.gen_sequence()]).collect();
    let trace = SimEngine::demand_trace(&spec, &batches);
    println!("trace: {} expert demands over {} sequences", trace.len(), batches.len());

    // the current-EAM context evolves as the trace replays; rebuild it per
    // sequence like the engine does
    let seq_eams: Vec<Eam> = batches
        .iter()
        .map(|b| b[0].to_eam(spec.n_layers, spec.experts_per_layer))
        .collect();

    let capacities = [64usize, 128, 256, 384];
    let mut table = Table::new(&["policy", "cap=64", "cap=128", "cap=256", "cap=384"]);
    let kinds: Vec<(&str, CacheKind)> = vec![
        ("activation (Alg. 2)", CacheKind::Activation),
        ("lru", CacheKind::Lru),
        ("lfu", CacheKind::Lfu),
        ("lfuda", CacheKind::Lfuda),
        ("slru", CacheKind::Slru),
        ("gdsf", CacheKind::Gdsf),
        ("neighbor", CacheKind::Neighbor),
        ("oracle (Belady)", CacheKind::Oracle),
    ];

    for (name, kind) in kinds {
        let mut cells = vec![name.to_string()];
        for &cap in &capacities {
            let policy: Box<dyn Policy> = match kind {
                CacheKind::Activation => Box::new(ActivationPolicy::new()),
                CacheKind::Lru => Box::new(LruPolicy::new()),
                CacheKind::Lfu => Box::new(LfuPolicy::new()),
                CacheKind::Lfuda => Box::new(LfuDaPolicy::new()),
                CacheKind::Slru => Box::new(SlruPolicy::new(cap)),
                CacheKind::Gdsf => Box::new(GdsfPolicy::new()),
                CacheKind::Neighbor => Box::new(NeighborPolicy::new()),
                CacheKind::Oracle => Box::new(OraclePolicy::from_trace(&trace)),
            };
            let mut cache = ExpertCache::new(cap, policy);
            // replay per sequence so the activation policy sees the right EAM
            let mut i = 0;
            for (si, b) in batches.iter().enumerate() {
                let n: usize = demands_of(&spec, &b[0]);
                let ctx = CacheCtx::new(&seq_eams[si], spec.n_layers);
                for key in &trace[i..i + n] {
                    if !cache.access(*key) {
                        cache.insert(*key, &ctx);
                    }
                }
                i += n;
            }
            cells.push(format!("{:.1}%", cache.hit_ratio() * 100.0));
        }
        table.row(&cells);
    }
    table.print("Cache hit ratio by policy and capacity (switch-base-64, mixed)");
}

fn demands_of(spec: &ModelSpec, seq: &moe_infinity::workload::SequenceActivation) -> usize {
    let mut n = 0;
    for iter in &seq.routes {
        for l in 0..spec.n_layers {
            let mut distinct: std::collections::BTreeSet<u16> = Default::default();
            for &(e, _) in &iter[l] {
                distinct.insert(e);
            }
            n += distinct.len();
        }
    }
    n
}
