//! Quickstart: load the AOT artifacts, build a tiny MoE with activation-aware
//! offloading, and generate a few sequences end-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use moe_infinity::engine::{real::tiny_spec, RealMoeEngine};
use moe_infinity::memory::TierConfig;
use moe_infinity::model::weights::TinyConfig;
use moe_infinity::prefetch::PredictorKind;
use moe_infinity::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );

    // 1. Model geometry comes from the AOT manifest — rust cannot drift
    //    from what python compiled.
    let cfg = TinyConfig::from_manifest(&artifacts)?;
    let spec = tiny_spec(&cfg);

    // 2. Memory hierarchy: a third of the experts fit the "GPU".
    let mut tier = TierConfig::default_for(&spec, spec.total_bytes() / 3, spec.total_bytes());
    tier.gpu_capacity = (spec.total_experts() / 3).max(2);

    // 3. The engine: PJRT-compiled HLO + EAM tracing + prefetch + cache.
    let mut engine = RealMoeEngine::new(
        &artifacts,
        42,
        4,
        tier,
        PredictorKind::ActivationAware { refine: true },
    )?;

    // 4. Offline tracing phase: build the EAMC from a handful of prompts.
    let prompts_of = |task: usize| -> Vec<Vec<i32>> {
        let per = cfg.vocab / 4;
        (0..cfg.batch)
            .map(|i| (0..8).map(|j| (task * per + (7 * i + 13 * j) % per) as i32).collect())
            .collect()
    };
    let trace: Vec<_> = (0..4).map(prompts_of).collect();
    engine.build_eamc(&trace, 8, 12)?;
    println!("EAMC ready: {} representative activation patterns", engine.eamc().len());

    // 5. Serve a batch.
    let out = engine.generate(&prompts_of(1), 12)?;
    for (i, toks) in out.tokens.iter().enumerate() {
        println!("sequence {i}: {toks:?}");
    }
    let lats = out.token_latencies();
    println!(
        "per-token latency: mean {} | prefetch recall {:.0}%",
        fmt_secs(lats.iter().sum::<f64>() / lats.len() as f64),
        out.recall() * 100.0
    );
    Ok(())
}
