"""AOT compile path: lower each model piece to an HLO-text artifact.

Interchange is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 rust crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (all under ``artifacts/``):

  embed.hlo.txt      attn_step.hlo.txt   router.hlo.txt
  expert.hlo.txt     combine.hlo.txt     lm_head.hlo.txt
  manifest.json      — model geometry + per-artifact arg shapes, so the rust
                       runtime (rust/src/runtime/artifacts.rs) cannot drift
                       from what was compiled.

Run once via ``make artifacts``; a content hash in the manifest makes the
target a no-op when inputs are unchanged.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import ModelConfig


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True: the rust
    side unwraps with to_tuple1/to_tuple_len)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(cfg: ModelConfig):
    """Lower every decode-step piece at the fixed geometry in ``cfg``.

    Returns {name: (hlo_text, arg_shapes, out_arity)}.
    """
    B, D, F, V, S, E = cfg.batch, cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq, cfg.n_experts

    pieces = {}

    def add(name, fn, specs, out_arity):
        lowered = jax.jit(fn).lower(*specs)
        pieces[name] = (
            to_hlo_text(lowered),
            [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs],
            out_arity,
        )

    add(
        "embed",
        lambda ids, emb: (model.embed(ids, emb),),
        [_spec((B,), jnp.int32), _spec((V, D))],
        1,
    )
    add(
        "attn_step",
        lambda x, k, v, pos, wq, wk, wv, wo: model.attn_step(
            x, k, v, pos, wq, wk, wv, wo, n_heads=cfg.n_heads
        ),
        [
            _spec((B, D)),
            _spec((B, S, D)),
            _spec((B, S, D)),
            _spec((), jnp.int32),
            _spec((D, D)),
            _spec((D, D)),
            _spec((D, D)),
            _spec((D, D)),
        ],
        3,
    )
    add("router", model.router, [_spec((B, D)), _spec((D, E))], 2)
    add(
        "expert",
        lambda x, w1, b1, w2, b2: (model.expert(x, w1, b1, w2, b2),),
        [_spec((B, D)), _spec((D, F)), _spec((F,)), _spec((F, D)), _spec((D,))],
        1,
    )
    add(
        "combine",
        lambda x, eo, g, sel: (model.combine(x, eo, g, sel),),
        [_spec((B, D)), _spec((B, D)), _spec((B,)), _spec((B,))],
        1,
    )
    add(
        "lm_head",
        lambda x, w: (model.lm_head(x, w),),
        [_spec((B, D)), _spec((D, V))],
        1,
    )
    return pieces


def _input_hash() -> str:
    """Hash of the compile-path sources, for no-op rebuild detection."""
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(base)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = ModelConfig()
    src_hash = _input_hash()
    manifest_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("src_hash") == src_hash:
            print(f"artifacts up to date (hash {src_hash[:12]}), skipping")
            return

    pieces = build_artifacts(cfg)
    manifest = {
        "src_hash": src_hash,
        "config": cfg.__dict__,
        "artifacts": {},
    }
    for name, (text, arg_shapes, out_arity) in pieces.items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": arg_shapes,
            "outputs": out_arity,
        }
        print(f"wrote {path} ({len(text)} chars, {len(arg_shapes)} args)")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
