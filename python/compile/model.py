"""L2 JAX model: decoder-only Switch-style MoE transformer, decode-step form.

The model is *deconstructed* into the per-piece functions the rust engine
needs for expert offloading: because experts migrate between memory tiers at
runtime, expert weights must be **runtime arguments** to a small per-expert
executable — a monolithic forward pass would bake a placement in. Each
function here is lowered to its own HLO-text artifact by ``aot.py`` and
executed by ``rust/src/runtime``:

  embed      : token ids -> hidden states
  attn_step  : one causal self-attention step against the rust-owned KV cache
  router     : Pallas top-1 router (kernels/router.py)
  expert_ffn : Pallas expert FFN     (kernels/expert_ffn.py)
  combine    : residual + gate * expert output scatter-combine
  lm_head    : hidden -> argmax next token (greedy decode)

All pieces are pure functions of their inputs; rust owns every buffer
(weights, KV cache, hidden states) between calls. Python never runs on the
request path.
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels.expert_ffn import expert_ffn
from .kernels.router import router as pallas_router


@dataclass(frozen=True)
class ModelConfig:
    """Geometry of the small real-compute MoE used end-to-end.

    Mirrors rust/src/model/spec.rs presets; the AOT manifest carries these so
    the two sides cannot drift.
    """

    vocab: int = 512
    d_model: int = 64
    d_ff: int = 128
    n_heads: int = 4
    n_layers: int = 4
    n_experts: int = 8
    max_seq: int = 64
    batch: int = 4

    @property
    def expert_param_count(self) -> int:
        # w1 [D,F] + b1 [F] + w2 [F,D] + b2 [D]
        return 2 * self.d_model * self.d_ff + self.d_ff + self.d_model


def embed(ids, emb):
    """ids [B] i32, emb [V, D] -> [B, D]."""
    return jnp.take(emb, ids, axis=0)


def attn_step(x, k_cache, v_cache, pos, wq, wk, wv, wo, *, n_heads):
    """One decode attention step; see kernels/ref.attention_ref for shapes.

    Returns (out_with_residual [B, D], new_k, new_v).
    """
    B, S, D = k_cache.shape
    H = n_heads
    hd = D // H
    q = (x @ wq).reshape(B, H, hd)
    k = (x @ wk).reshape(B, H, hd)
    v = (x @ wv).reshape(B, H, hd)
    onehot = (jnp.arange(S) == pos).astype(k_cache.dtype)
    new_k = k_cache * (1.0 - onehot)[None, :, None] + onehot[None, :, None] * k.reshape(B, 1, D)
    new_v = v_cache * (1.0 - onehot)[None, :, None] + onehot[None, :, None] * v.reshape(B, 1, D)
    kk = new_k.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    vv = new_v.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhd,bhsd->bhs", q, kk) / jnp.sqrt(float(hd))
    mask = (jnp.arange(S) <= pos)[None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    ctx = jnp.einsum("bhs,bhsd->bhd", w, vv).reshape(B, D)
    return x + ctx @ wo, new_k, new_v


def router(x, wr):
    """Pallas top-1 router. x [B, D], wr [D, E] -> (gates [B], idx [B] i32)."""
    return pallas_router(x, wr)


def expert(x, w1, b1, w2, b2):
    """Pallas expert FFN over the tokens routed to one expert. [T,D]->[T,D]."""
    return expert_ffn(x, w1, b1, w2, b2)


def combine(x, expert_out, gates, sel):
    """Residual + gated combine of per-token expert outputs.

    x [B, D] pre-FFN hidden; expert_out [B, D] rows already gathered back
    into token order by rust; gates [B]; sel [B] f32 mask (1.0 where the row
    is a real token, 0.0 for batch padding).
    """
    return x + expert_out * (gates * sel)[:, None]


def lm_head(x, w_out):
    """x [B, D], w_out [D, V] -> greedy next token ids [B] i32."""
    logits = x @ w_out
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
