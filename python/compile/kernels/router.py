"""L1 Pallas kernel: Switch top-1 router.

Small (E <= 256, D <= 1024) so a single-block VMEM-resident kernel is the
right shape: one (B, D) @ (D, E) MXU matmul, then a fused VPU softmax +
argmax. Emits the top-1 gate value and expert index per token — exactly the
signal the rust coordinator consumes to update the current EAM (Alg. 1
steps 5-7).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(x_ref, wr_ref, gate_ref, idx_ref):
    logits = jnp.dot(
        x_ref[...].astype(jnp.float32),
        wr_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / p.sum(axis=-1, keepdims=True)
    idx = jnp.argmax(p, axis=-1)
    gate_ref[...] = jnp.max(p, axis=-1)
    idx_ref[...] = idx.astype(jnp.int32)


@jax.jit
def router(x, wr):
    """x [B, D], wr [D, E] -> (gates [B] f32, idx [B] i32)."""
    B, _ = x.shape
    return pl.pallas_call(
        _router_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ),
        interpret=True,
    )(x, wr)
