"""L1 Pallas kernel: Switch-Transformer expert FFN, tiled for TPU VMEM.

The paper's compute hot-spot on the GPU is the per-expert FFN
``y = relu(x @ w1 + b1) @ w2 + b2``. The CUDA implementation tiles this over
threadblocks in shared memory; the TPU re-think (DESIGN.md §Hardware
Adaptation) tiles for VMEM instead:

* token dimension blocked at 8 (f32 sublane granularity),
* the hidden dimension ``F = d_ff`` blocked at 128 (lane granularity) and
  walked by the *grid*, so each grid step stages one ``(D, bf)`` slice of
  ``w1`` and one ``(bf, D)`` slice of ``w2`` HBM -> VMEM (double-buffered by
  the Pallas pipeline) while accumulating the second matmul into the output
  block that stays resident in VMEM,
* the MXU sees ``(bt x D) @ (D x bf)`` and ``(bt x bf) @ (bf x D)`` tiles.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so interpret mode is both the correctness path and the form
that lowers into the AOT HLO artifact consumed by the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _largest_divisor_at_most(n: int, cap: int) -> int:
    """Largest d <= cap with n % d == 0 (>= 1). Picks MXU/VPU-aligned tiles
    when the dims allow and degrades gracefully for odd test shapes."""
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One grid step: o[i] += relu(x[i] @ w1[:, j] + b1[j]) @ w2[j, :].

    Grid is (token blocks, F blocks); j (F) is the reduction axis walked
    sequentially so the output block accumulates in VMEM.
    """
    j = pl.program_id(1)
    h = jnp.maximum(
        jnp.dot(x_ref[...], w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...][None, :],
        0.0,
    )
    part = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part + b2_ref[...][None, :]

    @pl.when(j != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_t", "block_f"))
def expert_ffn(x, w1, b1, w2, b2, block_t: int = 8, block_f: int = 128):
    """Pallas expert FFN. Shapes: x [T, D], w1 [D, F], b1 [F], w2 [F, D],
    b2 [D] -> [T, D]. Computes in f32 and casts back to ``x.dtype``."""
    T, D = x.shape
    F = w1.shape[1]
    bt = _largest_divisor_at_most(T, block_t)
    bf = _largest_divisor_at_most(F, block_f)
    grid = (T // bt, F // bf)

    xf = x.astype(jnp.float32)
    out = pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, D), lambda i, j: (i, 0)),   # x: stays per i
            pl.BlockSpec((D, bf), lambda i, j: (0, j)),   # w1 slice walks F
            pl.BlockSpec((bf,), lambda i, j: (j,)),       # b1 slice
            pl.BlockSpec((bf, D), lambda i, j: (j, 0)),   # w2 slice walks F
            pl.BlockSpec((D,), lambda i, j: (0,)),        # b2
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), jnp.float32),
        interpret=True,
    )(
        xf,
        w1.astype(jnp.float32),
        b1.astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.astype(jnp.float32),
    )
    return out.astype(x.dtype)


def vmem_bytes(block_t: int, d_model: int, block_f: int, dtype_bytes: int = 4):
    """Estimated VMEM residency of one grid step (for DESIGN.md §Perf):
    x block + w1 slice + w2 slice + biases + h + output block."""
    return dtype_bytes * (
        block_t * d_model      # x block
        + d_model * block_f    # w1 slice
        + block_f * d_model    # w2 slice
        + block_f + d_model    # biases
        + block_t * block_f    # h intermediate
        + block_t * d_model    # output accumulator
    )
