"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle to float tolerance across a hypothesis-driven sweep of
shapes and dtypes (see python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def expert_ffn_ref(x, w1, b1, w2, b2):
    """Switch-Transformer expert FFN: y = relu(x @ w1 + b1) @ w2 + b2.

    Args:
      x:  [T, D]  tokens routed to this expert.
      w1: [D, F]  up projection.
      b1: [F]
      w2: [F, D]  down projection.
      b2: [D]
    Returns:
      [T, D]
    """
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2


def router_ref(x, wr):
    """Switch top-1 router: softmax gate + argmax expert index.

    Args:
      x:  [B, D] token hidden states.
      wr: [D, E] router weights.
    Returns:
      (gates [B] f32, idx [B] i32): the top-1 gate value and expert index.
    """
    logits = x @ wr
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    idx = jnp.argmax(probs, axis=-1).astype(jnp.int32)
    gates = jnp.take_along_axis(probs, idx[:, None], axis=-1)[:, 0]
    return gates, idx


def attention_ref(x, k_cache, v_cache, pos, wq, wk, wv, wo, n_heads):
    """Single-step causal attention with a fixed-size KV cache.

    Args:
      x:       [B, D]      current-token hidden states.
      k_cache: [B, S, D]   key cache (S = max sequence length).
      v_cache: [B, S, D]   value cache.
      pos:     []  i32     current position (same for all batch rows; rust
                           pads per-sequence).
      wq, wk, wv, wo: [D, D].
      n_heads: static int.
    Returns:
      (out [B, D], new_k [B, S, D], new_v [B, S, D])
    """
    B, S, D = k_cache.shape
    H = n_heads
    hd = D // H
    q = (x @ wq).reshape(B, H, hd)
    k = (x @ wk).reshape(B, H, hd)
    v = (x @ wv).reshape(B, H, hd)
    # write k, v at position pos
    onehot = (jnp.arange(S) == pos).astype(k_cache.dtype)  # [S]
    new_k = k_cache * (1.0 - onehot)[None, :, None] + onehot[None, :, None] * (
        k.reshape(B, 1, D)
    )
    new_v = v_cache * (1.0 - onehot)[None, :, None] + onehot[None, :, None] * (
        v.reshape(B, 1, D)
    )
    kk = new_k.reshape(B, S, H, hd).transpose(0, 2, 1, 3)  # [B,H,S,hd]
    vv = new_v.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhd,bhsd->bhs", q, kk) / jnp.sqrt(float(hd))
    mask = (jnp.arange(S) <= pos)[None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    ctx = jnp.einsum("bhs,bhsd->bhd", w, vv).reshape(B, D)
    return ctx @ wo, new_k, new_v
