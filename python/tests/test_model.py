"""L2 model-piece tests: shapes, numerics vs refs, and decode-step glue."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.model import ModelConfig


def _keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def test_embed_shape_and_lookup():
    ks = _keys(1)
    emb = jax.random.normal(ks[0], (32, 8))
    ids = jnp.array([0, 5, 31, 5], dtype=jnp.int32)
    out = model.embed(ids, emb)
    assert out.shape == (4, 8)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(emb[5]))
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(out[3]))


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([1, 4]),
    s=st.sampled_from([8, 16]),
    pos=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_attn_step_matches_ref(b, s, pos, seed):
    d, h = 16, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    x = jax.random.normal(ks[0], (b, d))
    kc = jax.random.normal(ks[1], (b, s, d))
    vc = jax.random.normal(ks[2], (b, s, d))
    wq, wk, wv, wo = (jax.random.normal(ks[3 + i], (d, d)) * 0.1 for i in range(4))
    out, nk, nv = model.attn_step(x, kc, vc, jnp.int32(pos), wq, wk, wv, wo, n_heads=h)
    ro, rk, rv = ref.attention_ref(x, kc, vc, jnp.int32(pos), wq, wk, wv, wo, h)
    # model adds the residual
    np.testing.assert_allclose(np.asarray(out), np.asarray(x + ro), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(nk), np.asarray(rk), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(nv), np.asarray(rv), rtol=1e-5, atol=1e-5)


def test_attn_step_kv_write_position():
    b, s, d, h = 2, 8, 16, 4
    ks = _keys(7, seed=9)
    x = jax.random.normal(ks[0], (b, d))
    kc = jnp.zeros((b, s, d))
    vc = jnp.zeros((b, s, d))
    wq, wk, wv, wo = (jax.random.normal(ks[3 + i], (d, d)) * 0.1 for i in range(4))
    _, nk, nv = model.attn_step(x, kc, vc, jnp.int32(3), wq, wk, wv, wo, n_heads=h)
    nk = np.asarray(nk)
    # only position 3 written
    assert np.abs(nk[:, 3]).sum() > 0
    mask = np.ones(s, dtype=bool)
    mask[3] = False
    assert np.abs(nk[:, mask]).sum() == 0


def test_combine_applies_gate_and_mask():
    x = jnp.ones((3, 4))
    eo = jnp.ones((3, 4)) * 2.0
    gates = jnp.array([0.5, 1.0, 0.25])
    sel = jnp.array([1.0, 0.0, 1.0])
    out = np.asarray(model.combine(x, eo, gates, sel))
    np.testing.assert_allclose(out[0], 1.0 + 2.0 * 0.5)
    np.testing.assert_allclose(out[1], 1.0)  # padded row: residual only
    np.testing.assert_allclose(out[2], 1.0 + 2.0 * 0.25)


def test_lm_head_greedy():
    x = jnp.eye(3, dtype=jnp.float32)  # [3, 3]
    w = jnp.array([[0.0, 10.0, 0.0, 0.0], [0.0, 0.0, 10.0, 0.0], [5.0, 0.0, 0.0, 9.0]])
    out = np.asarray(model.lm_head(x, w))
    np.testing.assert_array_equal(out, [1, 2, 3])


def test_full_decode_step_composition():
    """Glue test: run one full MoE decode step purely from the pieces, the
    same way the rust engine composes them, and check against a monolithic
    reference."""
    cfg = ModelConfig(vocab=64, d_model=16, d_ff=32, n_heads=4, n_layers=2, n_experts=4, max_seq=8, batch=4)
    ks = _keys(16, seed=42)
    emb = jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.5
    ids = jnp.array([1, 7, 33, 12], dtype=jnp.int32)

    x = model.embed(ids, emb)
    kc = jnp.zeros((cfg.batch, cfg.max_seq, cfg.d_model))
    vc = jnp.zeros((cfg.batch, cfg.max_seq, cfg.d_model))
    wq, wk, wv, wo = (jax.random.normal(ks[1 + i], (cfg.d_model, cfg.d_model)) * 0.1 for i in range(4))
    x, kc, vc = model.attn_step(x, kc, vc, jnp.int32(0), wq, wk, wv, wo, n_heads=cfg.n_heads)

    wr = jax.random.normal(ks[5], (cfg.d_model, cfg.n_experts))
    gates, idx = model.router(x, wr)
    ew = [
        (
            jax.random.normal(ks[6 + e], (cfg.d_model, cfg.d_ff)) * 0.1,
            jnp.zeros((cfg.d_ff,)),
            jax.random.normal(ks[10 + e], (cfg.d_ff, cfg.d_model)) * 0.1,
            jnp.zeros((cfg.d_model,)),
        )
        for e in range(cfg.n_experts)
    ]
    # per-expert execution exactly as rust does: gather rows, pad to B, run, scatter
    eo = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        rows = np.nonzero(np.asarray(idx) == e)[0]
        if len(rows) == 0:
            continue
        xin = jnp.zeros_like(x).at[: len(rows)].set(x[rows])
        yout = model.expert(xin, *ew[e])
        eo = eo.at[jnp.array(rows)].set(yout[: len(rows)])
    out = model.combine(x, eo, gates, jnp.ones((cfg.batch,)))

    # monolithic reference
    want = x + jnp.stack(
        [ref.expert_ffn_ref(x[i : i + 1], *ew[int(idx[i])])[0] * gates[i] for i in range(cfg.batch)]
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_expert_param_count_matches_geometry():
    cfg = ModelConfig()
    assert cfg.expert_param_count == 2 * 64 * 128 + 128 + 64
