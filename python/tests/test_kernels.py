"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes and dtypes for the Pallas kernels and asserts
allclose against the pure-jnp oracles in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.expert_ffn import expert_ffn, vmem_bytes, _largest_divisor_at_most
from compile.kernels.router import router
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- expert_ffn

@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 3, 4, 8, 16]),
    d=st.sampled_from([8, 16, 64]),
    f=st.sampled_from([16, 128, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_matches_ref_f32(t, d, f, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _rand(ks[0], (t, d), jnp.float32)
    w1 = _rand(ks[1], (d, f), jnp.float32) * 0.1
    b1 = _rand(ks[2], (f,), jnp.float32) * 0.1
    w2 = _rand(ks[3], (f, d), jnp.float32) * 0.1
    b2 = _rand(ks[4], (d,), jnp.float32) * 0.1
    got = expert_ffn(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(jnp.float32))


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([2, 8]),
    d=st.sampled_from([16, 64]),
    f=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_matches_ref_bf16(t, d, f, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    dt = jnp.bfloat16
    x = _rand(ks[0], (t, d), dt)
    w1 = _rand(ks[1], (d, f), dt) * 0.1
    b1 = _rand(ks[2], (f,), dt) * 0.1
    w2 = _rand(ks[3], (f, d), dt) * 0.1
    b2 = _rand(ks[4], (d,), dt) * 0.1
    got = expert_ffn(x, w1, b1, w2, b2).astype(jnp.float32)
    want = ref.expert_ffn_ref(
        x.astype(jnp.float32),
        w1.astype(jnp.float32),
        b1.astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.astype(jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dt))


def test_expert_ffn_odd_shapes_fall_back_to_full_block():
    # T=5, F=7: no nice divisors; kernel must still be exact.
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = _rand(ks[0], (5, 12), jnp.float32)
    w1 = _rand(ks[1], (12, 7), jnp.float32)
    b1 = _rand(ks[2], (7,), jnp.float32)
    w2 = _rand(ks[3], (7, 12), jnp.float32)
    b2 = _rand(ks[4], (12,), jnp.float32)
    got = expert_ffn(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_expert_ffn_grid_accumulation_multi_block():
    # F=256 with block_f=128 -> 2 reduction steps; checks the accumulate path.
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = _rand(ks[0], (8, 32), jnp.float32)
    w1 = _rand(ks[1], (32, 256), jnp.float32) * 0.05
    b1 = _rand(ks[2], (256,), jnp.float32) * 0.05
    w2 = _rand(ks[3], (256, 32), jnp.float32) * 0.05
    b2 = _rand(ks[4], (32,), jnp.float32) * 0.05
    got = expert_ffn(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_largest_divisor():
    assert _largest_divisor_at_most(256, 128) == 128
    assert _largest_divisor_at_most(96, 128) == 96
    assert _largest_divisor_at_most(7, 4) == 1
    assert _largest_divisor_at_most(12, 8) == 6


def test_vmem_budget_for_paper_geometries():
    # switch-large geometry (d_model=1024, d_ff=2816-ish): one grid step must
    # fit in 16MB VMEM with bt=8, bf=128.
    assert vmem_bytes(8, 1024, 128) <= 16 * 2**20
    # nllb-moe geometry d_model=2048, d_ff=8192
    assert vmem_bytes(8, 2048, 128) <= 16 * 2**20


# -------------------------------------------------------------------- router

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 4, 16]),
    d=st.sampled_from([8, 64]),
    e=st.sampled_from([4, 8, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_router_matches_ref(b, d, e, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = _rand(ks[0], (b, d), jnp.float32)
    wr = _rand(ks[1], (d, e), jnp.float32)
    g_got, i_got = router(x, wr)
    g_want, i_want = ref.router_ref(x, wr)
    np.testing.assert_array_equal(np.asarray(i_got), np.asarray(i_want))
    np.testing.assert_allclose(np.asarray(g_got), np.asarray(g_want), rtol=1e-5, atol=1e-5)


def test_router_gate_is_probability():
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = _rand(ks[0], (16, 32), jnp.float32)
    wr = _rand(ks[1], (32, 8), jnp.float32)
    g, i = router(x, wr)
    g = np.asarray(g)
    assert ((g > 1.0 / 8 - 1e-6) & (g <= 1.0 + 1e-6)).all()
    assert ((np.asarray(i) >= 0) & (np.asarray(i) < 8)).all()
