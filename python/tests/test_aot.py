"""AOT path tests: every artifact lowers, parses as HLO text, and the
manifest geometry matches ModelConfig."""

import json

import pytest

from compile.aot import build_artifacts, to_hlo_text
from compile.model import ModelConfig

CFG = ModelConfig(vocab=64, d_model=16, d_ff=32, n_heads=4, n_layers=2, n_experts=4, max_seq=8, batch=2)


@pytest.fixture(scope="module")
def pieces():
    return build_artifacts(CFG)


EXPECTED = {"embed", "attn_step", "router", "expert", "combine", "lm_head"}


def test_all_pieces_present(pieces):
    assert set(pieces) == EXPECTED


def test_hlo_text_nonempty_and_entry(pieces):
    for name, (text, _, _) in pieces.items():
        assert "ENTRY" in text, f"{name} missing ENTRY computation"
        assert len(text) > 100


def test_arg_shapes_match_config(pieces):
    B, D, E, F = CFG.batch, CFG.d_model, CFG.n_experts, CFG.d_ff
    args = pieces["router"][1]
    assert args[0]["shape"] == [B, D]
    assert args[1]["shape"] == [D, E]
    args = pieces["expert"][1]
    assert args[0]["shape"] == [B, D]
    assert args[1]["shape"] == [D, F]
    assert pieces["attn_step"][2] == 3  # out, new_k, new_v


def test_pallas_lowered_to_plain_hlo(pieces):
    # interpret=True must leave no mosaic/custom-call in the artifact —
    # otherwise the rust CPU PJRT client cannot execute it.
    for name in ("expert", "router"):
        text = pieces[name][0]
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_output_arities(pieces):
    assert {n: p[2] for n, p in pieces.items()} == {
        "embed": 1,
        "attn_step": 3,
        "router": 2,
        "expert": 1,
        "combine": 1,
        "lm_head": 1,
    }
