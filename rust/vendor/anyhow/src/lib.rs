//! Minimal in-tree shim for the `anyhow` API surface this repository uses:
//! [`Error`], [`Result`], [`Context`], `anyhow!` and `bail!`.
//!
//! The offline image has no crates.io access, so vendoring a small
//! API-compatible subset keeps the crate buildable without network. Error
//! values are flattened to strings at conversion time; context is prepended
//! `"{context}: {cause}"` exactly like upstream renders a one-level chain
//! with `{:#}`.

use std::fmt;

/// String-backed error value. Deliberately does **not** implement
/// `std::error::Error`, which is what makes the blanket `From<E: Error>`
/// conversion below coherent (same trick as upstream anyhow).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line (`"{context}: {self}"`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error as it propagates (subset of anyhow's trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{context}: {e}"),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error {
            msg: context.to_string(),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error {
            msg: f().to_string(),
        })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading x").unwrap_err();
        assert_eq!(e.to_string(), "reading x: gone");
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn b() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(b().unwrap_err().to_string(), "nope 1");
    }
}
