//! Inert stub of the `xla` (xla_extension) bindings.
//!
//! The container this repo builds in has no PJRT plugin and no crates.io
//! access, so the real bindings cannot be linked. This stub mirrors the
//! API surface `runtime/` uses; every entry point that would touch PJRT
//! returns [`Error::Unavailable`]. `Runtime::load` therefore fails fast
//! with a clear message, and `tests/runtime_e2e.rs` (which skips itself
//! when `artifacts/` is absent) never reaches these paths in CI.

use std::fmt;
use std::path::Path;

/// Error type matching the `?`-conversion shape of the real bindings.
#[derive(Debug)]
pub enum Error {
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla backend unavailable: this build uses the in-tree stub \
             (no PJRT plugin in the image); the simulated serving stack \
             does not require it"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal placeholder. Constructors succeed (they are pure host-side
/// operations in the real bindings too); anything that would read device
/// data fails with [`Error::Unavailable`].
#[derive(Debug, Clone, Default)]
pub struct Literal {}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal {}
    }

    pub fn scalar<T: Copy>(_v: T) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal {})
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::Unavailable)
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}

#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::Unavailable)
    }
}

#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }
}
