//! Differential suite for the request-lifecycle serving API (the
//! `Scheduler` trait / `Router` redesign): the new surface must reproduce
//! the pre-trait replays bitwise wherever it claims compatibility.
//!
//! * `StaticScheduler` / `ContinuousScheduler` vs the historical
//!   `serve`/`serve_continuous` replays: pinned transitively — the
//!   continuous-at-`max_batch=1` == static differential in
//!   `rust/tests/parallel.rs` replays the same PR 3 traces through the new
//!   implementations, and any drift in either scheduler breaks it.
//! * A 1-replica round-robin `Router` equals a bare `ContinuousScheduler`
//!   bitwise (the dispatch gate provably never changes admission instants
//!   with one replica).
//! * Preempt-then-resume equals the uninterrupted run in per-token expert
//!   demands (engine-level version lives in `engine::sim_engine` tests;
//!   here the scheduler-level replay is pinned end to end).
//! * Multi-replica routing replays are deterministic functions of the
//!   config.

use moe_infinity::benchsuite::{build_engine_with, build_requests, run_serve_with};
use moe_infinity::config::{SchedulerKind, ServeConfig};
use moe_infinity::server::{
    AdmissionPolicy, Batcher, Router, RoutingPolicy, Scheduler, ServeReport,
};
use moe_infinity::util::Pool;

fn base_cfg(rps: f64) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "switch-base-32".into();
    // 4GB GPU: offloading (and the whole prefetch/cache/queue machinery)
    // actually engages instead of everything staying warm
    cfg.memory.gpu_gb = 4.0;
    cfg.workload.rps = rps;
    cfg.workload.duration = 8.0;
    cfg.scheduler = SchedulerKind::Continuous;
    cfg.eamc.trace_sequences = 25;
    cfg.eamc.capacity = 6;
    cfg
}

fn assert_bitwise(a: &ServeReport, b: &ServeReport, ctx: &str) {
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.batches, b.batches, "{ctx}: batches");
    assert_eq!(a.demands, b.demands, "{ctx}: demands");
    assert_eq!(a.gpu_hits, b.gpu_hits, "{ctx}: gpu hits");
    assert_eq!(a.prefetch_bytes, b.prefetch_bytes, "{ctx}: prefetch bytes");
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{ctx}: makespan {} vs {}",
        a.makespan,
        b.makespan
    );
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(a.token_latency.samples()),
        bits(b.token_latency.samples()),
        "{ctx}: token latencies"
    );
    assert_eq!(
        bits(a.request_latency.samples()),
        bits(b.request_latency.samples()),
        "{ctx}: request latencies"
    );
    assert_eq!(bits(a.ttft.samples()), bits(b.ttft.samples()), "{ctx}: ttft");
    assert_eq!(bits(a.tpot.samples()), bits(b.tpot.samples()), "{ctx}: tpot");
}

#[test]
fn single_replica_round_robin_router_matches_bare_continuous_bitwise() {
    // sparse (idle gaps between requests) and queued (overlap) regimes
    for rps in [0.5, 4.0] {
        let cfg = base_cfg(rps);
        let pool = Pool::serial();
        let bare = run_serve_with(&cfg, &pool).expect("bare continuous");
        let requests = build_requests(&cfg).expect("requests");
        let engine = build_engine_with(&cfg, &pool).expect("engine");
        let batcher = Batcher::new(cfg.batching.max_batch, cfg.batching.max_wait);
        let mut router = Router::new(
            vec![engine],
            batcher,
            RoutingPolicy::RoundRobin,
            AdmissionPolicy::Fifo,
        );
        router.submit_all(&requests);
        let routed = router.drain();
        assert_bitwise(&routed, &bare, &format!("rps={rps}"));
    }
}

#[test]
fn multi_replica_router_replay_is_deterministic() {
    for routing in [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::TaskAffinity,
    ] {
        let mut cfg = base_cfg(3.0);
        cfg.replicas = 2;
        cfg.routing = routing;
        cfg.priority = AdmissionPolicy::Classes;
        cfg.workload.interactive_frac = 0.3;
        let a = run_serve_with(&cfg, &Pool::serial()).expect("router serve");
        let b = run_serve_with(&cfg, &Pool::new(4)).expect("router serve again");
        assert_bitwise(&a, &b, &format!("routing={routing:?}"));
        assert!(a.requests > 0);
    }
}

#[test]
fn classes_admission_serves_the_same_work_as_fifo() {
    let mut cfg = base_cfg(6.0);
    cfg.workload.interactive_frac = 0.25;
    cfg.priority = AdmissionPolicy::Fifo;
    let fifo = run_serve_with(&cfg, &Pool::serial()).expect("fifo");
    cfg.priority = AdmissionPolicy::Classes;
    let cls = run_serve_with(&cfg, &Pool::serial()).expect("classes");
    // same request stream, same total work — only the ordering may differ
    assert_eq!(fifo.requests, cls.requests);
    assert_eq!(fifo.tokens, cls.tokens);
    assert_eq!(fifo.request_latency.len(), cls.request_latency.len());
    assert_eq!(fifo.ttft.len(), cls.ttft.len());
}

#[test]
fn prefetch_cancellation_serves_identical_work() {
    // the dead-PCIe-traffic satellite is *quantified* by perf_router /
    // perf_scheduler (`cancel_*` rows in BENCH_scheduler.json); here the
    // tier-1 contract is that the cancellation path completes the same
    // work and accounts its traffic (the direct queue-drop mechanism is
    // pinned in the engine and memory-sim unit tests)
    let mut cfg = base_cfg(6.0);
    cfg.memory.gpu_gb = 3.0; // heavier offloading => more queued predictions
    let off = run_serve_with(&cfg, &Pool::serial()).expect("cancel off");
    cfg.cancel_retired_prefetch = true;
    let on = run_serve_with(&cfg, &Pool::serial()).expect("cancel on");
    assert_eq!(off.requests, on.requests);
    assert_eq!(off.tokens, on.tokens);
    assert!(on.prefetch_bytes > 0 && off.prefetch_bytes > 0);
}
