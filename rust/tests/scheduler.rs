//! Differential suite for the request-lifecycle serving API (the
//! `Scheduler` trait / `Router` redesign): the new surface must reproduce
//! the pre-trait replays bitwise wherever it claims compatibility.
//!
//! * `StaticScheduler` / `ContinuousScheduler` vs the historical
//!   `serve`/`serve_continuous` replays: pinned transitively — the
//!   continuous-at-`max_batch=1` == static differential in
//!   `rust/tests/parallel.rs` replays the same PR 3 traces through the new
//!   implementations, and any drift in either scheduler breaks it.
//! * A 1-replica round-robin `Router` equals a bare `ContinuousScheduler`
//!   bitwise (the dispatch gate provably never changes admission instants
//!   with one replica).
//! * Preempt-then-resume equals the uninterrupted run in per-token expert
//!   demands (engine-level version lives in `engine::sim_engine` tests;
//!   here the scheduler-level replay is pinned end to end).
//! * A `ChunkedScheduler` with an unlimited `prefill_chunk` equals a bare
//!   `ContinuousScheduler` bitwise (the ∞-chunk proportional split records
//!   the identical whole-prompt counts).
//! * The Classes admission heap pops in exactly the order the retired
//!   O(backlog) rescan picked — the `AdmitKey` is time-invariant, so heap
//!   order at enqueue time equals scan order at any later `now`.
//! * Multi-replica routing replays are deterministic functions of the
//!   config.

use std::collections::{BinaryHeap, VecDeque};

use moe_infinity::benchsuite::{
    build_engine_with, build_replica_engines_with, build_requests, run_serve_with,
};
use moe_infinity::config::{SchedulerKind, ServeConfig};
use moe_infinity::faults::{CrashWindow, FaultPlan};
use moe_infinity::model::ModelSpec;
use moe_infinity::server::{
    admit_key, pick_candidate, AdmissionPolicy, Batcher, ChunkedScheduler, ContinuousScheduler,
    RequestStat, Router, RoutingPolicy, Scheduler, ServeReport, StaticScheduler,
};
use moe_infinity::trace::Eam;
use moe_infinity::util::units::SimTime;
use moe_infinity::util::{Pool, Rng};
use moe_infinity::workload::{DatasetPreset, Priority, Request, RequestClass, Workload};

fn base_cfg(rps: f64) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "switch-base-32".into();
    // 4GB GPU: offloading (and the whole prefetch/cache/queue machinery)
    // actually engages instead of everything staying warm
    cfg.memory.gpu_gb = 4.0;
    cfg.workload.rps = rps;
    cfg.workload.duration = 8.0;
    cfg.scheduler = SchedulerKind::Continuous;
    cfg.eamc.trace_sequences = 25;
    cfg.eamc.capacity = 6;
    cfg
}

fn assert_bitwise(a: &ServeReport, b: &ServeReport, ctx: &str) {
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.batches, b.batches, "{ctx}: batches");
    assert_eq!(a.demands, b.demands, "{ctx}: demands");
    assert_eq!(a.gpu_hits, b.gpu_hits, "{ctx}: gpu hits");
    assert_eq!(a.prefetch_bytes, b.prefetch_bytes, "{ctx}: prefetch bytes");
    assert_eq!(a.shed, b.shed, "{ctx}: shed");
    assert_eq!(a.timed_out, b.timed_out, "{ctx}: timed out");
    assert_eq!(a.goodput_tokens, b.goodput_tokens, "{ctx}: goodput tokens");
    assert_eq!(a.demand_failures, b.demand_failures, "{ctx}: demand failures");
    assert_eq!(
        a.transfer_retries, b.transfer_retries,
        "{ctx}: transfer retries"
    );
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{ctx}: makespan {} vs {}",
        a.makespan,
        b.makespan
    );
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(a.token_latency.samples()),
        bits(b.token_latency.samples()),
        "{ctx}: token latencies"
    );
    assert_eq!(
        bits(a.request_latency.samples()),
        bits(b.request_latency.samples()),
        "{ctx}: request latencies"
    );
    assert_eq!(bits(a.ttft.samples()), bits(b.ttft.samples()), "{ctx}: ttft");
    assert_eq!(bits(a.tpot.samples()), bits(b.tpot.samples()), "{ctx}: tpot");
    assert_eq!(
        bits(a.decode_latency.samples()),
        bits(b.decode_latency.samples()),
        "{ctx}: decode latencies"
    );
}

#[test]
fn single_replica_round_robin_router_matches_bare_continuous_bitwise() {
    // sparse (idle gaps between requests) and queued (overlap) regimes
    for rps in [0.5, 4.0] {
        let cfg = base_cfg(rps);
        let pool = Pool::serial();
        let bare = run_serve_with(&cfg, &pool).expect("bare continuous");
        let requests = build_requests(&cfg).expect("requests");
        let engine = build_engine_with(&cfg, &pool).expect("engine");
        let batcher = Batcher::new(cfg.batching.max_batch, cfg.batching.max_wait);
        let mut router = Router::new(
            vec![engine],
            batcher,
            RoutingPolicy::RoundRobin,
            AdmissionPolicy::Fifo,
        );
        router.submit_all(&requests);
        let routed = router.drain();
        assert_bitwise(&routed, &bare, &format!("rps={rps}"));
    }
}

#[test]
fn multi_replica_router_replay_is_deterministic() {
    for routing in [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::TaskAffinity,
    ] {
        let mut cfg = base_cfg(3.0);
        cfg.replicas = 2;
        cfg.routing = routing;
        cfg.priority = AdmissionPolicy::Classes;
        cfg.workload.interactive_frac = 0.3;
        let a = run_serve_with(&cfg, &Pool::serial()).expect("router serve");
        let b = run_serve_with(&cfg, &Pool::new(4)).expect("router serve again");
        assert_bitwise(&a, &b, &format!("routing={routing:?}"));
        assert!(a.requests > 0);
    }
}

#[test]
fn chunked_unlimited_matches_bare_continuous_bitwise() {
    // the acceptance pin: ChunkedScheduler with prefill_chunk = ∞ replays
    // the continuous scheduler exactly, in both the sparse and the queued
    // regime of the pooled determinism grid's base config
    for rps in [0.5, 4.0] {
        let cfg = base_cfg(rps);
        let cont = run_serve_with(&cfg, &Pool::serial()).expect("continuous");
        let mut c2 = cfg.clone();
        c2.scheduler = SchedulerKind::Chunked;
        c2.prefill_chunk = 0; // unlimited
        let chunked = run_serve_with(&c2, &Pool::serial()).expect("chunked ∞");
        assert_bitwise(&chunked, &cont, &format!("chunked-∞ rps={rps}"));
    }
}

#[test]
fn chunked_finite_serves_identical_work() {
    // a real chunk splits every long prompt across iterations: the same
    // requests and tokens complete, per-request accounting stays whole,
    // and the replay takes strictly more engine iterations
    let cfg = base_cfg(6.0);
    let cont = run_serve_with(&cfg, &Pool::serial()).expect("continuous");
    let mut c2 = cfg.clone();
    c2.scheduler = SchedulerKind::Chunked;
    c2.prefill_chunk = 8; // below the mixed preset's minimum prompt (16)
    let chunked = run_serve_with(&c2, &Pool::serial()).expect("chunked");
    assert_eq!(chunked.requests, cont.requests);
    assert_eq!(chunked.tokens, cont.tokens);
    assert_eq!(chunked.request_latency.len(), cont.request_latency.len());
    assert_eq!(chunked.ttft.len(), cont.ttft.len());
    assert!(
        chunked.batches > cont.batches,
        "splitting every prefill must add iterations ({} vs {})",
        chunked.batches,
        cont.batches
    );
    assert!(chunked.decode_latency.len() > 0);
}

#[test]
fn chunked_composes_with_classes_and_router_deterministically() {
    let mut cfg = base_cfg(3.0);
    cfg.scheduler = SchedulerKind::Chunked;
    cfg.prefill_chunk = 32;
    cfg.replicas = 2;
    cfg.routing = RoutingPolicy::TaskAffinity;
    cfg.priority = AdmissionPolicy::Classes;
    cfg.workload.interactive_frac = 0.3;
    let a = run_serve_with(&cfg, &Pool::serial()).expect("chunked router");
    let b = run_serve_with(&cfg, &Pool::new(4)).expect("chunked router again");
    assert_bitwise(&a, &b, "chunked+classes+affinity");
    assert!(a.requests > 0);
    assert_eq!(a.request_latency.len() as u64, a.requests);
}

/// The fault layer's compatibility contract: an explicitly installed
/// **empty** `FaultPlan` (no failure probabilities, no brownouts, no
/// crash windows) must replay the entire existing stack bitwise — the
/// static, continuous, and chunked schedulers and a 2-replica router.
/// `MemorySim` only materializes fault state when a plan perturbs links,
/// so this pins that the disabled path is the fault-free path, not an
/// equivalent-looking reimplementation of it.
#[test]
fn empty_fault_plan_replays_every_scheduler_bitwise() {
    let pool = Pool::serial();
    let empty = |cfg: &ServeConfig| FaultPlan::new(cfg.seed ^ 0xFA57);
    for sched in [
        SchedulerKind::Static,
        SchedulerKind::Continuous,
        SchedulerKind::Chunked,
    ] {
        let mut cfg = base_cfg(3.0);
        cfg.scheduler = sched;
        if sched == SchedulerKind::Chunked {
            cfg.prefill_chunk = 32;
        }
        let baseline = run_serve_with(&cfg, &pool).expect("fault-free serve");
        let requests = build_requests(&cfg).expect("requests");
        let mut engine = build_engine_with(&cfg, &pool).expect("engine");
        engine.set_fault_plan(&empty(&cfg));
        let batcher = Batcher::new(cfg.batching.max_batch, cfg.batching.max_wait);
        let faulted = match sched {
            SchedulerKind::Static => {
                let mut s = StaticScheduler::new(engine, batcher);
                s.submit_all(&requests);
                s.drain()
            }
            SchedulerKind::Continuous => {
                let mut s = ContinuousScheduler::new(engine, batcher, cfg.priority);
                s.submit_all(&requests);
                s.drain()
            }
            SchedulerKind::Chunked => {
                let mut s = ChunkedScheduler::new(
                    engine,
                    batcher,
                    cfg.priority,
                    cfg.prefill_chunk_u32(),
                );
                s.submit_all(&requests);
                s.drain()
            }
        };
        assert_eq!(faulted.transfer_retries, 0, "{sched:?}: no retries");
        assert_eq!(faulted.demand_failures, 0, "{sched:?}: no failures");
        assert_eq!(faulted.shed, 0, "{sched:?}: no shedding");
        assert_eq!(faulted.timed_out, 0, "{sched:?}: no timeouts");
        assert_bitwise(&faulted, &baseline, &format!("{sched:?} empty plan"));
    }
    // 2-replica router: the same pin through the dispatch layer
    let mut cfg = base_cfg(3.0);
    cfg.replicas = 2;
    let baseline = run_serve_with(&cfg, &pool).expect("fault-free router");
    let requests = build_requests(&cfg).expect("requests");
    let engines = build_replica_engines_with(&cfg, &pool).expect("engines");
    let batcher = Batcher::new(cfg.batching.max_batch, cfg.batching.max_wait);
    let mut router =
        Router::new(engines, batcher, cfg.routing, cfg.priority).with_fault_plan(&empty(&cfg));
    router.submit_all(&requests);
    let faulted = router.drain();
    assert_bitwise(&faulted, &baseline, "2-replica router empty plan");
}

/// Satellite of the fault-injection PR (extends the PR 4 preempt/resume
/// differential to the cross-replica case): a sequence evicted by a
/// replica crash and resumed **on a different engine** must produce
/// identical per-token expert demands to the uninterrupted run. Per-token
/// demands are a pure function of the replayed trace (every activated
/// expert is demanded, hit or miss), so the pin is exact: the traced EAM
/// at handoff equals the trace prefix, and the crashed + survivor demand
/// totals equal the uninterrupted run's.
#[test]
fn replica_crash_failover_preserves_per_token_expert_demands() {
    let cfg = base_cfg(1.0);
    let pool = Pool::serial();
    let requests = build_requests(&cfg).expect("requests");
    let req = &requests[0];
    let iters = req.seq.iterations();
    assert!(iters >= 2, "need a multi-iteration request");
    let mk = || {
        let engine = build_engine_with(&cfg, &pool).expect("engine");
        let batcher = Batcher::new(cfg.batching.max_batch, cfg.batching.max_wait);
        ContinuousScheduler::new(engine, batcher, AdmissionPolicy::Fifo)
    };

    // reference: the request runs uninterrupted on one replica
    let mut reference = mk();
    reference.submit(req);
    let whole = reference.drain();
    assert_eq!(whole.requests, 1);

    // crashed replica: partial work, then the router-style surrender. The
    // crash instant is scanned until it lands strictly mid-flight (a fixed
    // fraction could fall inside the long prefill iteration or past the
    // last boundary, which the other asserts cover trivially).
    let mut captured = None;
    for frac in [0.5, 0.65, 0.8, 0.9, 0.35, 0.95] {
        let mut crashed = mk();
        crashed.submit(req);
        let t_mid = req.arrival + frac * (whole.makespan.to_f64() - req.arrival);
        while crashed.now() < t_mid {
            if !crashed.tick() {
                break;
            }
        }
        let mut handed = Vec::new();
        crashed.fail_over(&mut handed);
        assert_eq!(handed.len(), 1, "exactly the one request surrenders");
        let (r0, saved) = handed.pop().unwrap();
        if let Some(s) = saved {
            let done = s.iterations_done() as usize;
            if done > 0 && done < iters {
                captured = Some((crashed.drain(), r0, s, t_mid));
                break;
            }
        }
    }
    let (partial, r0, saved, t_mid) =
        captured.expect("some crash instant must interrupt mid-flight");
    assert_eq!(partial.requests, 0, "handed-over work is not completed here");
    let done = saved.iterations_done() as usize;

    // the saved EAM is exactly the executed trace prefix
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let mut prefix = Eam::new(spec.n_layers, spec.experts_per_layer);
    for it in 0..done {
        for l in 0..spec.n_layers {
            for &(e, c) in &req.seq.routes[it][l] {
                prefix.record(l, e as usize, c);
            }
        }
    }
    assert_eq!(
        saved.eam(),
        &prefix,
        "handoff must carry the traced EAM of the executed prefix"
    );

    // survivor: resumes warm and finishes the request
    let mut survivor = mk();
    survivor.submit_failover(r0, Some(saved), t_mid);
    let rest = survivor.drain();
    assert_eq!(rest.requests, 1, "the survivor completes the request");
    assert_eq!(
        partial.tokens + rest.tokens,
        whole.tokens,
        "every token executes exactly once across the crash"
    );
    assert_eq!(
        partial.demands + rest.demands,
        whole.demands,
        "per-token expert demands must match the uninterrupted run"
    );
}

/// Deadline shedding is opt-in and scheduler-scoped: with it off, an
/// overloaded replay completes everything late; with it on, hopeless
/// SLO-carrying requests are shed at admission or aborted at iteration
/// boundaries and the goodput numerator only counts within-SLO tokens.
#[test]
fn shedding_is_deterministic_and_only_drops_slo_work() {
    let mut cfg = base_cfg(8.0);
    cfg.priority = AdmissionPolicy::Classes;
    cfg.workload.interactive_frac = 0.5;
    cfg.workload.interactive_slo = 0.2; // tight: overload makes some hopeless
    cfg.faults.shedding = true;
    let a = run_serve_with(&cfg, &Pool::serial()).expect("shedding serve");
    let b = run_serve_with(&cfg, &Pool::serial()).expect("shedding serve again");
    assert_bitwise(&a, &b, "shedding replay");
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.timed_out, b.timed_out);
    assert!(
        a.shed + a.timed_out > 0,
        "a 0.2s SLO at rps 8 must shed or abort something"
    );
    assert!(a.goodput_tokens <= a.tokens);
    // every non-SLO request still completes: only SLO work may be dropped
    let mut off = cfg.clone();
    off.faults.shedding = false;
    let full = run_serve_with(&off, &Pool::serial()).expect("no-shedding serve");
    assert_eq!(
        a.requests + a.shed + a.timed_out,
        full.requests,
        "shedding must account for every request"
    );
}

#[test]
fn classes_heap_pops_in_reference_rescan_order() {
    // The Indexed-Classes differential: the AdmitKey heap must admit in
    // exactly the order the retired O(backlog) rescan picked. The scan key
    // uses slack = deadline − now, so the reference is evaluated at a
    // *different, advancing* `now` for every pick — the heap (whose keys
    // were computed once at enqueue) must still agree, which is precisely
    // the time-invariance the O(log n) replacement rests on.
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), 11);
    let seq = w.gen_sequence();
    let mut rng = Rng::new(0xC1A55E5);
    let n = 200usize;
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            // deliberate collisions: few distinct arrivals and SLOs so the
            // deadline/arrival tie-breaks are exercised, plus no-SLO keys
            let arrival = (rng.below(8) as f64) * 0.5;
            let priority = match rng.below(3) {
                0 => Priority::Batch,
                1 => Priority::Normal,
                _ => Priority::Interactive,
            };
            let slo = match rng.below(3) {
                0 => None,
                1 => Some(1.0),
                _ => Some((rng.below(4) as f64 + 1.0) * 0.25),
            };
            let mut r = Request::new(i as u64, arrival, seq.clone());
            r.class = RequestClass { priority, slo };
            r
        })
        .collect();
    let refs: Vec<&Request> = reqs.iter().collect();

    // reference: repeated rescans over a shrinking waiting list, `now`
    // advancing between picks
    let mut waiting: VecDeque<u32> = (0..n as u32).collect();
    let mut scan_order = Vec::with_capacity(n);
    let mut now = 10.0;
    while let Some((from_preempted, pos)) = pick_candidate(&refs, &waiting, &[], now) {
        assert!(!from_preempted);
        scan_order.push(waiting.remove(pos).unwrap());
        now += 0.37; // admissions happen at later and later boundaries
    }

    // heap: keys computed once, popped straight
    let mut heap: BinaryHeap<_> = (0..n as u32).map(|i| admit_key(refs[i as usize], i)).collect();
    let mut heap_order = Vec::with_capacity(n);
    while let Some(k) = heap.pop() {
        heap_order.push(k.idx());
    }

    assert_eq!(
        heap_order, scan_order,
        "AdmitKey heap order must replay the rescan's admission order bitwise"
    );
}

#[test]
fn classes_admission_serves_the_same_work_as_fifo() {
    let mut cfg = base_cfg(6.0);
    cfg.workload.interactive_frac = 0.25;
    cfg.priority = AdmissionPolicy::Fifo;
    let fifo = run_serve_with(&cfg, &Pool::serial()).expect("fifo");
    cfg.priority = AdmissionPolicy::Classes;
    let cls = run_serve_with(&cfg, &Pool::serial()).expect("classes");
    // same request stream, same total work — only the ordering may differ
    assert_eq!(fifo.requests, cls.requests);
    assert_eq!(fifo.tokens, cls.tokens);
    assert_eq!(fifo.request_latency.len(), cls.request_latency.len());
    assert_eq!(fifo.ttft.len(), cls.ttft.len());
}

/// Per-request outcome rows must agree field-for-field (floats by bits):
/// this is what pins warm-failover *timing* — a request crashed off one
/// replica and resumed on another reports its latency/ttft from the same
/// instants under both router loops, not merely the same totals.
fn assert_stats_bitwise(a: &[RequestStat], b: &[RequestStat], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: stat count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{ctx}: id");
        assert_eq!(x.finished, y.finished, "{ctx}: req {} finished", x.id);
        assert_eq!(x.outcome, y.outcome, "{ctx}: req {} outcome", x.id);
        assert_eq!(
            x.preemptions, y.preemptions,
            "{ctx}: req {} preemptions",
            x.id
        );
        assert_eq!(
            x.arrival.to_bits(),
            y.arrival.to_bits(),
            "{ctx}: req {} arrival",
            x.id
        );
        assert_eq!(
            x.latency.to_bits(),
            y.latency.to_bits(),
            "{ctx}: req {} latency {} vs {}",
            x.id,
            x.latency,
            y.latency
        );
        assert_eq!(
            x.ttft.to_bits(),
            y.ttft.to_bits(),
            "{ctx}: req {} ttft {} vs {}",
            x.id,
            x.ttft,
            y.ttft
        );
    }
}

/// Replay `reqs` through a fresh router; `lockstep` picks the loop.
/// Returns the merged report plus each replica's per-request stat rows.
fn replay_router(
    cfg: &ServeConfig,
    pool: &Pool,
    reqs: &[Request],
    plan: Option<&FaultPlan>,
    chunk: Option<u32>,
    lockstep: bool,
) -> (ServeReport, Vec<Vec<RequestStat>>) {
    let engines = build_replica_engines_with(cfg, pool).expect("engines");
    let batcher = Batcher::new(cfg.batching.max_batch, cfg.batching.max_wait);
    let mut router = Router::new(engines, batcher, cfg.routing, cfg.priority);
    if let Some(c) = chunk {
        router = router.with_prefill_chunk(c);
    }
    if let Some(p) = plan {
        router = router.with_fault_plan(p);
    }
    router.submit_all(reqs);
    let report = if lockstep {
        router.drain_lockstep()
    } else {
        router.drain()
    };
    let stats = router.replicas().iter().map(|r| r.request_stats()).collect();
    (report, stats)
}

/// The PR 7 acceptance differential: the event-calendar router loop must
/// replay the retired lockstep polling loop **bitwise** — reports, per
/// token latencies, fault counters, and per-request stat rows — across
/// every scheduler kind ({continuous, chunked, classes}, each under a
/// different routing policy), with and without a fault plan that injects
/// link failures plus a replica-0 crash/recover window (so warm-failover
/// timing is compared too), at 1, 2 and 4 replicas. The lockstep loop
/// stays compiled (`Router::drain_lockstep`) precisely to serve as this
/// reference.
#[test]
fn calendar_router_replays_lockstep_bitwise_across_the_matrix() {
    let pool = Pool::serial();
    // (label, scheduler flavor as (routing, admission, chunk))
    let kinds: [(&str, RoutingPolicy, AdmissionPolicy, Option<u32>); 3] = [
        ("continuous", RoutingPolicy::RoundRobin, AdmissionPolicy::Fifo, None),
        ("chunked", RoutingPolicy::LeastLoaded, AdmissionPolicy::Fifo, Some(32)),
        ("classes", RoutingPolicy::TaskAffinity, AdmissionPolicy::Classes, None),
    ];
    for n in [1usize, 2, 4] {
        for &(label, routing, admission, chunk) in &kinds {
            for faulted in [false, true] {
                let mut cfg = base_cfg(2.0 * n as f64);
                cfg.workload.duration = 6.0;
                cfg.replicas = n;
                cfg.routing = routing;
                cfg.priority = admission;
                if admission == AdmissionPolicy::Classes {
                    cfg.workload.interactive_frac = 0.3;
                }
                let plan = faulted.then(|| {
                    let mut p = FaultPlan::new(cfg.seed ^ 0xFA57);
                    p.ssd_failure_p = 0.1;
                    p.gpu_failure_p = 0.05;
                    p.crashes.push(CrashWindow {
                        replica: 0,
                        crash: SimTime::from_f64(cfg.workload.duration * 0.3),
                        recover: SimTime::from_f64(cfg.workload.duration * 0.6),
                    });
                    p
                });
                let reqs = build_requests(&cfg).expect("requests");
                let ctx = format!("{label} n={n} faulted={faulted}");
                let (lock, lock_stats) =
                    replay_router(&cfg, &pool, &reqs, plan.as_ref(), chunk, true);
                let (cal, cal_stats) =
                    replay_router(&cfg, &pool, &reqs, plan.as_ref(), chunk, false);
                assert!(lock.requests > 0, "{ctx}: replay must serve");
                if faulted {
                    assert!(
                        lock.transfer_retries > 0,
                        "{ctx}: fault plan must exercise retries"
                    );
                }
                assert_bitwise(&cal, &lock, &ctx);
                for (k, (ls, cs)) in lock_stats.iter().zip(&cal_stats).enumerate() {
                    assert_stats_bitwise(cs, ls, &format!("{ctx} replica {k}"));
                }
            }
        }
    }
}

/// The DetMap-migration pin: with every decision-path container in
/// cache/prefetch/memory on the fixed-seed hasher (`util::detmap`), a full
/// 2-replica calendar replay must be a pure function of the config —
/// bitwise-identical reports and per-request stat rows across independent
/// runs, and still bitwise-equal to the retained lockstep reference. If a
/// future change sneaks iteration-order dependence into a decision path
/// (or swaps a container back to the entropy-seeded default hasher — which
/// moelint R1 also rejects statically), this is the dynamic half of that
/// ratchet.
#[test]
fn detmap_migration_replays_2replica_calendar_bitwise() {
    let pool = Pool::serial();
    let mut cfg = base_cfg(6.0);
    cfg.replicas = 2;
    cfg.routing = RoutingPolicy::TaskAffinity;
    let reqs = build_requests(&cfg).expect("requests");
    let (a, a_stats) = replay_router(&cfg, &pool, &reqs, None, None, false);
    let (b, b_stats) = replay_router(&cfg, &pool, &reqs, None, None, false);
    let (lock, lock_stats) = replay_router(&cfg, &pool, &reqs, None, None, true);
    assert!(a.requests > 0, "detmap pin: replay must serve");
    assert_bitwise(&a, &b, "detmap pin: calendar run 1 vs run 2");
    assert_bitwise(&a, &lock, "detmap pin: calendar vs lockstep");
    for (k, (xs, ys)) in a_stats.iter().zip(&b_stats).enumerate() {
        assert_stats_bitwise(xs, ys, &format!("detmap pin replica {k} (rerun)"));
    }
    for (k, (xs, ys)) in a_stats.iter().zip(&lock_stats).enumerate() {
        assert_stats_bitwise(xs, ys, &format!("detmap pin replica {k} (lockstep)"));
    }
}

#[test]
fn prefetch_cancellation_serves_identical_work() {
    // the dead-PCIe-traffic satellite is *quantified* by perf_router /
    // perf_scheduler (`cancel_*` rows in BENCH_scheduler.json); here the
    // tier-1 contract is that the cancellation path completes the same
    // work and accounts its traffic (the direct queue-drop mechanism is
    // pinned in the engine and memory-sim unit tests)
    let mut cfg = base_cfg(6.0);
    cfg.memory.gpu_gb = 3.0; // heavier offloading => more queued predictions
    cfg.cancel_retired_prefetch = false; // explicit: on is the default now
    let off = run_serve_with(&cfg, &Pool::serial()).expect("cancel off");
    cfg.cancel_retired_prefetch = true;
    let on = run_serve_with(&cfg, &Pool::serial()).expect("cancel on");
    assert_eq!(off.requests, on.requests);
    assert_eq!(off.tokens, on.tokens);
    assert!(on.prefetch_bytes > 0 && off.prefetch_bytes > 0);
}
