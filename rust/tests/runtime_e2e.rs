//! End-to-end runtime tests: real PJRT execution of the AOT artifacts.
//! Skipped gracefully when `artifacts/` hasn't been built (run
//! `make artifacts` first); CI always builds them.

use std::path::PathBuf;

use moe_infinity::engine::{real::tiny_spec, RealMoeEngine};
use moe_infinity::memory::TierConfig;
use moe_infinity::model::weights::TinyConfig;
use moe_infinity::prefetch::PredictorKind;

fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

fn engine(artifacts: &PathBuf, predictor: PredictorKind) -> RealMoeEngine {
    let cfg = TinyConfig::from_manifest(artifacts).unwrap();
    let spec = tiny_spec(&cfg);
    let mut tier = TierConfig::default_for(&spec, spec.total_bytes() / 3, spec.total_bytes());
    tier.gpu_capacity = (spec.total_experts() / 3).max(2);
    RealMoeEngine::new(artifacts, 11, 4, tier, predictor).unwrap()
}

#[test]
fn real_generation_is_deterministic_and_traced() {
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = engine(&dir, PredictorKind::ActivationAware { refine: true });
    let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3, 4], vec![300, 301, 302, 303]];
    let a = eng.generate(&prompts, 6).unwrap();
    // re-run on a fresh engine: identical tokens (deterministic weights +
    // greedy decode)
    let mut eng2 = engine(&dir, PredictorKind::ActivationAware { refine: true });
    let b = eng2.generate(&prompts, 6).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.tokens.len(), 2);
    assert_eq!(a.tokens[0].len(), 6);
    // EAMs traced: every generated token routed once per layer
    let cfg = eng.cfg();
    for eam in &a.eams {
        for l in 0..cfg.n_layers {
            assert!(eam.row_sum(l) > 0, "layer {l} untraced");
        }
    }
    assert!(a.demands > 0);
}

#[test]
fn real_router_exhibits_task_locality() {
    // Prompts from the same embedding cluster must route more similarly
    // than prompts from different clusters — the emergent property the
    // whole system depends on.
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = engine(&dir, PredictorKind::NoPrefetch);
    let cfg = eng.cfg().clone();
    let per = cfg.vocab / 4;
    let task_prompt = |task: usize, salt: usize| -> Vec<i32> {
        (0..6).map(|j| (task * per + (salt * 7 + j * 13) % per) as i32).collect()
    };
    let a1 = eng.generate(&[task_prompt(0, 1)], 8).unwrap().eams[0].clone();
    let a2 = eng.generate(&[task_prompt(0, 2)], 8).unwrap().eams[0].clone();
    let b = eng.generate(&[task_prompt(3, 1)], 8).unwrap().eams[0].clone();
    let d_same = a1.distance(&a2);
    let d_diff = a1.distance(&b);
    assert!(
        d_same < d_diff,
        "same-task routing distance {d_same} must beat cross-task {d_diff}"
    );
}

#[test]
fn real_prefetch_improves_recall_over_no_prefetch() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = TinyConfig::from_manifest(&dir).unwrap();
    let per = cfg.vocab / 4;
    let mk_set = |salt: usize| -> Vec<Vec<i32>> {
        (0..cfg.batch)
            .map(|i| {
                let task = (salt + i) % 4;
                (0..6).map(|j| (task * per + (salt * 11 + i * 7 + j * 3) % per) as i32).collect()
            })
            .collect()
    };
    let run = |kind: PredictorKind| -> f64 {
        let mut eng = engine(&dir, kind);
        let trace_sets: Vec<_> = (0..5).map(mk_set).collect();
        eng.build_eamc(&trace_sets, 6, 12).unwrap();
        let mut hits = 0;
        let mut demands = 0;
        for salt in 10..16 {
            let out = eng.generate(&mk_set(salt), 8).unwrap();
            hits += out.gpu_hits;
            demands += out.demands;
        }
        hits as f64 / demands as f64
    };
    let aware = run(PredictorKind::ActivationAware { refine: true });
    let none = run(PredictorKind::NoPrefetch);
    assert!(
        aware >= none,
        "real-path prefetch recall {aware} must be >= on-demand {none}"
    );
}

#[test]
fn real_generate_rejects_bad_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = engine(&dir, PredictorKind::NoPrefetch);
    let cfg = eng.cfg().clone();
    // unequal prompt lengths
    assert!(eng.generate(&[vec![1, 2], vec![1]], 4).is_err());
    // too many prompts
    let too_many: Vec<Vec<i32>> = (0..cfg.batch + 1).map(|_| vec![1, 2]).collect();
    assert!(eng.generate(&too_many, 4).is_err());
    // exceeding max_seq
    assert!(eng
        .generate(&[vec![1; cfg.max_seq]], 4)
        .is_err());
    // empty
    assert!(eng.generate(&[], 4).is_err());
}
