//! Determinism contract of the parallel offline layer (tentpole of the
//! "deterministic parallel execution" change): everything that runs on a
//! `util::pool::Pool` — Eq. 1 k-means, `Eamc::construct`, offline dataset
//! generation, and benchsuite `run_grid` — must produce **bitwise
//! identical** results at any thread count. These tests pin that contract
//! with pool sizes 1 / 2 / 8; `scripts/tier1.sh` additionally re-runs them
//! with `MOE_POOL_THREADS=1` so the env-derived default path is covered in
//! both serial and parallel modes.

use moe_infinity::benchsuite::{build_eamc_with, run_grid, run_serve_with};
use moe_infinity::config::{SchedulerKind, ServeConfig};
use moe_infinity::model::ModelSpec;
use moe_infinity::server::ServeReport;
use moe_infinity::trace::{kmeans_medoids_with, Eam, Eamc};
use moe_infinity::util::{Pool, Rng};
use moe_infinity::workload::{DatasetPreset, Workload};

const POOL_SIZES: [usize; 3] = [1, 2, 8];

fn trace_dataset(n: usize, seed: u64) -> Vec<Eam> {
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("mixed").unwrap();
    let mut w = Workload::new(&spec, ds, seed);
    w.gen_eam_dataset(n)
}

#[test]
fn kmeans_is_bitwise_identical_across_pool_sizes() {
    let ds = trace_dataset(60, 17);
    let base = kmeans_medoids_with(&ds, 10, 50, 99, &Pool::serial());
    assert!(!base.medoids.is_empty());
    for threads in POOL_SIZES {
        let r = kmeans_medoids_with(&ds, 10, 50, 99, &Pool::new(threads));
        assert_eq!(r.medoids, base.medoids, "medoids differ at {threads} threads");
        assert_eq!(
            r.assignment, base.assignment,
            "assignment differs at {threads} threads"
        );
        assert_eq!(
            r.iterations, base.iterations,
            "iteration count differs at {threads} threads"
        );
    }
}

#[test]
fn eamc_construct_is_bitwise_identical_across_pool_sizes() {
    let ds = trace_dataset(50, 23);
    let base = Eamc::construct_with(8, &ds, 7, &Pool::serial());
    for threads in POOL_SIZES {
        let c = Eamc::construct_with(8, &ds, 7, &Pool::new(threads));
        assert_eq!(c.len(), base.len(), "entry count differs at {threads} threads");
        assert_eq!(c.build_id(), base.build_id());
        for (i, (a, b)) in c.iter().zip(base.iter()).enumerate() {
            assert_eq!(a, b, "entry {i} differs at {threads} threads");
        }
        // the derived lookup structures must agree too
        assert_eq!(c.bytes(), base.bytes());
        assert_eq!(c.lookup_bytes(), base.lookup_bytes());
    }
}

#[test]
fn parallel_dataset_generation_is_thread_invariant() {
    let spec = ModelSpec::preset("switch-base-64").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let w = Workload::new(&spec, ds, 31);
    let base = w.gen_eam_dataset_par(&Pool::serial(), 24, 0xFEED);
    for threads in POOL_SIZES {
        let got = w.gen_eam_dataset_par(&Pool::new(threads), 24, 0xFEED);
        assert_eq!(got, base, "dataset differs at {threads} threads");
    }
}

#[test]
fn build_eamc_is_thread_invariant_end_to_end() {
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("mixed").unwrap();
    let base = build_eamc_with(&spec, &ds, 40, 10, 3, &Pool::serial());
    for threads in [2, 8] {
        let c = build_eamc_with(&spec, &ds, 40, 10, 3, &Pool::new(threads));
        assert_eq!(c.len(), base.len());
        for (a, b) in c.iter().zip(base.iter()) {
            assert_eq!(a, b);
        }
    }
}

fn small_grid() -> Vec<ServeConfig> {
    let mut grid = Vec::new();
    for (system, rps, sched) in [
        ("moe-infinity", 1.0, SchedulerKind::Static),
        ("moe-infinity", 3.0, SchedulerKind::Continuous),
        ("pytorch-um", 1.0, SchedulerKind::Static),
        ("pytorch-um", 3.0, SchedulerKind::Continuous),
    ] {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.system = system.into();
        cfg.scheduler = sched;
        cfg.workload.rps = rps;
        cfg.workload.duration = 6.0;
        cfg.eamc.trace_sequences = 25;
        cfg.eamc.capacity = 6;
        grid.push(cfg);
    }
    // a multi-replica router point: its replay must be exactly as pooled-
    // deterministic as the bare schedulers (per-replica EAMC construction
    // runs on the pool; the replay itself is virtual-time serial)
    let mut cfg = ServeConfig::default();
    cfg.model = "switch-base-32".into();
    cfg.scheduler = SchedulerKind::Continuous;
    cfg.replicas = 2;
    cfg.routing = moe_infinity::server::RoutingPolicy::TaskAffinity;
    cfg.workload.rps = 3.0;
    cfg.workload.duration = 6.0;
    cfg.eamc.trace_sequences = 25;
    cfg.eamc.capacity = 6;
    grid.push(cfg);
    // chunked-prefill points: a finite chunk (real splitting) and the
    // unlimited sentinel (the chunked == continuous differential in
    // rust/tests/scheduler.rs replays this grid's base config)
    for chunk in [32usize, 0] {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.scheduler = SchedulerKind::Chunked;
        cfg.prefill_chunk = chunk;
        cfg.workload.rps = 3.0;
        cfg.workload.duration = 6.0;
        cfg.eamc.trace_sequences = 25;
        cfg.eamc.capacity = 6;
        grid.push(cfg);
    }
    // a fault-injected point: transfer failures, a brownout, SLOs and
    // deadline shedding together — the degraded path must be exactly as
    // pooled-deterministic as the clean ones (every fault draw comes from
    // a seeded per-link stream, never from wall time)
    let mut cfg = ServeConfig::default();
    cfg.model = "switch-base-32".into();
    // 4GB GPU: offloading engages, so the injected transfer faults land
    cfg.memory.gpu_gb = 4.0;
    cfg.scheduler = SchedulerKind::Continuous;
    cfg.workload.rps = 3.0;
    cfg.workload.duration = 6.0;
    cfg.workload.interactive_frac = 0.3;
    cfg.workload.interactive_slo = 1.0;
    cfg.eamc.trace_sequences = 25;
    cfg.eamc.capacity = 6;
    cfg.faults.ssd_failure_p = 0.1;
    cfg.faults.gpu_failure_p = 0.1;
    cfg.faults.brownout = 0.5;
    cfg.faults.brownout_start = 1.0;
    cfg.faults.brownout_end = 4.0;
    cfg.faults.shedding = true;
    grid.push(cfg);
    grid
}

/// Bitwise report comparison: counters exactly, floats by bit pattern.
fn assert_reports_identical(a: &ServeReport, b: &ServeReport, ctx: &str) {
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.batches, b.batches, "{ctx}: batches");
    assert_eq!(a.shed, b.shed, "{ctx}: shed");
    assert_eq!(a.timed_out, b.timed_out, "{ctx}: timed out");
    assert_eq!(a.goodput_tokens, b.goodput_tokens, "{ctx}: goodput tokens");
    assert_eq!(a.demand_failures, b.demand_failures, "{ctx}: demand failures");
    assert_eq!(
        a.transfer_retries, b.transfer_retries,
        "{ctx}: transfer retries"
    );
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{ctx}: makespan {} vs {}",
        a.makespan,
        b.makespan
    );
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(a.token_latency.samples()),
        bits(b.token_latency.samples()),
        "{ctx}: token latencies"
    );
    assert_eq!(
        bits(a.request_latency.samples()),
        bits(b.request_latency.samples()),
        "{ctx}: request latencies"
    );
}

#[test]
fn run_grid_is_bitwise_identical_across_pool_sizes() {
    let grid = small_grid();
    // serial reference: each point through run_serve_with on a serial pool
    let serial = Pool::serial();
    let base: Vec<ServeReport> = grid
        .iter()
        .map(|cfg| run_serve_with(cfg, &serial).expect("serial serve"))
        .collect();
    for threads in POOL_SIZES {
        let got = run_grid(&grid, &Pool::new(threads));
        assert_eq!(got.len(), grid.len());
        for (i, (g, b)) in got.into_iter().zip(base.iter()).enumerate() {
            let g = g.expect("grid serve");
            assert_reports_identical(&g, b, &format!("point {i} at {threads} threads"));
        }
    }
}

/// The scheduler differential contract: with `max_batch = 1` continuous
/// batching degenerates to run-to-completion — admission instants equal the
/// static batcher's dispatch instants (`max(arrival, engine-free)`), every
/// step replays `run_batch`'s iteration body, and admission into an empty
/// session performs the same queue/batch-EAM reset `run_batch` does. The
/// two replays must therefore agree **bitwise**, both when requests are
/// sparse (engine idles between them) and when they queue behind each
/// other. This also pins the static path itself: `run_batch_into` is now
/// implemented on `BatchSession::step`, and any drift from the historical
/// loop would show up here and in the pooled-grid determinism checks.
#[test]
fn continuous_single_slot_matches_static_bitwise() {
    for rps in [0.3, 3.0] {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        // 4GB GPU: offloading (and therefore the whole prefetch/cache/queue
        // machinery) actually engages instead of everything staying warm
        cfg.memory.gpu_gb = 4.0;
        cfg.workload.rps = rps;
        cfg.workload.duration = 8.0;
        cfg.batching.max_batch = 1;
        cfg.eamc.trace_sequences = 25;
        cfg.eamc.capacity = 6;
        // this differential pins the *uncancelled* historical replay: the
        // static (deferred-feedback) path never cancels at retirement, so
        // with the now-default cancellation the continuous timeline would
        // legitimately diverge between a retirement and the next batch
        // boundary. Explicit false keeps the pin stable under any default.
        cfg.cancel_retired_prefetch = false;
        let pool = Pool::serial();
        let stat = run_serve_with(&cfg, &pool).expect("static serve");
        let mut c2 = cfg.clone();
        c2.scheduler = SchedulerKind::Continuous;
        let cont = run_serve_with(&c2, &pool).expect("continuous serve");
        assert_eq!(stat.requests, cont.requests, "rps={rps}: requests");
        assert_eq!(stat.tokens, cont.tokens, "rps={rps}: tokens");
        assert_eq!(
            stat.makespan.to_bits(),
            cont.makespan.to_bits(),
            "rps={rps}: makespan {} vs {}",
            stat.makespan,
            cont.makespan
        );
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(stat.token_latency.samples()),
            bits(cont.token_latency.samples()),
            "rps={rps}: per-token latencies must be bitwise identical"
        );
        assert_eq!(
            bits(stat.request_latency.samples()),
            bits(cont.request_latency.samples()),
            "rps={rps}: per-request latencies must be bitwise identical"
        );
    }
}

#[test]
fn run_grid_reports_per_point_errors_in_order() {
    let mut grid = small_grid();
    grid[1].model = "no-such-model".into();
    let out = run_grid(&grid, &Pool::new(4));
    assert!(out[0].is_ok());
    assert!(out[1].is_err(), "bad point must fail in place, not poison the grid");
    assert!(out[2].is_ok());
}

#[test]
fn stream_rngs_do_not_depend_on_draw_order() {
    // the property parallel generation rests on: stream i is the same
    // whether streams are created in order, in reverse, or interleaved
    let forward: Vec<u64> = (0u64..16).map(|i| Rng::for_stream(5, i).next_u64()).collect();
    let mut reverse: Vec<u64> = (0u64..16)
        .rev()
        .map(|i| Rng::for_stream(5, i).next_u64())
        .collect();
    reverse.reverse();
    assert_eq!(forward, reverse);
}
