//! Property-based tests on coordinator invariants (in-tree harness —
//! `util::proptest` — the image has no proptest crate). Each property runs
//! hundreds of randomized cases; failures report the case index + seed.

use moe_infinity::cache::{
    ActivationPolicy, CacheCtx, CacheTier, ExpertCache, GdsfPolicy, IndexedActivationPolicy,
    LfuDaPolicy, LruPolicy, Policy, SlruPolicy,
};
use moe_infinity::model::{ExpertKey, ModelSpec};
use moe_infinity::prefetch::{PrefetchQueue, MAX_PRIORITY};
use moe_infinity::server::Batcher;
use moe_infinity::trace::{kmeans_medoids, Eam, Eamc, EamcMatcher};
use moe_infinity::util::proptest::{forall, forall_res};
use moe_infinity::util::{DetSet, Rng};
use moe_infinity::workload::{DatasetPreset, Request, Workload};

fn random_eam(rng: &mut Rng, layers: usize, experts: usize) -> Eam {
    let mut m = Eam::new(layers, experts);
    let entries = 1 + rng.below(layers * 3);
    for _ in 0..entries {
        m.record(rng.below(layers), rng.below(experts), 1 + rng.below(9) as u32);
    }
    m
}

#[test]
fn prop_eam_distance_is_a_semimetric() {
    forall_res(
        0xD15,
        300,
        |rng| {
            let (l, e) = (2 + rng.below(6), 2 + rng.below(16));
            (random_eam(rng, l, e), random_eam(rng, l, e))
        },
        |(a, b)| {
            let dab = a.distance(b);
            let dba = b.distance(a);
            if (dab - dba).abs() > 1e-9 {
                return Err(format!("not symmetric: {dab} vs {dba}"));
            }
            if !(-1e-9..=2.0 + 1e-9).contains(&dab) {
                return Err(format!("out of range: {dab}"));
            }
            if a.distance(a) > 1e-9 {
                return Err("self-distance nonzero".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eam_distance_scale_invariant() {
    forall_res(
        0xD16,
        200,
        |rng| {
            let (l, e) = (2 + rng.below(4), 2 + rng.below(8));
            let a = random_eam(rng, l, e);
            let k = 2 + rng.below(9) as u32;
            // b = k * a
            let mut b = Eam::new(l, e);
            for li in 0..l {
                for ei in 0..e {
                    let c = a.count(li, ei);
                    if c > 0 {
                        b.record(li, ei, c * k);
                    }
                }
            }
            (a, b)
        },
        |(a, b)| {
            let d = a.distance(b);
            if d.abs() > 1e-6 {
                Err(format!("scaled copy at distance {d}"))
            } else {
                Ok(())
            }
        },
    );
}

#[test]
fn prop_queue_pops_in_nonincreasing_priority() {
    forall_res(
        0xABC,
        150,
        |rng| {
            let n = 1 + rng.below(200);
            (0..n)
                .map(|_| {
                    (
                        ExpertKey::new(rng.below(8), rng.below(64)),
                        if rng.below(20) == 0 { MAX_PRIORITY } else { rng.f64() },
                    )
                })
                .collect::<Vec<_>>()
        },
        |ops| {
            let mut q = PrefetchQueue::new();
            let mut live = std::collections::HashSet::new();
            for &(k, p) in ops {
                if q.submit(k, p) {
                    live.insert(k);
                }
            }
            if q.len() != live.len() {
                return Err(format!("live count {} vs {}", q.len(), live.len()));
            }
            let mut last = f64::INFINITY;
            let mut popped = std::collections::HashSet::new();
            while let Some((k, p)) = q.pop() {
                if p > last {
                    return Err(format!("priority went up: {p} after {last}"));
                }
                last = p;
                if !popped.insert(k) {
                    return Err(format!("duplicate pop of {k}"));
                }
            }
            if popped != live {
                return Err("popped set != submitted set".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_capacity_and_residency_invariants() {
    forall_res(
        0xCAC,
        150,
        |rng| {
            let cap = 1 + rng.below(40);
            let n_ops = 50 + rng.below(300);
            let ops: Vec<ExpertKey> = (0..n_ops)
                .map(|_| ExpertKey::new(rng.below(6), rng.below(32)))
                .collect();
            (cap, ops, rng.below(2) == 0)
        },
        |(cap, ops, use_lru)| {
            let policy: Box<dyn moe_infinity::cache::Policy> = if *use_lru {
                Box::new(LruPolicy::new())
            } else {
                Box::new(ActivationPolicy::new())
            };
            let mut cache = ExpertCache::new(*cap, policy);
            let eam = Eam::new(6, 32);
            let ctx = CacheCtx::new(&eam, 6);
            let mut resident = std::collections::HashSet::new();
            for &k in ops {
                if !cache.access(k) {
                    if let Some(ev) = cache.insert(k, &ctx) {
                        if !resident.remove(&ev) {
                            return Err(format!("evicted non-resident {ev}"));
                        }
                    }
                    resident.insert(k);
                }
                if cache.len() > *cap {
                    return Err(format!("over capacity: {} > {cap}", cache.len()));
                }
                if cache.len() != resident.len() {
                    return Err("shadow set diverged".into());
                }
                if !cache.contains(k) {
                    return Err(format!("just-inserted {k} missing"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kmeans_medoids_are_members_and_cover() {
    forall_res(
        0x63A,
        40,
        |rng| {
            let n = 4 + rng.below(30);
            let k = 1 + rng.below(6);
            let eams: Vec<Eam> = (0..n).map(|_| random_eam(rng, 3, 8)).collect();
            (eams, k)
        },
        |(eams, k)| {
            let r = kmeans_medoids(eams, *k, 20, 7);
            if r.medoids.is_empty() || r.medoids.len() > *k {
                return Err(format!("bad medoid count {}", r.medoids.len()));
            }
            for &m in &r.medoids {
                if m >= eams.len() {
                    return Err(format!("medoid index {m} out of bounds"));
                }
            }
            if r.assignment.len() != eams.len() {
                return Err("assignment size mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_invariants() {
    let spec = ModelSpec::preset("switch-base-8").unwrap();
    forall_res(
        0xBA7,
        60,
        |rng| {
            let mut w = Workload::new(
                &spec,
                DatasetPreset::by_name("translation").unwrap(),
                rng.next_u64(),
            );
            let n = 2 + rng.below(30);
            let mut t = 0.0;
            let reqs: Vec<Request> = (0..n)
                .map(|i| {
                    t += rng.exp(2.0);
                    Request::new(i as u64, t, w.gen_sequence())
                })
                .collect();
            let max_batch = 1 + rng.below(8);
            let max_wait = 0.05 + rng.f64();
            let engine_free = rng.f64() * 5.0;
            (reqs, max_batch, max_wait, engine_free)
        },
        |(reqs, max_batch, max_wait, engine_free)| {
            let b = Batcher::new(*max_batch, *max_wait);
            let refs: Vec<&Request> = reqs.iter().collect();
            let mut idx = 0;
            let mut last_dispatch = 0.0f64;
            while idx < reqs.len() {
                let (dispatch, end) = b.next_batch(&refs, idx, *engine_free);
                if end <= idx {
                    return Err("empty batch".into());
                }
                if end - idx > *max_batch {
                    return Err(format!("batch too large: {}", end - idx));
                }
                if dispatch < reqs[idx].arrival {
                    return Err("dispatched before first arrival".into());
                }
                if dispatch < *engine_free {
                    return Err("dispatched while engine busy".into());
                }
                for r in &reqs[idx..end] {
                    if r.arrival > dispatch {
                        return Err("batched a request from the future".into());
                    }
                }
                if dispatch + 1e-9 < last_dispatch {
                    return Err("dispatch time went backwards".into());
                }
                last_dispatch = dispatch;
                idx = end;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eamc_nearest_never_worse_than_random_member() {
    forall_res(
        0xEA3,
        40,
        |rng| {
            let n = 6 + rng.below(20);
            let ds: Vec<Eam> = (0..n).map(|_| random_eam(rng, 4, 8)).collect();
            let probe = random_eam(rng, 4, 8);
            let pick = rng.below(n);
            (ds, probe, pick)
        },
        |(ds, probe, pick)| {
            let eamc = moe_infinity::trace::Eamc::construct(ds.len(), ds, 3);
            let (_, best_d) = eamc.nearest(probe).unwrap();
            // the fast path's chosen distance must not exceed the naive
            // distance to any stored member (allowing top-K truncation
            // tolerance)
            let d_pick = probe.distance_partial(&ds[*pick % ds.len()]);
            if best_d > d_pick + 0.35 {
                return Err(format!("nearest {best_d} far worse than member {d_pick}"));
            }
            Ok(())
        },
    );
}

/// Differential: the incremental matcher must make the same nearest-entry
/// decision as `Eamc::nearest`'s full scan, which in turn must agree with
/// the naive `Eam::distance_partial` argmin (expert counts are kept ≤ the
/// sparse top-K so row truncation never perturbs the metric). Ties are
/// resolved by comparing the reference distances of the chosen entries.
#[test]
fn prop_incremental_matcher_agrees_with_full_scan_and_naive_argmin() {
    forall_res(
        0x3A7C,
        120,
        |rng| {
            let l = 2 + rng.below(4);
            let e = 2 + rng.below(7); // ≤ 8 = SPARSE_TOP_K: no truncation
            let n = 3 + rng.below(8);
            let ds: Vec<Eam> = (0..n).map(|_| random_eam(rng, l, e)).collect();
            let cap = 1 + rng.below(n);
            let trace: Vec<(usize, usize, u32)> = (0..10 + rng.below(30))
                .map(|_| (rng.below(l), rng.below(e), 1 + rng.below(9) as u32))
                .collect();
            (ds, cap, trace)
        },
        |(ds, cap, trace)| {
            let eamc = Eamc::construct(*cap, ds, 3);
            let mut matcher = EamcMatcher::new();
            matcher.attach(&eamc);
            let mut cur = Eam::new(ds[0].layers(), ds[0].experts());
            for &(l, e, c) in trace {
                matcher.record(eamc.index(), l, e, c);
                cur.record(l, e, c);
                let (fi, fd) = matcher.nearest().expect("non-empty");
                let (si, sd) = eamc.nearest_entry(&cur).expect("non-empty");
                // decision equality modulo exact ties, judged by the f64
                // reference metric
                let rf = eamc.distance_to_entry(&cur, fi);
                let rs = eamc.distance_to_entry(&cur, si);
                // the scan accumulates in f32, the matcher in f64 — on
                // near-ties they may legitimately pick different entries,
                // but only within f32 rounding of each other
                if (rf - rs).abs() > 1e-4 {
                    return Err(format!(
                        "matcher chose entry {fi} (ref d {rf}), scan chose {si} (ref d {rs})"
                    ));
                }
                if (fd - rf).abs() > 1e-4 {
                    return Err(format!("incremental distance drifted: {fd} vs ref {rf}"));
                }
                if (sd - rs).abs() > 1e-4 {
                    return Err(format!("scan distance drifted: {sd} vs ref {rs}"));
                }
                // agreement with the naive argmin over full-precision
                // partial distances (no truncation at these widths)
                let naive = eamc
                    .iter()
                    .map(|m| cur.distance_partial(m))
                    .fold(f64::INFINITY, f64::min);
                if (rf - naive).abs() > 1e-4 {
                    return Err(format!(
                        "chosen entry ref d {rf} vs naive argmin {naive}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Differential: the heap-indexed Alg. 2 policy must pick exactly the same
/// victim as the reference scan under arbitrary interleavings of EAM row
/// mutations, inserts, evictions and protection changes.
#[test]
fn prop_indexed_victim_matches_scan_policy() {
    forall_res(
        0x1DEA,
        120,
        |rng| {
            let l = 2 + rng.below(5);
            let e = 2 + rng.below(12);
            let ops: Vec<(u8, usize, usize, u32)> = (0..40 + rng.below(80))
                .map(|_| {
                    (
                        rng.below(4) as u8,
                        rng.below(64),
                        rng.below(64),
                        rng.below(16) as u32,
                    )
                })
                .collect();
            (l, e, ops)
        },
        |(l, e, ops)| {
            let (l, e) = (*l, *e);
            let mut eam = Eam::new(l, e);
            let mut scan = ActivationPolicy::new();
            let mut heap = IndexedActivationPolicy::new();
            let mut entries: Vec<ExpertKey> = Vec::new();
            let mut protected: DetSet<ExpertKey> = DetSet::default();
            for &(op, a, b, c) in ops {
                match op {
                    0 => eam.record(a % l, b % e, 1 + c % 7),
                    1 => {
                        let k = ExpertKey::new(a % l, b % e);
                        if !entries.contains(&k) {
                            entries.push(k);
                            scan.on_insert(k);
                            heap.on_insert(k);
                        }
                    }
                    2 => {
                        if entries.is_empty() {
                            continue;
                        }
                        let ctx = CacheCtx::new(&eam, l);
                        let excl = if !protected.is_empty() && protected.len() < entries.len()
                        {
                            Some(&protected)
                        } else {
                            None
                        };
                        let va = scan.victim(&entries, excl, &ctx);
                        let vb = heap.victim(&entries, excl, &ctx);
                        if va != vb {
                            return Err(format!(
                                "victims diverged: scan {va} vs heap {vb} \
                                 ({} entries, {} protected)",
                                entries.len(),
                                protected.len()
                            ));
                        }
                        scan.on_evict(va);
                        heap.on_evict(va);
                        protected.remove(&va);
                        entries.retain(|&k| k != va);
                    }
                    _ => {
                        if entries.is_empty() {
                            continue;
                        }
                        let k = entries[a % entries.len()];
                        if !protected.remove(&k) {
                            protected.insert(k);
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Differential at the cache level: two `ExpertCache`s — one on the scan
/// policy, one on the heap-indexed policy — replaying the same access /
/// insert / protect stream must evict identical keys at every step
/// (including through `choose_victim`'s protected-entry path).
#[test]
fn prop_cache_with_indexed_policy_matches_scan_cache() {
    forall_res(
        0xCAFE,
        100,
        |rng| {
            let cap = 2 + rng.below(12);
            let l = 2 + rng.below(4);
            let e = 4 + rng.below(12);
            let ops: Vec<(usize, usize, u32, bool, bool)> = (0..60 + rng.below(120))
                .map(|_| {
                    (
                        rng.below(64),
                        rng.below(64),
                        rng.below(5) as u32,
                        rng.below(4) == 0, // protect the touched key
                        rng.below(3) == 0, // mutate the EAM first
                    )
                })
                .collect();
            (cap, l, e, ops)
        },
        |(cap, l, e, ops)| {
            let (l, e) = (*l, *e);
            let mut eam = Eam::new(l, e);
            let mut a = ExpertCache::new(*cap, Box::new(ActivationPolicy::new()));
            let mut b = ExpertCache::new(*cap, Box::new(IndexedActivationPolicy::new()));
            for &(ka, kb, tokens, protect, mutate) in ops {
                if mutate {
                    eam.record(ka % l, kb % e, 1 + tokens);
                }
                let key = ExpertKey::new(ka % l, kb % e);
                let ctx = CacheCtx::new(&eam, l);
                let hit_a = a.access(key);
                let hit_b = b.access(key);
                if hit_a != hit_b {
                    return Err(format!("hit/miss diverged on {key}"));
                }
                if !hit_a {
                    let ev_a = a.insert(key, &ctx);
                    let ev_b = b.insert(key, &ctx);
                    if ev_a != ev_b {
                        return Err(format!(
                            "evictions diverged on {key}: scan {ev_a:?} vs heap {ev_b:?}"
                        ));
                    }
                } else if protect {
                    a.protect(key);
                    b.protect(key);
                }
            }
            if a.evictions() != b.evictions() || a.hits() != b.hits() {
                return Err("stats diverged".into());
            }
            Ok(())
        },
    );
}

/// Shared op-stream generator for the zoo-policy differentials: random
/// interleavings of accesses, inserts, victim picks and protection toggles
/// over a small key space.
fn policy_ops(rng: &mut moe_infinity::util::Rng) -> Vec<(u8, usize, usize, u32)> {
    (0..40 + rng.below(80))
        .map(|_| {
            (
                rng.below(4) as u8,
                rng.below(64),
                rng.below(64),
                rng.below(16) as u32,
            )
        })
        .collect()
}

/// Differential: the heap-backed LFU-DA policy must pick exactly the same
/// victim as a naive reference (scan over `K = freq-at-touch + age`, age
/// jumping to the victim's K on eviction) under arbitrary interleavings.
#[test]
fn prop_lfuda_heap_matches_naive_reference() {
    use std::collections::HashMap;

    #[derive(Default)]
    struct Naive {
        age: u64,
        freq: HashMap<ExpertKey, u64>,
        kval: HashMap<ExpertKey, u64>,
    }
    impl Naive {
        fn touch(&mut self, key: ExpertKey) {
            let f = self.freq.entry(key).or_insert(0);
            *f += 1;
            self.kval.insert(key, *f + self.age);
        }
        fn victim(&self, entries: &[ExpertKey], excl: Option<&DetSet<ExpertKey>>) -> ExpertKey {
            entries
                .iter()
                .copied()
                .filter(|e| !excl.is_some_and(|x| x.contains(e)))
                .min_by_key(|e| (self.kval.get(e).copied().unwrap_or(0), *e))
                .expect("guard keeps at least one entry unprotected")
        }
        fn evict(&mut self, key: ExpertKey) {
            self.age = self.kval.get(&key).copied().unwrap_or(0);
            self.freq.remove(&key);
            self.kval.remove(&key);
        }
    }

    let eam = Eam::new(4, 8);
    forall_res(0x1F0A, 120, policy_ops, |ops| {
        let mut heap = LfuDaPolicy::new();
        let mut naive = Naive::default();
        let mut entries: Vec<ExpertKey> = Vec::new();
        let mut protected: DetSet<ExpertKey> = DetSet::default();
        let ctx = CacheCtx::new(&eam, 4);
        for &(op, a, b, _c) in ops {
            match op {
                0 => {
                    if entries.is_empty() {
                        continue;
                    }
                    let k = entries[a % entries.len()];
                    heap.on_access(k);
                    naive.touch(k);
                }
                1 => {
                    let k = ExpertKey::new(a % 4, b % 12);
                    if !entries.contains(&k) {
                        entries.push(k);
                        heap.on_insert(k);
                        naive.touch(k);
                    }
                }
                2 => {
                    if entries.is_empty() {
                        continue;
                    }
                    let excl = if !protected.is_empty() && protected.len() < entries.len() {
                        Some(&protected)
                    } else {
                        None
                    };
                    let va = naive.victim(&entries, excl);
                    let vb = heap.victim(&entries, excl, &ctx);
                    if va != vb {
                        return Err(format!(
                            "victims diverged: naive {va} vs heap {vb} \
                             ({} entries, {} protected)",
                            entries.len(),
                            protected.len()
                        ));
                    }
                    naive.evict(va);
                    heap.on_evict(va);
                    protected.remove(&va);
                    entries.retain(|&k| k != va);
                }
                _ => {
                    if entries.is_empty() {
                        continue;
                    }
                    let k = entries[a % entries.len()];
                    if !protected.remove(&k) {
                        protected.insert(k);
                    }
                }
            }
        }
        Ok(())
    });
}

/// Differential: the two-heap SLRU policy must agree with a naive reference
/// (full scans over segment/tick maps, argmin-tick demotion) on every
/// victim pick and on segment membership after every op.
#[test]
fn prop_slru_heap_matches_naive_reference() {
    use std::collections::HashMap;

    struct Naive {
        clock: u64,
        seg: HashMap<ExpertKey, u8>,
        tick: HashMap<ExpertKey, u64>,
        budget: usize,
    }
    impl Naive {
        fn place(&mut self, key: ExpertKey, s: u8) {
            self.clock += 1;
            self.seg.insert(key, s);
            self.tick.insert(key, self.clock);
        }
        fn access(&mut self, key: ExpertKey) {
            match self.seg.get(&key).copied() {
                Some(1) => self.place(key, 1),
                Some(0) => {
                    self.place(key, 1);
                    let protected = self.seg.values().filter(|&&s| s == 1).count();
                    if protected > self.budget {
                        let lru = self
                            .seg
                            .iter()
                            .filter(|(_, &s)| s == 1)
                            .map(|(k, _)| (self.tick[k], *k))
                            .min()
                            .expect("protected segment non-empty")
                            .1;
                        self.place(lru, 0);
                    }
                }
                _ => {}
            }
        }
        fn victim(&self, entries: &[ExpertKey], excl: Option<&DetSet<ExpertKey>>) -> ExpertKey {
            entries
                .iter()
                .copied()
                .filter(|e| !excl.is_some_and(|x| x.contains(e)))
                .min_by_key(|e| {
                    (
                        self.seg.get(e).copied().unwrap_or(0),
                        self.tick.get(e).copied().unwrap_or(0),
                        *e,
                    )
                })
                .expect("guard keeps at least one entry unprotected")
        }
        fn evict(&mut self, key: ExpertKey) {
            self.seg.remove(&key);
            self.tick.remove(&key);
        }
    }

    let eam = Eam::new(4, 8);
    forall_res(
        0x51C0,
        120,
        |rng| (1 + rng.below(12), policy_ops(rng)),
        |(cap, ops)| {
            let cap = *cap;
            let mut heap = SlruPolicy::new(cap);
            let mut naive = Naive {
                clock: 0,
                seg: HashMap::new(),
                tick: HashMap::new(),
                // same formula as SlruPolicy::new
                budget: (cap * 4 / 5).clamp(1, cap.max(1)),
            };
            let mut entries: Vec<ExpertKey> = Vec::new();
            let mut protected: DetSet<ExpertKey> = DetSet::default();
            let ctx = CacheCtx::new(&eam, 4);
            for &(op, a, b, _c) in ops {
                match op {
                    0 => {
                        if entries.is_empty() {
                            continue;
                        }
                        let k = entries[a % entries.len()];
                        heap.on_access(k);
                        naive.access(k);
                    }
                    1 => {
                        let k = ExpertKey::new(a % 4, b % 12);
                        if !entries.contains(&k) {
                            entries.push(k);
                            heap.on_insert(k);
                            naive.place(k, 0);
                        }
                    }
                    2 => {
                        if entries.is_empty() {
                            continue;
                        }
                        let excl = if !protected.is_empty() && protected.len() < entries.len() {
                            Some(&protected)
                        } else {
                            None
                        };
                        let va = naive.victim(&entries, excl);
                        let vb = heap.victim(&entries, excl, &ctx);
                        if va != vb {
                            return Err(format!(
                                "victims diverged: naive {va} vs heap {vb} \
                                 ({} entries, {} protected)",
                                entries.len(),
                                protected.len()
                            ));
                        }
                        naive.evict(va);
                        heap.on_evict(va);
                        protected.remove(&va);
                        entries.retain(|&k| k != va);
                    }
                    _ => {
                        if entries.is_empty() {
                            continue;
                        }
                        let k = entries[a % entries.len()];
                        if !protected.remove(&k) {
                            protected.insert(k);
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Differential: the heap-backed GDSF policy (sentinel resolution + re-key
/// sweeps when the fetch cost changes between picks) must agree with a
/// naive reference scanning `H = age-at-touch + freq * fetch_cost` — the
/// per-pick cost varies, so the sweep path is exercised constantly.
#[test]
fn prop_gdsf_heap_matches_naive_reference_across_cost_changes() {
    use std::collections::HashMap;

    #[derive(Default)]
    struct Naive {
        age: f64,
        freq: HashMap<ExpertKey, u64>,
        snap: HashMap<ExpertKey, f64>,
    }
    impl Naive {
        fn touch(&mut self, key: ExpertKey) {
            *self.freq.entry(key).or_insert(0) += 1;
            self.snap.insert(key, self.age);
        }
        fn h(&self, e: &ExpertKey, fc: f64) -> f64 {
            self.snap.get(e).copied().unwrap_or(self.age)
                + self.freq.get(e).copied().unwrap_or(0) as f64 * fc
        }
        fn victim(
            &self,
            entries: &[ExpertKey],
            excl: Option<&DetSet<ExpertKey>>,
            fc: f64,
        ) -> (ExpertKey, f64) {
            let key = entries
                .iter()
                .copied()
                .filter(|e| !excl.is_some_and(|x| x.contains(e)))
                .min_by(|x, y| {
                    (self.h(x, fc), *x)
                        .partial_cmp(&(self.h(y, fc), *y))
                        .expect("H is finite")
                })
                .expect("guard keeps at least one entry unprotected");
            (key, self.h(&key, fc))
        }
        fn evict(&mut self, key: ExpertKey, h: f64) {
            self.age = h;
            self.freq.remove(&key);
            self.snap.remove(&key);
        }
    }

    let eam = Eam::new(4, 8);
    forall_res(0x6D5F, 120, policy_ops, |ops| {
        let mut heap = GdsfPolicy::new();
        let mut naive = Naive::default();
        let mut entries: Vec<ExpertKey> = Vec::new();
        let mut protected: DetSet<ExpertKey> = DetSet::default();
        for &(op, a, b, c) in ops {
            match op {
                0 => {
                    if entries.is_empty() {
                        continue;
                    }
                    let k = entries[a % entries.len()];
                    heap.on_access(k);
                    naive.touch(k);
                }
                1 => {
                    let k = ExpertKey::new(a % 4, b % 12);
                    if !entries.contains(&k) {
                        entries.push(k);
                        heap.on_insert(k);
                        naive.touch(k);
                    }
                }
                2 => {
                    if entries.is_empty() {
                        continue;
                    }
                    // vary the backing-fetch cost between picks to force
                    // whole-heap re-key sweeps
                    let fc = [0.5, 1.0, 2.0, 4.0][c as usize % 4];
                    let ctx = CacheCtx::new(&eam, 4).for_tier(CacheTier::Gpu, fc);
                    let excl = if !protected.is_empty() && protected.len() < entries.len() {
                        Some(&protected)
                    } else {
                        None
                    };
                    let (va, hv) = naive.victim(&entries, excl, fc);
                    let vb = heap.victim(&entries, excl, &ctx);
                    if va != vb {
                        return Err(format!(
                            "victims diverged at cost {fc}: naive {va} vs heap {vb} \
                             ({} entries, {} protected)",
                            entries.len(),
                            protected.len()
                        ));
                    }
                    naive.evict(va, hv);
                    heap.on_evict(va);
                    protected.remove(&va);
                    entries.retain(|&k| k != va);
                }
                _ => {
                    if entries.is_empty() {
                        continue;
                    }
                    let k = entries[a % entries.len()];
                    if !protected.remove(&k) {
                        protected.insert(k);
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_prefill_chunk_split_conserves_per_layer_rows() {
    // The chunked-prefill conservation law: for ANY chunk size, walking a
    // real prefill row chunk by chunk through the proportional split
    // accumulates (a) exactly the stored count for every expert cell and
    // therefore (b) exactly `prompt_len` tokens per layer — so a chunked
    // replay's per-sequence EAM is identical to the unchunked one no
    // matter how the prompt was sliced.
    use moe_infinity::engine::prefill_chunk_tokens;
    let spec = ModelSpec::preset("switch-base-16").unwrap();
    forall_res(
        0xC4A2,
        40,
        |rng| (rng.next_u64(), 1 + rng.below(24) as u32),
        |&(seed, chunk)| {
            let mut w = Workload::new(
                &spec,
                DatasetPreset::by_name("mixed").unwrap(),
                seed,
            );
            let seq = w.gen_sequence();
            let prompt = seq.prompt_len as u32;
            for (l, row) in seq.routes[0].iter().enumerate() {
                let mut layer_total = 0u32;
                for &(e, c) in row {
                    let mut acc = 0u32;
                    let mut done = 0u32;
                    while done < prompt {
                        let k = chunk.min(prompt - done);
                        acc += prefill_chunk_tokens(c, done, k, prompt);
                        done += k;
                    }
                    if acc != c {
                        return Err(format!(
                            "layer {l} expert {e}: chunked sum {acc} != stored {c} \
                             (chunk {chunk}, prompt {prompt})"
                        ));
                    }
                    layer_total += acc;
                }
                if layer_total != prompt {
                    return Err(format!(
                        "layer {l}: chunked row total {layer_total} != prompt {prompt}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_workload_eam_invariant() {
    let spec = ModelSpec::preset("switch-base-16").unwrap();
    forall_res(
        0xF00,
        30,
        |rng| rng.next_u64(),
        |&seed| {
            let mut w = Workload::new(
                &spec,
                DatasetPreset::by_name("flan").unwrap(),
                seed,
            );
            let seq = w.gen_sequence();
            let eam = seq.to_eam(spec.n_layers, spec.experts_per_layer);
            let n = seq.total_tokens() as u32;
            for l in 0..spec.n_layers {
                if eam.row_sum(l) != n {
                    return Err(format!("layer {l}: {} != {n}", eam.row_sum(l)));
                }
            }
            Ok(())
        },
    );
}
