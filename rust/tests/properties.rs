//! Property-based tests on coordinator invariants (in-tree harness —
//! `util::proptest` — the image has no proptest crate). Each property runs
//! hundreds of randomized cases; failures report the case index + seed.

use moe_infinity::cache::{ActivationPolicy, CacheCtx, ExpertCache, LruPolicy};
use moe_infinity::model::{ExpertKey, ModelSpec};
use moe_infinity::prefetch::{PrefetchQueue, MAX_PRIORITY};
use moe_infinity::server::Batcher;
use moe_infinity::trace::{kmeans_medoids, Eam};
use moe_infinity::util::proptest::{forall, forall_res};
use moe_infinity::util::Rng;
use moe_infinity::workload::{DatasetPreset, Request, Workload};

fn random_eam(rng: &mut Rng, layers: usize, experts: usize) -> Eam {
    let mut m = Eam::new(layers, experts);
    let entries = 1 + rng.below(layers * 3);
    for _ in 0..entries {
        m.record(rng.below(layers), rng.below(experts), 1 + rng.below(9) as u32);
    }
    m
}

#[test]
fn prop_eam_distance_is_a_semimetric() {
    forall_res(
        0xD15,
        300,
        |rng| {
            let (l, e) = (2 + rng.below(6), 2 + rng.below(16));
            (random_eam(rng, l, e), random_eam(rng, l, e))
        },
        |(a, b)| {
            let dab = a.distance(b);
            let dba = b.distance(a);
            if (dab - dba).abs() > 1e-9 {
                return Err(format!("not symmetric: {dab} vs {dba}"));
            }
            if !(-1e-9..=2.0 + 1e-9).contains(&dab) {
                return Err(format!("out of range: {dab}"));
            }
            if a.distance(a) > 1e-9 {
                return Err("self-distance nonzero".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eam_distance_scale_invariant() {
    forall_res(
        0xD16,
        200,
        |rng| {
            let (l, e) = (2 + rng.below(4), 2 + rng.below(8));
            let a = random_eam(rng, l, e);
            let k = 2 + rng.below(9) as u32;
            // b = k * a
            let mut b = Eam::new(l, e);
            for li in 0..l {
                for ei in 0..e {
                    let c = a.count(li, ei);
                    if c > 0 {
                        b.record(li, ei, c * k);
                    }
                }
            }
            (a, b)
        },
        |(a, b)| {
            let d = a.distance(b);
            if d.abs() > 1e-6 {
                Err(format!("scaled copy at distance {d}"))
            } else {
                Ok(())
            }
        },
    );
}

#[test]
fn prop_queue_pops_in_nonincreasing_priority() {
    forall_res(
        0xABC,
        150,
        |rng| {
            let n = 1 + rng.below(200);
            (0..n)
                .map(|_| {
                    (
                        ExpertKey::new(rng.below(8), rng.below(64)),
                        if rng.below(20) == 0 { MAX_PRIORITY } else { rng.f64() },
                    )
                })
                .collect::<Vec<_>>()
        },
        |ops| {
            let mut q = PrefetchQueue::new();
            let mut live = std::collections::HashSet::new();
            for &(k, p) in ops {
                if q.submit(k, p) {
                    live.insert(k);
                }
            }
            if q.len() != live.len() {
                return Err(format!("live count {} vs {}", q.len(), live.len()));
            }
            let mut last = f64::INFINITY;
            let mut popped = std::collections::HashSet::new();
            while let Some((k, p)) = q.pop() {
                if p > last {
                    return Err(format!("priority went up: {p} after {last}"));
                }
                last = p;
                if !popped.insert(k) {
                    return Err(format!("duplicate pop of {k}"));
                }
            }
            if popped != live {
                return Err("popped set != submitted set".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_capacity_and_residency_invariants() {
    forall_res(
        0xCAC,
        150,
        |rng| {
            let cap = 1 + rng.below(40);
            let n_ops = 50 + rng.below(300);
            let ops: Vec<ExpertKey> = (0..n_ops)
                .map(|_| ExpertKey::new(rng.below(6), rng.below(32)))
                .collect();
            (cap, ops, rng.below(2) == 0)
        },
        |(cap, ops, use_lru)| {
            let policy: Box<dyn moe_infinity::cache::Policy> = if *use_lru {
                Box::new(LruPolicy::new())
            } else {
                Box::new(ActivationPolicy::new())
            };
            let mut cache = ExpertCache::new(*cap, policy);
            let eam = Eam::new(6, 32);
            let ctx = CacheCtx {
                cur_eam: &eam,
                n_layers: 6,
            };
            let mut resident = std::collections::HashSet::new();
            for &k in ops {
                if !cache.access(k) {
                    if let Some(ev) = cache.insert(k, &ctx) {
                        if !resident.remove(&ev) {
                            return Err(format!("evicted non-resident {ev}"));
                        }
                    }
                    resident.insert(k);
                }
                if cache.len() > *cap {
                    return Err(format!("over capacity: {} > {cap}", cache.len()));
                }
                if cache.len() != resident.len() {
                    return Err("shadow set diverged".into());
                }
                if !cache.contains(k) {
                    return Err(format!("just-inserted {k} missing"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kmeans_medoids_are_members_and_cover() {
    forall_res(
        0x63A,
        40,
        |rng| {
            let n = 4 + rng.below(30);
            let k = 1 + rng.below(6);
            let eams: Vec<Eam> = (0..n).map(|_| random_eam(rng, 3, 8)).collect();
            (eams, k)
        },
        |(eams, k)| {
            let r = kmeans_medoids(eams, *k, 20, 7);
            if r.medoids.is_empty() || r.medoids.len() > *k {
                return Err(format!("bad medoid count {}", r.medoids.len()));
            }
            for &m in &r.medoids {
                if m >= eams.len() {
                    return Err(format!("medoid index {m} out of bounds"));
                }
            }
            if r.assignment.len() != eams.len() {
                return Err("assignment size mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_invariants() {
    let spec = ModelSpec::preset("switch-base-8").unwrap();
    forall_res(
        0xBA7,
        60,
        |rng| {
            let mut w = Workload::new(
                &spec,
                DatasetPreset::by_name("translation").unwrap(),
                rng.next_u64(),
            );
            let n = 2 + rng.below(30);
            let mut t = 0.0;
            let reqs: Vec<Request> = (0..n)
                .map(|i| {
                    t += rng.exp(2.0);
                    Request {
                        id: i as u64,
                        arrival: t,
                        seq: w.gen_sequence(),
                    }
                })
                .collect();
            let max_batch = 1 + rng.below(8);
            let max_wait = 0.05 + rng.f64();
            let engine_free = rng.f64() * 5.0;
            (reqs, max_batch, max_wait, engine_free)
        },
        |(reqs, max_batch, max_wait, engine_free)| {
            let b = Batcher::new(*max_batch, *max_wait);
            let mut idx = 0;
            let mut last_dispatch = 0.0f64;
            while idx < reqs.len() {
                let (dispatch, end) = b.next_batch(reqs, idx, *engine_free);
                if end <= idx {
                    return Err("empty batch".into());
                }
                if end - idx > *max_batch {
                    return Err(format!("batch too large: {}", end - idx));
                }
                if dispatch < reqs[idx].arrival {
                    return Err("dispatched before first arrival".into());
                }
                if dispatch < *engine_free {
                    return Err("dispatched while engine busy".into());
                }
                for r in &reqs[idx..end] {
                    if r.arrival > dispatch {
                        return Err("batched a request from the future".into());
                    }
                }
                if dispatch + 1e-9 < last_dispatch {
                    return Err("dispatch time went backwards".into());
                }
                last_dispatch = dispatch;
                idx = end;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eamc_nearest_never_worse_than_random_member() {
    forall_res(
        0xEA3,
        40,
        |rng| {
            let n = 6 + rng.below(20);
            let ds: Vec<Eam> = (0..n).map(|_| random_eam(rng, 4, 8)).collect();
            let probe = random_eam(rng, 4, 8);
            let pick = rng.below(n);
            (ds, probe, pick)
        },
        |(ds, probe, pick)| {
            let eamc = moe_infinity::trace::Eamc::construct(ds.len(), ds, 3);
            let (_, best_d) = eamc.nearest(probe).unwrap();
            // the fast path's chosen distance must not exceed the naive
            // distance to any stored member (allowing top-K truncation
            // tolerance)
            let d_pick = probe.distance_partial(&ds[*pick % ds.len()]);
            if best_d > d_pick + 0.35 {
                return Err(format!("nearest {best_d} far worse than member {d_pick}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_workload_eam_invariant() {
    let spec = ModelSpec::preset("switch-base-16").unwrap();
    forall_res(
        0xF00,
        30,
        |rng| rng.next_u64(),
        |&seed| {
            let mut w = Workload::new(
                &spec,
                DatasetPreset::by_name("flan").unwrap(),
                seed,
            );
            let seq = w.gen_sequence();
            let eam = seq.to_eam(spec.n_layers, spec.experts_per_layer);
            let n = seq.total_tokens() as u32;
            for l in 0..spec.n_layers {
                if eam.row_sum(l) != n {
                    return Err(format!("layer {l}: {} != {n}", eam.row_sum(l)));
                }
            }
            Ok(())
        },
    );
}
