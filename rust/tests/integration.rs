//! Integration tests across modules: workload → trace → prefetch → cache →
//! memory → engine → server, plus the whole-system baseline comparisons the
//! paper's evaluation depends on.

use moe_infinity::benchsuite::{build_eamc, build_requests, run_serve, tier_with};
use moe_infinity::cache::CacheKind;
use moe_infinity::config::ServeConfig;
use moe_infinity::engine::{ComputeModel, EngineConfig, SimEngine};
use moe_infinity::model::ModelSpec;
use moe_infinity::prefetch::PredictorKind;
use moe_infinity::server::{Batcher, Scheduler, StaticScheduler};
use moe_infinity::workload::{DatasetPreset, Workload};

fn small_cfg(system: &str) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.model = "switch-base-32".into();
    cfg.system = system.into();
    // 4GB GPU: switch-base-32 is 7.3GB of experts, so offloading actually
    // engages (24GB would hold the whole model and all systems would tie).
    cfg.memory.gpu_gb = 4.0;
    cfg.workload.rps = 1.0;
    cfg.workload.duration = 8.0;
    cfg.eamc.trace_sequences = 60;
    cfg.eamc.capacity = 20;
    cfg
}

#[test]
fn full_serving_pipeline_all_systems() {
    for system in moe_infinity::baselines::SYSTEMS {
        let mut cfg = small_cfg(system);
        if system.starts_with("zero") {
            cfg.workload.duration = 3.0; // fetch-all is expensive to simulate
        }
        let report = run_serve(&cfg).unwrap_or_else(|e| panic!("{system}: {e}"));
        assert!(report.requests > 0, "{system} served nothing");
        assert!(report.token_throughput() > 0.0);
        assert!(report.makespan > 0.0);
    }
}

#[test]
fn continuous_scheduler_serves_all_fast_systems() {
    // iteration-level scheduling must compose with every policy bundle the
    // engine supports (incl. the fetch-all ZeRO semantics); keep the slow
    // fetch-all systems on a short replay like the static test does
    use moe_infinity::config::SchedulerKind;
    for system in ["moe-infinity", "pytorch-um"] {
        let mut cfg = small_cfg(system);
        cfg.scheduler = SchedulerKind::Continuous;
        let report = run_serve(&cfg).unwrap_or_else(|e| panic!("{system}: {e}"));
        assert!(report.requests > 0, "{system} served nothing");
        assert_eq!(
            report.request_latency.len() as u64,
            report.requests,
            "{system}: every request must record a completion latency"
        );
        assert!(report.token_throughput() > 0.0);
    }
    let mut cfg = small_cfg("zero-offload");
    cfg.scheduler = SchedulerKind::Continuous;
    cfg.workload.duration = 3.0;
    let report = run_serve(&cfg).unwrap();
    assert!(report.requests > 0, "fetch-all semantics work under continuous");
}

#[test]
fn moe_infinity_beats_baselines_end_to_end() {
    // The paper's headline ordering at matched workloads (Fig. 4).
    let mut means = std::collections::HashMap::new();
    for system in ["moe-infinity", "pytorch-um", "zero-offload"] {
        let mut cfg = small_cfg(system);
        cfg.workload.duration = 6.0;
        cfg.workload.rps = 0.5;
        let mut report = run_serve(&cfg).unwrap();
        means.insert(system, report.token_latency.mean() + report.token_latency.p99());
    }
    assert!(
        means["moe-infinity"] < means["pytorch-um"],
        "moe-infinity {:?} must beat pytorch-um {:?}",
        means["moe-infinity"],
        means["pytorch-um"]
    );
    assert!(
        means["pytorch-um"] < means["zero-offload"],
        "pytorch-um {:?} must beat zero-offload {:?}",
        means["pytorch-um"],
        means["zero-offload"]
    );
}

#[test]
fn deterministic_replay() {
    let cfg = small_cfg("moe-infinity");
    let mut a = run_serve(&cfg).unwrap();
    let mut b = run_serve(&cfg).unwrap();
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.tokens, b.tokens);
    assert!((a.token_latency.mean() - b.token_latency.mean()).abs() < 1e-12);
    assert!((a.token_latency.p99() - b.token_latency.p99()).abs() < 1e-12);
}

#[test]
fn requests_preserve_arrival_order_and_window() {
    let cfg = small_cfg("moe-infinity");
    let reqs = build_requests(&cfg).unwrap();
    assert!(!reqs.is_empty());
    for w in reqs.windows(2) {
        assert!(w[1].arrival >= w[0].arrival);
    }
    assert!(reqs.last().unwrap().arrival < cfg.workload.duration);
}

#[test]
fn serve_with_engine_components_composes() {
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let eamc = build_eamc(&spec, &ds, 60, 12, 3);
    let engine = SimEngine::new(
        spec.clone(),
        tier_with(&spec, 128, 256, 6.0, 32.0, CacheKind::Activation),
        eamc,
        ComputeModel::a5000(),
        EngineConfig::default(),
    );
    let mut w = Workload::new(&spec, ds, 3);
    let reqs: Vec<_> = (0..6)
        .map(|i| moe_infinity::workload::Request::new(i, i as f64 * 0.4, w.gen_sequence()))
        .collect();
    let mut sched = StaticScheduler::new(engine, Batcher::new(4, 0.3));
    sched.submit_all(&reqs);
    let report = sched.drain();
    assert_eq!(report.requests, 6);
    // memory stats flowed through the stack and into the report
    assert!(sched.engine().sim().stats().demand_total() > 0);
    assert!(report.demands > 0);
}

#[test]
fn cache_policy_ordering_holds_in_engine() {
    // Alg. 2 must beat LRU in serving recall on a locality-heavy workload.
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let recall_with = |kind: CacheKind| -> f64 {
        let eamc = build_eamc(&spec, &ds, 60, 12, 5);
        let mut engine = SimEngine::new(
            spec.clone(),
            tier_with(&spec, 96, 200, 6.0, 32.0, kind),
            eamc,
            ComputeModel::a5000(),
            EngineConfig {
                predictor: PredictorKind::NoPrefetch, // isolate the cache
                ..Default::default()
            },
        );
        let mut w = Workload::new(&spec, ds.clone(), 5);
        let mut hits = 0;
        let mut demands = 0;
        for _ in 0..12 {
            let seq = w.gen_sequence();
            let r = engine.run_batch(&[seq], engine.now());
            hits += r.gpu_hits;
            demands += r.demands;
        }
        hits as f64 / demands as f64
    };
    let act = recall_with(CacheKind::Activation);
    let lfu = recall_with(CacheKind::Lfu);
    assert!(
        act > lfu,
        "activation cache {act} must beat LFU {lfu} (paper §8.4)"
    );
}

#[test]
fn config_toml_round_trip_through_files() {
    let cfg = small_cfg("moe-infinity");
    let path = std::env::temp_dir().join("moe_inf_test_cfg.toml");
    std::fs::write(&path, cfg.to_toml()).unwrap();
    let back = ServeConfig::from_toml_file(&path).unwrap();
    assert_eq!(cfg, back);
    std::fs::remove_file(&path).ok();
}

#[test]
fn eamc_drift_reconstruction_recovers() {
    // §4.3 end to end: MMLU-built EAMC, BIGBench stream, rebuild fires.
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let mmlu = DatasetPreset::by_name("mmlu").unwrap();
    let bb = DatasetPreset::by_name("bigbench").unwrap();
    let mut eamc = build_eamc(&spec, &mmlu, 80, 30, 7);
    eamc.set_rebuild_threshold(8);
    let mut engine = SimEngine::new(
        spec.clone(),
        // small GPU cache so drift-induced misses are visible
        tier_with(&spec, 48, 256, 6.0, 32.0, CacheKind::Activation),
        eamc,
        ComputeModel::a5000(),
        EngineConfig {
            well_predicted_recall: 0.8,
            ..Default::default()
        },
    );
    let mut w = Workload::new(&spec, bb, 7);
    for _ in 0..40 {
        let seq = w.gen_sequence();
        engine.run_batch(&[seq], engine.now());
        if engine.eamc().stats().builds > 1 {
            break;
        }
    }
    assert!(
        engine.eamc().stats().builds > 1,
        "online reconstruction should fire under drift"
    );
}
