//! Allocation-regression guard for the serving hot path.
//!
//! Two contracts: after warm-up, (1) a steady-state `run_batch` decode
//! pass and (2) a continuous-batching admit → step… → retire window on a
//! live `BatchSession` each perform **zero** heap allocations — the
//! per-layer union, the per-slot EAMs and matcher handles, the prediction
//! buffer, the prefetch queues, the eviction heap, the step-event buffers
//! and the EAMC recent-window ring all recycle engine-owned storage. This
//! test installs the counting global allocator from `util::alloc` (only
//! this test binary owns the global allocator) and asserts the count is
//! exactly zero for both warmed paths.

use moe_infinity::cache::CacheKind;
use moe_infinity::engine::{
    BatchResult, ComputeModel, EngineConfig, FeedbackMode, SimEngine, StepResult,
};
use moe_infinity::faults::{Brownout, FaultLink, FaultPlan};
use moe_infinity::memory::{Link, Tier, TierConfig};
use moe_infinity::model::ModelSpec;
use moe_infinity::server::{AdmissionPolicy, Batcher, Router, RoutingPolicy, Scheduler};
use moe_infinity::trace::Eamc;
use moe_infinity::util::alloc::{measure, CountingAlloc};
use moe_infinity::util::units::SimTime;
use moe_infinity::workload::{DatasetPreset, Request, SequenceActivation, Workload};

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc::new();

fn tier(spec: &ModelSpec, gpu: usize) -> TierConfig {
    TierConfig {
        gpu_capacity: gpu,
        dram_capacity: spec.total_experts() / 2,
        backing: Tier::Ssd,
        ssd_to_dram: Link::new(6.0, 50e-6),
        dram_to_gpu: Link::new(32.0, 10e-6),
        n_gpus: 1,
        demand_extra_latency: SimTime::ZERO,
        demand_bw_factor: 1.0,
        gpu_policy: CacheKind::Activation,
        dram_policy: CacheKind::Activation,
        oracle_trace: Vec::new(),
        activation_terms: (true, true),
        prefetch_gpu_budget: 0.5,
    }
}

#[test]
fn steady_state_decode_batch_is_allocation_free() {
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let mut w = Workload::new(&spec, ds, 5);
    let eam_ds = w.gen_eam_dataset(30);
    let mut eamc = Eamc::construct(8, &eam_ds, 11);
    // steady state = no online reconstruction; shrink the recent-window
    // ring so warm-up fills it and later observes recycle slots in place
    eamc.set_rebuild_threshold(usize::MAX);
    eamc.set_recent_capacity(2);

    let mut eng = SimEngine::new(
        spec.clone(),
        tier(&spec, 64),
        eamc,
        ComputeModel::a5000(),
        EngineConfig::default(),
    );
    let seqs: Vec<_> = (0..2).map(|_| w.gen_sequence()).collect();
    let mut result = BatchResult::default();

    // warm every pool, map, heap and result buffer to its high-water mark
    for _ in 0..5 {
        let start = eng.now();
        eng.run_batch_into(&seqs, start, &mut result);
    }

    let start = eng.now();
    let (_, stats) = measure(|| {
        eng.run_batch_into(&seqs, start, &mut result);
    });
    assert_eq!(
        stats.total(),
        0,
        "steady-state run_batch must not allocate, but did: {stats:?}"
    );
    // sanity: the measured batch really did work
    assert!(!result.token_latencies.is_empty());
    assert!(result.demands > 0);
}

#[test]
fn steady_state_continuous_batching_is_allocation_free() {
    // The continuous-batching contract: once every pooled buffer (slot
    // state, matcher handles, union scratch, prefetch queues, step-event
    // buffers, the EAMC recent ring) has reached its high-water mark, a
    // full admit → step… → retire window on a live session performs zero
    // heap allocations — admission recycles freed slots, retirement feeds
    // the EAMC through the in-place ring and subtracts the finished EAM
    // from the batch EAM without allocating.
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let mut w = Workload::new(&spec, ds, 7);
    let eam_ds = w.gen_eam_dataset(30);
    let mut eamc = Eamc::construct(8, &eam_ds, 11);
    // steady state = no online reconstruction; small recent ring so warm-up
    // fills it and later observes recycle slots in place
    eamc.set_rebuild_threshold(usize::MAX);
    eamc.set_recent_capacity(2);

    let mut eng = SimEngine::new(
        spec.clone(),
        tier(&spec, 64),
        eamc,
        ComputeModel::a5000(),
        EngineConfig::default(),
    );
    let a = w.gen_sequence();
    let b = w.gen_sequence();
    let mut step = StepResult::default();
    let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);

    // one admission/retirement cycle over the fixed sequence pair
    fn cycle<'s>(
        session: &mut moe_infinity::engine::BatchSession<'_>,
        step: &mut StepResult,
        a: &'s SequenceActivation,
        b: &'s SequenceActivation,
        base: u64,
    ) {
        session.admit(base, a);
        session.admit(base + 1, b);
        let mut active = 2usize;
        while active > 0 {
            assert!(session.step(|id: u64| if id % 2 == 0 { a } else { b }, step));
            active -= step.finished.len();
        }
    }

    // warm every pool, queue, ring and slot buffer to its high-water mark
    for i in 0..5u64 {
        cycle(&mut session, &mut step, &a, &b, 2 * i);
    }

    let (_, stats) = measure(|| {
        cycle(&mut session, &mut step, &a, &b, 10);
    });
    assert_eq!(
        stats.total(),
        0,
        "a warmed continuous-batching window (admit + steps + retire) must \
         not allocate, but did: {stats:?}"
    );
    // sanity: the measured window really did work
    assert!(step.t_end > 0.0);
    let t = session.finish();
    assert_eq!(eng.now(), t);
}

#[test]
fn steady_state_fault_injected_window_is_allocation_free() {
    // The fault-layer contract: injecting transfer failures and brownouts
    // must not put allocations on the hot path. Retry draws come from
    // pre-seeded per-link rng streams, backoff is arithmetic, brownout
    // lookups scan a fixed window list, and dropped prefetches recycle the
    // same in-flight/queue storage — so a warmed admit → step… → retire
    // window stays at exactly zero heap allocations even with an ACTIVE
    // fault plan installed (the fault-free path is covered a fortiori by
    // the other guards, which run with the fault layer compiled in).
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let mut w = Workload::new(&spec, ds, 19);
    let eam_ds = w.gen_eam_dataset(30);
    let mut eamc = Eamc::construct(8, &eam_ds, 11);
    eamc.set_rebuild_threshold(usize::MAX);
    eamc.set_recent_capacity(2);

    let mut eng = SimEngine::new(
        spec.clone(),
        tier(&spec, 64),
        eamc,
        ComputeModel::a5000(),
        EngineConfig::default(),
    );
    let mut plan = FaultPlan::new(0xFA57);
    plan.ssd_failure_p = 0.2;
    plan.gpu_failure_p = 0.2;
    plan.brownouts.push(Brownout {
        link: FaultLink::DramToGpu,
        start: SimTime::ZERO,
        end: SimTime::from_f64(f64::MAX),
        factor: 0.5,
    });
    eng.set_fault_plan(&plan); // the one Box lands here, before the window
    let a = w.gen_sequence();
    let b = w.gen_sequence();
    let mut step = StepResult::default();
    let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);

    fn cycle<'s>(
        session: &mut moe_infinity::engine::BatchSession<'_>,
        step: &mut StepResult,
        a: &'s SequenceActivation,
        b: &'s SequenceActivation,
        base: u64,
    ) {
        session.admit(base, a);
        session.admit(base + 1, b);
        let mut active = 2usize;
        while active > 0 {
            assert!(session.step(|id: u64| if id % 2 == 0 { a } else { b }, step));
            active -= step.finished.len();
        }
    }

    for i in 0..5u64 {
        cycle(&mut session, &mut step, &a, &b, 2 * i);
    }

    let (_, stats) = measure(|| {
        cycle(&mut session, &mut step, &a, &b, 10);
    });
    assert_eq!(
        stats.total(),
        0,
        "a warmed fault-injected window (retries, brownouts, drops) must \
         not allocate, but did: {stats:?}"
    );
    assert!(step.t_end > 0.0);
    let t = session.finish();
    assert_eq!(eng.now(), t);
    let st = eng.sim().stats();
    assert!(st.transfer_retries > 0, "p=0.2 must exercise the retry path");
}

#[test]
fn steady_state_chunked_prefill_window_is_allocation_free() {
    // The chunked-prefill contract: a warmed admit → chunk-step… →
    // last-chunk → decode… → retire window allocates nothing. Chunking
    // adds per-step state (`slot_prefill_done`/`slot_chunk`, the
    // `prefilling`/`stalled` event buffers) — all of it pooled per slot or
    // reused per step, so the budgeted path must be exactly as
    // allocation-free as the unlimited one.
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let mut w = Workload::new(&spec, ds, 13);
    let eam_ds = w.gen_eam_dataset(30);
    let mut eamc = Eamc::construct(8, &eam_ds, 11);
    eamc.set_rebuild_threshold(usize::MAX);
    eamc.set_recent_capacity(2);

    let mut eng = SimEngine::new(
        spec.clone(),
        tier(&spec, 64),
        eamc,
        ComputeModel::a5000(),
        EngineConfig::default(),
    );
    let a = w.gen_sequence();
    let b = w.gen_sequence();
    let mut step = StepResult::default();
    let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);

    // admit two sequences and run them dry under a small shared chunk
    // budget — slot 1 stalls while slot 0's prompt chunks through, so the
    // stalled/prefilling paths are exercised every cycle
    fn cycle<'s>(
        session: &mut moe_infinity::engine::BatchSession<'_>,
        step: &mut StepResult,
        a: &'s SequenceActivation,
        b: &'s SequenceActivation,
        base: u64,
    ) {
        session.admit(base, a);
        session.admit(base + 1, b);
        let mut active = 2usize;
        while active > 0 {
            session.set_prefill_limit(8);
            assert!(session.step(|id: u64| if id % 2 == 0 { a } else { b }, step));
            active -= step.finished.len();
        }
    }

    for i in 0..5u64 {
        cycle(&mut session, &mut step, &a, &b, 2 * i);
    }

    let (_, stats) = measure(|| {
        cycle(&mut session, &mut step, &a, &b, 10);
    });
    assert_eq!(
        stats.total(),
        0,
        "a warmed chunked admit → chunk-step → retire window must not \
         allocate, but did: {stats:?}"
    );
    assert!(step.t_end > 0.0);
    let t = session.finish();
    assert_eq!(eng.now(), t);
}

#[test]
fn steady_state_router_iteration_is_allocation_free() {
    // The router contract: submission pre-sizes every replica buffer and
    // report recorder, affinity scoring reuses per-replica matcher
    // handles, and replica steps run on the session substrate — so once
    // the replay is warmed, a window of router ticks (dispatch, admission,
    // stepping, retirement) performs zero heap allocations.
    //
    // Since PR 7, `tick` is the event-calendar loop, so the window also
    // pins the calendar hot path: `submit_all` reserves the binary heap
    // for the whole replay's worth of entries (2 per request + one live
    // per replica + crash edges, covering the lazy-invalidation garbage
    // bound), and a tick's pop → run-to-frontier batch → refresh pushes
    // must recycle that capacity. A heap regrowth inside the measured
    // window — i.e. an under-estimated stale-entry bound — fails the
    // guard.
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let mk_engine = |seed: u64| {
        let mut w = Workload::new(&spec, ds.clone(), seed);
        let eam_ds = w.gen_eam_dataset(30);
        let mut eamc = Eamc::construct(8, &eam_ds, 11);
        // steady state = no online reconstruction; tiny recent ring,
        // pre-filled so every serving-path observe recycles slots in place
        // (the ring's first pushes clone and would otherwise depend on how
        // many retirements the warm-up happens to reach on this replica)
        eamc.set_rebuild_threshold(usize::MAX);
        eamc.set_recent_capacity(2);
        let filler = w
            .gen_sequence()
            .to_eam(spec.n_layers, spec.experts_per_layer);
        eamc.observe(&filler, true);
        eamc.observe(&filler, true);
        SimEngine::new(
            spec.clone(),
            tier(&spec, 64),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        )
    };
    let engines = vec![mk_engine(7), mk_engine(8)];
    let mut w = Workload::new(&spec, ds.clone(), 9);
    let reqs: Vec<Request> = (0..40)
        .map(|i| Request::new(i as u64, i as f64 * 0.05, w.gen_sequence()))
        .collect();
    let mut router = Router::new(
        engines,
        Batcher::new(4, 0.1),
        RoutingPolicy::TaskAffinity,
        AdmissionPolicy::Fifo,
    );
    router.submit_all(&reqs);
    // warm every pool, queue, matcher arena, slot buffer and the EAMC
    // recent rings to their high-water marks (dispatches, admissions and
    // several retirements all happen in the first 200 events)
    for _ in 0..200 {
        if !router.tick() {
            panic!("warm-up exhausted the replay; grow the request stream");
        }
    }
    let (_, stats) = measure(|| {
        for _ in 0..10 {
            router.tick();
        }
    });
    assert_eq!(
        stats.total(),
        0,
        "a warmed router iteration window must not allocate, but did: {stats:?}"
    );
    let report = router.drain();
    assert_eq!(report.requests, 40, "every request still completes");
}

#[test]
fn counting_allocator_actually_counts() {
    // meta-check so a silently broken counter can't green-light the guard
    let (v, stats) = measure(|| {
        let mut v: Vec<u64> = Vec::new();
        for i in 0..100 {
            v.push(i);
        }
        v.len()
    });
    assert_eq!(v, 100);
    assert!(stats.total() > 0, "Vec growth must be visible: {stats:?}");
}
