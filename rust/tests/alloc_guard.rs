//! Allocation-regression guard for the serving hot path.
//!
//! The tentpole contract: after warm-up, a steady-state `run_batch` decode
//! pass performs **zero** heap allocations — the per-layer union, the
//! per-sequence EAMs and matcher handles, the prediction buffer, the
//! prefetch queues, the eviction heap and the EAMC recent-window ring all
//! recycle engine-owned buffers. This test installs the counting global
//! allocator from `util::alloc` (only this test binary owns the global
//! allocator) and asserts the count is exactly zero for a warmed batch.

use moe_infinity::cache::CacheKind;
use moe_infinity::engine::{BatchResult, ComputeModel, EngineConfig, SimEngine};
use moe_infinity::memory::{Link, Tier, TierConfig};
use moe_infinity::model::ModelSpec;
use moe_infinity::trace::Eamc;
use moe_infinity::util::alloc::{measure, CountingAlloc};
use moe_infinity::workload::{DatasetPreset, Workload};

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc::new();

fn tier(spec: &ModelSpec, gpu: usize) -> TierConfig {
    TierConfig {
        gpu_capacity: gpu,
        dram_capacity: spec.total_experts() / 2,
        backing: Tier::Ssd,
        ssd_to_dram: Link::new(6.0, 50e-6),
        dram_to_gpu: Link::new(32.0, 10e-6),
        n_gpus: 1,
        demand_extra_latency: 0.0,
        demand_bw_factor: 1.0,
        cache_kind: CacheKind::Activation,
        oracle_trace: Vec::new(),
        activation_terms: (true, true),
        prefetch_gpu_budget: 0.5,
    }
}

#[test]
fn steady_state_decode_batch_is_allocation_free() {
    let spec = ModelSpec::preset("switch-base-32").unwrap();
    let ds = DatasetPreset::by_name("translation").unwrap();
    let mut w = Workload::new(&spec, ds, 5);
    let eam_ds = w.gen_eam_dataset(30);
    let mut eamc = Eamc::construct(8, &eam_ds, 11);
    // steady state = no online reconstruction; shrink the recent-window
    // ring so warm-up fills it and later observes recycle slots in place
    eamc.set_rebuild_threshold(usize::MAX);
    eamc.set_recent_capacity(2);

    let mut eng = SimEngine::new(
        spec.clone(),
        tier(&spec, 64),
        eamc,
        ComputeModel::a5000(),
        EngineConfig::default(),
    );
    let seqs: Vec<_> = (0..2).map(|_| w.gen_sequence()).collect();
    let mut result = BatchResult::default();

    // warm every pool, map, heap and result buffer to its high-water mark
    for _ in 0..5 {
        let start = eng.now();
        eng.run_batch_into(&seqs, start, &mut result);
    }

    let start = eng.now();
    let (_, stats) = measure(|| {
        eng.run_batch_into(&seqs, start, &mut result);
    });
    assert_eq!(
        stats.total(),
        0,
        "steady-state run_batch must not allocate, but did: {stats:?}"
    );
    // sanity: the measured batch really did work
    assert!(!result.token_latencies.is_empty());
    assert!(result.demands > 0);
}

#[test]
fn counting_allocator_actually_counts() {
    // meta-check so a silently broken counter can't green-light the guard
    let (v, stats) = measure(|| {
        let mut v: Vec<u64> = Vec::new();
        for i in 0..100 {
            v.push(i);
        }
        v.len()
    });
    assert_eq!(v, 100);
    assert!(stats.total() > 0, "Vec growth must be visible: {stats:?}");
}
