//! The real-compute engine: Algorithm 1 driving the **actual** tiny MoE
//! through PJRT-compiled HLO artifacts (L2 JAX graph + L1 Pallas kernels).
//!
//! This is the end-to-end proof that all three layers compose: routing
//! decisions come from the real router kernel, expert FFNs run real numerics
//! (validated against the pure-jnp oracle at build time), and the rust
//! coordinator traces EAMs / prefetches / caches exactly as in the simulated
//! path. Expert *transfers* remain virtual-time (no GPU exists here); each
//! reported per-token latency = measured wall compute + simulated stall.

use anyhow::{anyhow, Result};
use std::path::Path;
use std::time::Instant;

use crate::cache::CacheCtx;
use crate::memory::{MemorySim, TierConfig};
use crate::model::weights::{SyntheticCheckpoint, TinyConfig};
use crate::model::{ExpertKey, ModelSpec};
use crate::prefetch::{Predictor, PredictorKind};
use crate::runtime::Runtime;
use crate::trace::{Eam, Eamc};
use crate::util::units::SimTime;

/// Output of one batch generation on the real model.
#[derive(Debug, Clone)]
pub struct GenOutput {
    /// Generated token ids per batch row.
    pub tokens: Vec<Vec<i32>>,
    /// Per forward-iteration: measured compute wall time (seconds).
    pub compute_wall: Vec<f64>,
    /// Per forward-iteration: simulated expert-fetch stall (seconds).
    pub fetch_stall: Vec<f64>,
    /// Expert demands / GPU-cache hits over the batch.
    pub demands: u64,
    pub gpu_hits: u64,
    /// Completed per-sequence EAMs (for tracing / EAMC construction).
    pub eams: Vec<Eam>,
}

impl GenOutput {
    /// Estimated serving per-token latency: compute + stall.
    pub fn token_latencies(&self) -> Vec<f64> {
        self.compute_wall
            .iter()
            .zip(&self.fetch_stall)
            .map(|(c, s)| c + s)
            .collect()
    }

    pub fn recall(&self) -> f64 {
        if self.demands == 0 {
            1.0
        } else {
            self.gpu_hits as f64 / self.demands as f64
        }
    }
}

/// KV caches and hidden-state buffers for one generation, owned by rust.
struct DecodeState {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

/// The real engine.
pub struct RealMoeEngine {
    rt: Runtime,
    ckpt: SyntheticCheckpoint,
    spec: ModelSpec,
    sim: MemorySim,
    eamc: Eamc,
    predictor: Predictor,
    vtime: f64,
    pred_buf: Vec<(ExpertKey, f64)>,
}

impl RealMoeEngine {
    /// Load artifacts, generate the synthetic checkpoint, set up offloading.
    pub fn new(
        artifacts_dir: &Path,
        seed: u64,
        n_task_clusters: usize,
        tier: TierConfig,
        predictor_kind: PredictorKind,
    ) -> Result<RealMoeEngine> {
        let rt = Runtime::load(artifacts_dir)?;
        let cfg = rt.cfg.clone();
        let ckpt = SyntheticCheckpoint::generate(&cfg, seed, n_task_clusters);
        let spec = tiny_spec(&cfg);
        let sim = MemorySim::new(&spec, tier);
        let predictor = Predictor::new(predictor_kind, cfg.n_layers, cfg.n_experts)
            .with_min_ratio(0.02);
        let eamc = Eamc::new(64, cfg.n_layers, cfg.n_experts);
        Ok(RealMoeEngine {
            rt,
            ckpt,
            spec,
            sim,
            eamc,
            predictor,
            vtime: 0.0,
            pred_buf: Vec::new(),
        })
    }

    pub fn cfg(&self) -> &TinyConfig {
        &self.rt.cfg
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn sim(&self) -> &MemorySim {
        &self.sim
    }

    pub fn eamc(&self) -> &Eamc {
        &self.eamc
    }

    /// Offline tracing phase (§4.2): run `prompt_sets` through the model,
    /// record their EAMs, and construct the EAMC.
    pub fn build_eamc(
        &mut self,
        prompt_sets: &[Vec<Vec<i32>>],
        gen_tokens: usize,
        capacity: usize,
    ) -> Result<()> {
        let mut dataset = Vec::new();
        for prompts in prompt_sets {
            let out = self.generate(prompts, gen_tokens)?;
            dataset.extend(out.eams);
        }
        if dataset.is_empty() {
            return Err(anyhow!("no EAMs traced"));
        }
        self.eamc = Eamc::construct(capacity, &dataset, 0xE5);
        Ok(())
    }

    /// Generate `max_new` tokens for a batch of equal-length prompts
    /// (padded internally to the compiled batch size).
    pub fn generate(&mut self, prompts: &[Vec<i32>], max_new: usize) -> Result<GenOutput> {
        let c = self.rt.cfg.clone();
        let b = c.batch;
        if prompts.is_empty() || prompts.len() > b {
            return Err(anyhow!("need 1..={b} prompts, got {}", prompts.len()));
        }
        let plen = prompts[0].len();
        if plen == 0 || prompts.iter().any(|p| p.len() != plen) {
            return Err(anyhow!("prompts must be equal-length and non-empty"));
        }
        if plen + max_new > c.max_seq {
            return Err(anyhow!(
                "prompt {plen} + gen {max_new} exceeds compiled max_seq {}",
                c.max_seq
            ));
        }
        let real = prompts.len();
        // batch padding: duplicate row 0 into unused slots, masked out
        let sel: Vec<f32> = (0..b).map(|i| if i < real { 1.0 } else { 0.0 }).collect();

        let mut state = DecodeState {
            k: vec![vec![0.0; b * c.max_seq * c.d_model]; c.n_layers],
            v: vec![vec![0.0; b * c.max_seq * c.d_model]; c.n_layers],
        };
        let mut cur_eams: Vec<Eam> = (0..real).map(|_| Eam::new(c.n_layers, c.n_experts)).collect();
        let mut batch_eam = Eam::new(c.n_layers, c.n_experts);
        self.sim.clear_queues();

        let mut out = GenOutput {
            tokens: vec![Vec::new(); real],
            compute_wall: Vec::new(),
            fetch_stall: Vec::new(),
            demands: 0,
            gpu_hits: 0,
            eams: Vec::new(),
        };

        let mut ids: Vec<i32> = (0..b).map(|i| prompts[i.min(real - 1)][0]).collect();
        let total_steps = plen + max_new;
        for pos in 0..total_steps - 1 {
            let is_gen = pos + 1 >= plen;
            let iter_idx = pos.saturating_sub(plen - 1);
            let (wall, stall, next) =
                self.decode_step(&ids, pos, &sel, &mut state, &mut cur_eams, &mut batch_eam, iter_idx, &mut out)?;
            if is_gen {
                out.compute_wall.push(wall);
                out.fetch_stall.push(stall);
                for (i, row) in out.tokens.iter_mut().enumerate() {
                    row.push(next[i]);
                }
                ids = next;
            } else {
                // prefill: next input is the next prompt token
                ids = (0..b).map(|i| prompts[i.min(real - 1)][pos + 1]).collect();
                // prefill compute also counts toward the first token
                if !out.compute_wall.is_empty() {
                } else if pos + 2 >= plen {
                    // accounted in the first generated step
                }
            }
        }

        for eam in cur_eams {
            let recall = out.recall();
            self.eamc.observe(&eam, recall >= 0.5);
            out.eams.push(eam);
        }
        Ok(out)
    }

    /// One full forward step over all layers; returns (wall, stall, next ids).
    #[allow(clippy::too_many_arguments)]
    fn decode_step(
        &mut self,
        ids: &[i32],
        pos: usize,
        sel: &[f32],
        state: &mut DecodeState,
        cur_eams: &mut [Eam],
        batch_eam: &mut Eam,
        iter_idx: usize,
        out: &mut GenOutput,
    ) -> Result<(f64, f64, Vec<i32>)> {
        let c = self.rt.cfg.clone();
        let (b, d) = (c.batch, c.d_model);
        // moelint: allow(wall-clock, real-runtime path reports host latency by design)
        let t0 = Instant::now();
        let mut stall = 0.0f64;

        let mut x = self.rt.embed(ids, self.ckpt.try_get("emb")?)?;
        for l in 0..c.n_layers {
            // attention
            let (nx, nk, nv) = self.rt.attn_step(
                &x,
                &state.k[l],
                &state.v[l],
                pos as i32,
                self.ckpt.try_get(&format!("l{l}.wq"))?,
                self.ckpt.try_get(&format!("l{l}.wk"))?,
                self.ckpt.try_get(&format!("l{l}.wv"))?,
                self.ckpt.try_get(&format!("l{l}.wo"))?,
            )?;
            x = nx;
            state.k[l] = nk;
            state.v[l] = nv;

            // router (L1 Pallas kernel)
            let (gates, idx) = self.rt.router(&x, self.ckpt.try_get(&format!("l{l}.wr"))?)?;

            // trace (Alg. 1 steps 6-7)
            for (row, &e) in idx.iter().enumerate().take(cur_eams.len()) {
                cur_eams[row].record(l, e as usize, 1);
                batch_eam.record(l, e as usize, 1);
                self.predictor.observe_route(l, e as usize, 1);
            }

            // prefetch resubmission (Alg. 1 step 8)
            for row in 0..cur_eams.len() {
                if self.predictor.should_predict(l, iter_idx) {
                    let mut buf = std::mem::take(&mut self.pred_buf);
                    // the tiny real model re-predicts rarely; the naive
                    // nearest scan is fine here (no matcher handle threaded)
                    self.predictor.predict(&cur_eams[row], &self.eamc, None, l, &mut buf);
                    let ctx = CacheCtx::new(batch_eam, c.n_layers);
                    for &(key, prio) in buf.iter() {
                        if prio > crate::prefetch::EPSILON {
                            self.sim
                                .submit_prefetch(key, prio, SimTime::from_f64(self.vtime), &ctx);
                        }
                    }
                    self.pred_buf = buf;
                }
            }

            // expert execution (Alg. 1 steps 9-13), per distinct expert
            let mut eo = vec![0.0f32; b * d];
            let mut experts: Vec<u16> = idx.iter().map(|&e| e as u16).collect();
            experts.sort();
            experts.dedup();
            for &e in &experts {
                let key = ExpertKey::new(l, e as usize);
                let ctx = CacheCtx::new(batch_eam, c.n_layers);
                // virtual-time offloading accounting
                let vt_before_wall = t0.elapsed().as_secs_f64();
                let vt_now = self.vtime + vt_before_wall + stall;
                let was_on_gpu = self.sim.is_on_gpu(key);
                let ready = self.sim.demand(key, SimTime::from_f64(vt_now), &ctx).to_f64();
                out.demands += 1;
                if was_on_gpu {
                    out.gpu_hits += 1;
                }
                stall += ready - vt_now;

                // gather rows routed to e, padded to the compiled batch
                let rows: Vec<usize> =
                    (0..b).filter(|&r| idx[r] as u16 == e).collect();
                let mut xin = vec![0.0f32; b * d];
                for (slot, &r) in rows.iter().enumerate() {
                    xin[slot * d..(slot + 1) * d].copy_from_slice(&x[r * d..(r + 1) * d]);
                }
                let [w1, b1, w2, b2] = self.ckpt.try_expert_tensors(l, e as usize)?;
                let y = self.rt.expert(&xin, w1, b1, w2, b2)?;
                for (slot, &r) in rows.iter().enumerate() {
                    eo[r * d..(r + 1) * d].copy_from_slice(&y[slot * d..(slot + 1) * d]);
                }
            }
            x = self.rt.combine(&x, &eo, &gates, sel)?;
        }
        let next = self.rt.lm_head(&x, self.ckpt.try_get("w_out")?)?;
        let wall = t0.elapsed().as_secs_f64();
        self.vtime += wall + stall;
        Ok((wall, stall, next))
    }
}

/// ModelSpec view of the tiny geometry (drives the memory simulator).
pub fn tiny_spec(c: &TinyConfig) -> ModelSpec {
    ModelSpec {
        name: "tiny-moe-real".into(),
        n_layers: c.n_layers,
        experts_per_layer: c.n_experts,
        d_model: c.d_model,
        d_ff: c.d_ff,
        dtype_bytes: 4,
        dense_bytes: (c.vocab * c.d_model * 4) as u64,
    }
}
