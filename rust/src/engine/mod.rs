//! The generative-inference engine: the paper's Algorithm 1 ("Generative
//! Inference with Expert Prefetching") generalized to batches.
//!
//! Two backends share this module's structure:
//! * [`SimEngine`] — executes *routing traces* ([`crate::workload`]) against
//!   the discrete-event memory simulator with a calibrated compute-time
//!   model; this is what all large-model experiments (Figs. 4-13) run.
//! * `engine::real` (see [`crate::runtime`]) — executes the **real** tiny
//!   MoE via PJRT-compiled HLO artifacts end-to-end; routing comes from the
//!   actual Pallas router kernel.

pub mod real;
mod sim_engine;

pub use real::{GenOutput, RealMoeEngine};
pub use sim_engine::{
    prefill_chunk_tokens, BatchResult, BatchSession, EngineConfig, FeedbackMode, PreemptedSeq,
    SessionState, SimEngine, StepResult,
};

use crate::model::ModelSpec;

/// Calibrated compute-time model for the simulated backend.
///
/// Only *relative* magnitudes matter for reproducing the paper's figure
/// shapes: expert execution is fast relative to expert transfer (an A5000
/// runs a 18MB switch-base expert in ~0.2ms but fetching it over PCIe 4.0
/// takes ~0.6ms; over NVMe ~3ms).
#[derive(Debug, Clone, Copy)]
pub struct ComputeModel {
    /// Effective GPU throughput in FLOP/s (derated from peak).
    pub gpu_flops: f64,
    /// Fixed per-layer overhead (kernel launches, router, combine).
    pub layer_overhead: f64,
}

impl ComputeModel {
    /// RTX A5000 (the paper's 8-GPU server): 27.8 TFLOP/s f32 peak,
    /// derated to 50% achievable on small decode batches.
    pub fn a5000() -> ComputeModel {
        ComputeModel {
            gpu_flops: 13.9e12,
            layer_overhead: 30e-6,
        }
    }

    /// V100 (the paper's 6-node cluster): 15.7 TFLOP/s f32 peak, 50%.
    pub fn v100() -> ComputeModel {
        ComputeModel {
            gpu_flops: 7.8e12,
            layer_overhead: 30e-6,
        }
    }

    /// Time to run one expert over `tokens` tokens.
    pub fn expert_time(&self, spec: &ModelSpec, tokens: u32) -> f64 {
        spec.expert_flops_per_token() as f64 * tokens as f64 / self.gpu_flops
    }

    /// Time for the dense (attention) part of one layer over `tokens`.
    pub fn dense_time(&self, spec: &ModelSpec, tokens: u32) -> f64 {
        self.layer_overhead
            + spec.dense_flops_per_token_layer() as f64 * tokens as f64 / self.gpu_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expert_time_scales_with_tokens_and_size() {
        let cm = ComputeModel::a5000();
        let base = ModelSpec::preset("switch-base-128").unwrap();
        let large = ModelSpec::preset("switch-large-128").unwrap();
        assert!(cm.expert_time(&base, 2) > cm.expert_time(&base, 1));
        assert!(cm.expert_time(&large, 1) > cm.expert_time(&base, 1));
    }

    #[test]
    fn transfer_dominates_compute_for_offloaded_experts() {
        // The premise of the paper: fetching an expert costs much more than
        // executing it. Verify our calibration preserves that.
        let cm = ComputeModel::a5000();
        let spec = ModelSpec::preset("switch-base-128").unwrap();
        let exec = cm.expert_time(&spec, 16);
        let pcie4 = spec.expert_bytes() as f64 / 32e9;
        assert!(
            pcie4 > 3.0 * exec,
            "PCIe fetch {pcie4} should dwarf exec {exec}"
        );
    }

    #[test]
    fn v100_slower_than_a5000() {
        let spec = ModelSpec::preset("switch-base-128").unwrap();
        assert!(
            ComputeModel::v100().expert_time(&spec, 4)
                > ComputeModel::a5000().expert_time(&spec, 4)
        );
    }
}
