//! Algorithm 1 over the discrete-event memory simulator.

use crate::cache::CacheCtx;
use crate::cluster::ClusterModel;
use crate::engine::ComputeModel;
use crate::memory::{MemorySim, TierConfig};
use crate::model::{ExpertKey, ModelSpec};
use crate::prefetch::{Predictor, PredictorKind};
use crate::trace::{Eam, Eamc, EamcMatcher};
use crate::util::units::SimTime;
use crate::workload::SequenceActivation;

/// Engine policy knobs (the ablation surface of §8.3/§8.4).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub predictor: PredictorKind,
    /// §8.3 "effects of activation-aware priority": when false, prefetches
    /// all carry one flat priority (FIFO order); on-demand still jumps.
    pub priority_enabled: bool,
    /// Recall threshold under which a sequence counts as poorly predicted
    /// (feeds EAMC online reconstruction, §4.3).
    pub well_predicted_recall: f64,
    /// Minimum predicted activation ratio worth a prefetch transfer
    /// (precision gate; see `Predictor::with_min_ratio`).
    pub min_prefetch_ratio: f64,
    /// ZeRO semantics: fetch every expert of a layer before executing it
    /// (no router visibility — see `baselines::fetch_all_for`).
    pub fetch_all_experts: bool,
    /// Cancel a sequence's still-queued prefetches the moment it retires or
    /// is preempted, instead of leaving them until the next
    /// re-prioritization pass drains them. Ownership is "last predictor
    /// wins": a key predicted later by a still-live sequence is not
    /// cancelled, and an over-eager cancel is healed by the next
    /// iteration's re-prediction. **On by default** since the
    /// `cancel_{off,on}_prefetch_mb` rows in `BENCH_scheduler.json` showed
    /// the cancellation is pure dead-PCIe-traffic savings (perf_scheduler
    /// asserts the no-p99-cost contract on every CI run); the bitwise
    /// scheduler differentials that pin the *uncancelled* replay set this
    /// to `false` explicitly, so the suite is stable under either default.
    pub cancel_retired_prefetch: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            predictor: PredictorKind::ActivationAware { refine: true },
            priority_enabled: true,
            well_predicted_recall: 0.5,
            min_prefetch_ratio: 0.05,
            fetch_all_experts: false,
            cancel_retired_prefetch: true,
        }
    }
}

/// Proportional prefix-split of one prefill row cell: how many of an
/// expert's `c` prompt tokens land in the chunk covering prompt positions
/// `[done, done + k)` of a `prompt`-token prefill.
///
/// `floor(c·(done+k)/prompt) − floor(c·done/prompt)` telescopes exactly:
/// summing over any chunk partition of `[0, prompt)` returns `c`, and the
/// full range `[0, prompt)` is `c` itself — which is what makes a
/// chunk-size-∞ chunked replay record the same counts as the historical
/// whole-prompt iteration 0 (pinned bitwise) and what the chunk-sum
/// property test in `tests/properties.rs` pins for every finite split.
#[inline]
pub fn prefill_chunk_tokens(c: u32, done: u32, k: u32, prompt: u32) -> u32 {
    debug_assert!(prompt > 0 && done + k <= prompt);
    let hi = (c as u64 * (done + k) as u64) / prompt as u64;
    let lo = (c as u64 * done as u64) / prompt as u64;
    (hi - lo) as u32
}

/// Outcome of one batch generation (all sequences run to completion).
#[derive(Debug, Clone, Default)]
pub struct BatchResult {
    /// Latency of each forward iteration (per-token latency, §2.1).
    pub token_latencies: Vec<f64>,
    /// Virtual time when the batch finished.
    pub finish: f64,
    /// Per-sequence prefetch recall: fraction of expert demands that hit GPU.
    pub seq_recalls: Vec<f64>,
    /// Total expert demands / GPU hits in this batch.
    pub demands: u64,
    pub gpu_hits: u64,
    /// Expert-ready waits observed (expert demand stall per event).
    pub stalls: Vec<f64>,
}

impl BatchResult {
    pub fn mean_token_latency(&self) -> f64 {
        if self.token_latencies.is_empty() {
            0.0
        } else {
            self.token_latencies.iter().sum::<f64>() / self.token_latencies.len() as f64
        }
    }

    /// Batch prefetch recall. Nothing demanded ⇒ nothing missed ⇒ 1.0
    /// (the same convention the per-sequence recall path uses).
    pub fn recall(&self) -> f64 {
        if self.demands == 0 {
            1.0
        } else {
            self.gpu_hits as f64 / self.demands as f64
        }
    }
}

/// The simulated-backend engine (one model replica).
///
/// All per-batch working state (per-sequence EAMs, matcher handles, the
/// per-layer routing union, demand/hit tallies) lives in engine-owned
/// buffers that are cleared — not reallocated — at batch boundaries, so a
/// steady-state decode iteration performs no heap allocation (pinned by
/// `tests/alloc_guard.rs`).
pub struct SimEngine {
    spec: ModelSpec,
    sim: MemorySim,
    eamc: Eamc,
    predictor: Predictor,
    compute: ComputeModel,
    cfg: EngineConfig,
    clock: f64,
    /// Expert-parallel cluster execution model (None = single node).
    cluster: Option<ClusterModel>,
    /// Reusable prediction buffer (hot path, no per-layer allocation).
    pred_buf: Vec<(ExpertKey, f64)>,
    /// Per-sequence incremental matcher handles (re-attached per batch).
    matchers: Vec<EamcMatcher>,
    /// Pooled per-sequence EAMs (Alg. 1 step 2 clears these).
    cur_eams: Vec<Eam>,
    /// Batch-combined EAM driving cache decisions.
    batch_eam: Eam,
    /// All-zero EAM for idle-time cache contexts.
    idle_eam: Eam,
    /// Per-layer routing union scratch (replaces a per-layer BTreeMap):
    /// token totals and touching sequences per expert id, plus the sorted
    /// list of experts active in the current layer.
    union_tokens: Vec<u32>,
    union_seqs: Vec<Vec<u32>>,
    union_active: Vec<u16>,
    /// Per-sequence demand/GPU-hit tallies for the recall feedback loop.
    seq_demands: Vec<u64>,
    seq_hits: Vec<u64>,
    // --- resumable stepping-session state (continuous batching) ---
    // All per-slot arrays grow together; a slot id stays valid for the
    // occupant's whole lifetime, so EAM/matcher/tally state survives other
    // sequences joining and leaving around it.
    /// External id of each slot's occupant (`FREE_SLOT` when vacant).
    slot_occupant: Vec<u64>,
    /// Next local iteration each occupied slot will execute.
    slot_iter: Vec<u32>,
    /// Total iterations of each slot's sequence.
    slot_total: Vec<u32>,
    /// Prompt length of each slot's sequence (iteration-0 token count).
    slot_prompt: Vec<u32>,
    /// Prompt tokens already consumed by completed prefill chunks. A slot
    /// with `slot_iter == 0 && slot_prefill_done < slot_prompt` is in the
    /// `Prefilling(consumed..)` state: its next step executes the next
    /// chunk of the prompt instead of a decode token.
    slot_prefill_done: Vec<u32>,
    /// Prompt tokens granted to each slot for the *current* step (scratch,
    /// written at the top of every [`BatchSession::step`]).
    slot_chunk: Vec<u32>,
    /// Prefill grant precedence per slot: the per-iteration chunk budget
    /// is granted in ascending `(rank, slot)` order, NOT slot order — slot
    /// ids recycle, so a newly admitted prompt can occupy a *lower* slot
    /// than an older mid-prefill sequence and would otherwise steal the
    /// whole budget every iteration (starvation). Defaults to a monotone
    /// admission counter (FCFS); schedulers may override via
    /// [`BatchSession::set_prefill_rank`] (the Classes policy ranks by
    /// priority so an interactive prefill is never budget-starved behind a
    /// batch one).
    slot_rank: Vec<u64>,
    /// Monotone source for the default FCFS `slot_rank`.
    next_rank: u64,
    /// Reusable ordering scratch for the budget-grant pass.
    grant_scratch: Vec<u32>,
    /// Occupied slot ids, ascending — the deterministic step order.
    slot_active: Vec<u32>,
    /// Per-iteration prefill token budget shared by all prefilling slots in
    /// slot order (`u32::MAX` = unlimited, the historical whole-prompt
    /// iteration 0). Schedulers set it through
    /// [`BatchSession::set_prefill_limit`] before each step.
    prefill_limit: u32,
    /// Pooled step-event buffers for `run_batch_into`.
    step_scratch: StepResult,
    /// Last predictor of each expert's queued prefetch (`slot + 1`, 0 =
    /// none), flat-indexed by expert. Only maintained when
    /// [`EngineConfig::cancel_retired_prefetch`] is on; retirement and
    /// preemption then cancel the still-queued predictions the departing
    /// sequence owned.
    prefetch_owner: Vec<u32>,
}

/// Sentinel occupant id of a vacant slot.
const FREE_SLOT: u64 = u64::MAX;

/// When a [`BatchSession`] reports sequence recall back to the EAMC (§4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedbackMode {
    /// Observe every admitted sequence when the session finishes, in slot
    /// order — the static `run_batch` contract (bitwise-preserved). Slots
    /// are not recycled; the batch membership is fixed.
    Deferred,
    /// Observe each sequence the iteration it retires and free its slot for
    /// the next admission — the continuous serving loop.
    Immediate,
}

/// Detached continuation of a [`BatchSession`] (see
/// [`BatchSession::suspend`] / [`SimEngine::resume_session`]). All real
/// session state lives in engine-owned pooled buffers; this token carries
/// only the scalars the session wrapper holds, which is what lets a
/// scheduler own both its engine and a long-lived logical session without
/// a self-referential borrow.
#[derive(Debug, Clone, Copy)]
pub struct SessionState {
    feedback: FeedbackMode,
    use_matcher: bool,
    t: f64,
    admitted: usize,
}

impl SessionState {
    /// Virtual time of the suspended session's next iteration boundary.
    pub fn now(&self) -> f64 {
        self.t
    }
}

/// Saved mid-flight state of a voluntarily preempted sequence (see
/// [`BatchSession::evict`] / [`BatchSession::admit_resumed`]): the traced
/// `cur_eam`, the next iteration to execute, and the recall tallies. The
/// buffers are caller-owned and reusable — `evict` writes into them via
/// [`Eam::copy_from`], so a warmed preempt/resume cycle allocates nothing.
#[derive(Debug, Clone)]
pub struct PreemptedSeq {
    ext_id: u64,
    iter: u32,
    total: u32,
    prompt: u32,
    /// Prompt tokens consumed by completed prefill chunks at eviction time
    /// (a sequence may be preempted mid-prefill under chunked scheduling).
    prefill_done: u32,
    demands: u64,
    hits: u64,
    eam: Eam,
}

impl PreemptedSeq {
    /// Empty holder for `layers × experts` geometry (the first `evict` into
    /// a mismatched holder re-allocates the EAM buffer; after that it is
    /// recycled in place).
    pub fn new(layers: usize, experts: usize) -> PreemptedSeq {
        PreemptedSeq {
            ext_id: FREE_SLOT,
            iter: 0,
            total: 0,
            prompt: 0,
            prefill_done: 0,
            demands: 0,
            hits: 0,
            eam: Eam::new(layers, experts),
        }
    }

    /// External id of the sequence this state belongs to.
    pub fn ext_id(&self) -> u64 {
        self.ext_id
    }

    /// Re-tag the saved state with a new external id. Cross-replica warm
    /// failover needs this: the surviving replica assigns its own request
    /// index as the session-local id, while the traced EAM, resume point
    /// and recall tallies carry over untouched.
    pub fn set_ext_id(&mut self, ext_id: u64) {
        self.ext_id = ext_id;
    }

    /// Iterations already executed (the resume point).
    pub fn iterations_done(&self) -> u32 {
        self.iter
    }

    /// The sequence's traced EAM at eviction time.
    pub fn eam(&self) -> &Eam {
        &self.eam
    }
}

/// Events of one [`BatchSession::step`]; buffers are reused across steps so
/// a warmed steady-state iteration records without allocating.
#[derive(Debug, Clone, Default)]
pub struct StepResult {
    /// Virtual time at the iteration's start and end.
    pub t_start: f64,
    pub t_end: f64,
    /// External ids of the sequences that executed this iteration, in slot
    /// order.
    pub executed: Vec<u64>,
    /// External ids of executed sequences still mid-prefill *after* this
    /// iteration (a non-final prefill chunk ran). An executed id absent
    /// from this list either decoded or just completed its last prefill
    /// chunk — the iteration TTFT accounting keys on.
    pub prefilling: Vec<u64>,
    /// External ids of active prefilling sequences that received zero
    /// prefill budget this iteration (the shared chunk budget was consumed
    /// by earlier slots). They rode the iteration without executing;
    /// schedulers charge the gap like a suspension.
    pub stalled: Vec<u64>,
    /// External ids of the sequences that finished (retired) at this
    /// iteration's end.
    pub finished: Vec<u64>,
    /// Expert demands issued / GPU hits observed during the iteration.
    pub demands: u64,
    pub gpu_hits: u64,
    /// Per-demand stall time (`ready - t`), in demand order.
    pub stalls: Vec<f64>,
}

impl StepResult {
    /// Wall-clock (virtual) latency of the iteration.
    pub fn latency(&self) -> f64 {
        self.t_end - self.t_start
    }

    fn clear(&mut self) {
        self.t_start = 0.0;
        self.t_end = 0.0;
        self.executed.clear();
        self.prefilling.clear();
        self.stalled.clear();
        self.finished.clear();
        self.demands = 0;
        self.gpu_hits = 0;
        self.stalls.clear();
    }
}

impl SimEngine {
    pub fn new(
        spec: ModelSpec,
        tier: TierConfig,
        eamc: Eamc,
        compute: ComputeModel,
        cfg: EngineConfig,
    ) -> SimEngine {
        let sim = MemorySim::new(&spec, tier);
        let predictor = Predictor::new(cfg.predictor, spec.n_layers, spec.experts_per_layer)
            .with_min_ratio(cfg.min_prefetch_ratio);
        let (n_layers, n_experts) = (spec.n_layers, spec.experts_per_layer);
        SimEngine {
            spec,
            sim,
            eamc,
            predictor,
            compute,
            cfg,
            clock: 0.0,
            cluster: None,
            pred_buf: Vec::new(),
            matchers: Vec::new(),
            cur_eams: Vec::new(),
            batch_eam: Eam::new(n_layers, n_experts),
            idle_eam: Eam::new(n_layers, n_experts),
            union_tokens: vec![0; n_experts],
            union_seqs: vec![Vec::new(); n_experts],
            union_active: Vec::with_capacity(n_experts),
            seq_demands: Vec::new(),
            seq_hits: Vec::new(),
            slot_occupant: Vec::new(),
            slot_iter: Vec::new(),
            slot_total: Vec::new(),
            slot_prompt: Vec::new(),
            slot_prefill_done: Vec::new(),
            slot_chunk: Vec::new(),
            slot_rank: Vec::new(),
            next_rank: 0,
            grant_scratch: Vec::new(),
            slot_active: Vec::new(),
            prefill_limit: u32::MAX,
            step_scratch: StepResult::default(),
            prefetch_owner: vec![0; n_layers * n_experts],
        }
    }

    /// Enable expert-parallel cluster execution (§7, Fig. 13): per-layer
    /// all-to-all exchanges are charged and distinct experts execute in
    /// parallel across nodes.
    pub fn with_cluster(mut self, cluster: ClusterModel) -> SimEngine {
        self.cluster = Some(cluster);
        self
    }

    pub fn now(&self) -> f64 {
        self.clock
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn sim(&self) -> &MemorySim {
        &self.sim
    }

    /// Install a fault plan on this replica's memory simulator (see
    /// [`crate::faults::FaultPlan`]). An empty or crash-only plan is a
    /// strict no-op — the replay stays bitwise identical to an engine that
    /// never saw a plan (pinned in `tests/scheduler.rs`).
    pub fn set_fault_plan(&mut self, plan: &crate::faults::FaultPlan) {
        self.sim.set_fault_plan(plan);
    }

    pub fn eamc(&self) -> &Eamc {
        &self.eamc
    }

    pub fn eamc_mut(&mut self) -> &mut Eamc {
        &mut self.eamc
    }

    /// Idle the engine until `t` (arrivals later than the current clock).
    pub fn idle_until(&mut self, t: f64) {
        if t > self.clock {
            let ctx = CacheCtx::new(&self.idle_eam, self.spec.n_layers);
            self.sim.advance_to(SimTime::from_f64(t), &ctx);
            self.clock = t;
        }
    }

    /// Run one batch to completion (Alg. 1, batch-generalized):
    /// per-sequence `cur_eam`s are traced independently (the paper's
    /// sequence-level insight); prefetch predictions from all active
    /// sequences are merged into the shared priority queue; the cache
    /// context uses the batch-combined EAM.
    pub fn run_batch(&mut self, seqs: &[SequenceActivation], start: f64) -> BatchResult {
        let mut result = BatchResult::default();
        self.run_batch_into(seqs, start, &mut result);
        result
    }

    /// [`SimEngine::run_batch`] writing into a caller-owned result whose
    /// buffers are reused. Together with the engine-owned scratch this makes
    /// a warmed steady-state batch fully allocation-free (see
    /// `tests/alloc_guard.rs`).
    ///
    /// Implemented on the stepping session: all sequences are admitted up
    /// front, every iteration is one [`BatchSession::step`], and recall
    /// feedback is deferred to the end in slot order — which makes the
    /// output bitwise identical to the historical run-to-completion loop
    /// (slots are admitted in sequence order, so slot ids equal the old
    /// batch-local indices and every float op replays in the same order).
    // moelint: hot
    pub fn run_batch_into(
        &mut self,
        seqs: &[SequenceActivation],
        start: f64,
        result: &mut BatchResult,
    ) {
        assert!(!seqs.is_empty());
        result.token_latencies.clear();
        result.seq_recalls.clear();
        result.stalls.clear();
        result.demands = 0;
        result.gpu_hits = 0;

        let mut step = std::mem::take(&mut self.step_scratch);
        let mut session = self.begin_session(start, FeedbackMode::Deferred);
        for (i, s) in seqs.iter().enumerate() {
            session.admit(i as u64, s);
        }
        while session.step(|id| &seqs[id as usize], &mut step) {
            result.token_latencies.push(step.latency());
            result.demands += step.demands;
            result.gpu_hits += step.gpu_hits;
            for &s in &step.stalls {
                result.stalls.push(s);
            }
        }
        result.finish = session.finish();
        self.step_scratch = step;
        // §4.3 recall values (the observes themselves ran inside `finish`,
        // interleaved exactly as the historical loop did — observe does not
        // touch the tallies, so reading them afterwards is equivalent).
        for si in 0..seqs.len() {
            let recall = if self.seq_demands[si] == 0 {
                1.0
            } else {
                self.seq_hits[si] as f64 / self.seq_demands[si] as f64
            };
            result.seq_recalls.push(recall);
        }
    }

    /// Open a resumable stepping session (the continuous-batching
    /// substrate). Sequences are [`BatchSession::admit`]ted into stable
    /// slots and executed one iteration at a time by
    /// [`BatchSession::step`]; they may join and leave at any iteration
    /// boundary. All per-slot working state (current EAM, incremental
    /// matcher handle, demand/hit tallies) lives in engine-owned pooled
    /// buffers keyed by slot id, so a warmed session step allocates
    /// nothing (`tests/alloc_guard.rs`).
    pub fn begin_session(&mut self, start: f64, feedback: FeedbackMode) -> BatchSession<'_> {
        self.idle_until(start);
        let t = self.clock.max(start);
        // matcher accumulators only pay off when the activation-aware
        // predictor consumes them; the §8.3/§8.4 baselines skip the upkeep
        let use_matcher = matches!(self.cfg.predictor, PredictorKind::ActivationAware { .. });
        self.slot_active.clear();
        self.slot_occupant.fill(FREE_SLOT);
        // a fresh session starts on the historical whole-prompt iteration 0;
        // chunked schedulers re-set the budget before every step
        self.prefill_limit = u32::MAX;
        BatchSession {
            eng: self,
            feedback,
            use_matcher,
            t,
            admitted: 0,
        }
    }

    /// Re-open a session previously detached with [`BatchSession::suspend`].
    /// All per-slot working state lives in engine-owned buffers, so the
    /// state token plus the engine reconstruct the session exactly; unlike
    /// [`SimEngine::begin_session`] nothing is reset.
    pub fn resume_session(&mut self, state: SessionState) -> BatchSession<'_> {
        BatchSession {
            eng: self,
            feedback: state.feedback,
            use_matcher: state.use_matcher,
            t: state.t,
            admitted: state.admitted,
        }
    }

    /// Re-sync every active slot's matcher handle after an EAMC
    /// reconstruction mid-session: attach to the new build and replay the
    /// slot's traced EAM into the fresh accumulators.
    fn resync_active_matchers(&mut self) {
        for i in 0..self.slot_active.len() {
            let slot = self.slot_active[i] as usize;
            self.replay_matcher(slot);
        }
    }

    /// Attach `slot`'s matcher to the current EAMC build and replay the
    /// slot's traced EAM into the fresh accumulators (mid-session rebuild
    /// re-sync, and restoring a preempted sequence's matcher on resume).
    fn replay_matcher(&mut self, slot: usize) {
        self.matchers[slot].attach(&self.eamc);
        for l in 0..self.spec.n_layers {
            if self.cur_eams[slot].row_sum(l) == 0 {
                continue;
            }
            for e in 0..self.spec.experts_per_layer {
                let c = self.cur_eams[slot].count(l, e);
                if c > 0 {
                    self.matchers[slot].record(self.eamc.index(), l, e, c);
                }
            }
        }
    }

    /// Cancel every still-queued prefetch whose latest predictor was `slot`
    /// (no-op unless [`EngineConfig::cancel_retired_prefetch`] is set).
    fn cancel_owned_prefetches(&mut self, slot: usize) {
        if !self.cfg.cancel_retired_prefetch {
            return;
        }
        let owner = slot as u32 + 1;
        let experts = self.spec.experts_per_layer;
        for idx in 0..self.prefetch_owner.len() {
            if self.prefetch_owner[idx] == owner {
                self.prefetch_owner[idx] = 0;
                self.sim.cancel_prefetch(ExpertKey::new(idx / experts, idx % experts));
            }
        }
    }

    /// The exact order of expert demands `run_batch` will issue — used to
    /// build the ORACLE cache policy's future trace (§8.4).
    pub fn demand_trace(spec: &ModelSpec, batches: &[Vec<SequenceActivation>]) -> Vec<ExpertKey> {
        let mut out = Vec::new();
        for seqs in batches {
            let max_iters = seqs.iter().map(|s| s.iterations()).max().unwrap_or(0);
            for iter in 0..max_iters {
                for l in 0..spec.n_layers {
                    let mut union: std::collections::BTreeSet<u16> = Default::default();
                    for s in seqs {
                        if iter < s.iterations() {
                            for &(e, _) in &s.routes[iter][l] {
                                union.insert(e);
                            }
                        }
                    }
                    for e in union {
                        out.push(ExpertKey::new(l, e as usize));
                    }
                }
            }
        }
        out
    }
}

/// Admission into an **empty** session is a batch boundary: stale queued
/// prefetches (with their ownership marks) and the combined batch EAM are
/// dropped — the same reset `run_batch` performs after idling to its start
/// time, which is what keeps the single-slot continuous replay bitwise
/// identical to the static path.
fn reset_if_empty(eng: &mut SimEngine) {
    if eng.slot_active.is_empty() {
        eng.sim.clear_queues();
        eng.batch_eam.clear();
        if eng.cfg.cancel_retired_prefetch {
            eng.prefetch_owner.fill(0);
        }
    }
}

/// Lowest free slot id, growing every per-slot array together (one-time,
/// pooled) when none is free.
fn alloc_slot(eng: &mut SimEngine) -> usize {
    match eng.slot_occupant.iter().position(|&o| o == FREE_SLOT) {
        Some(s) => s,
        None => {
            let s = eng.slot_occupant.len();
            let (l, e) = (eng.spec.n_layers, eng.spec.experts_per_layer);
            eng.slot_occupant.push(FREE_SLOT);
            eng.slot_iter.push(0);
            eng.slot_total.push(0);
            eng.slot_prompt.push(0);
            eng.slot_prefill_done.push(0);
            eng.slot_chunk.push(0);
            eng.slot_rank.push(0);
            eng.cur_eams.push(Eam::new(l, e));
            eng.matchers.push(EamcMatcher::new());
            eng.seq_demands.push(0);
            eng.seq_hits.push(0);
            s
        }
    }
}

/// Whether `slot` received a zero prefill grant for the current step (set
/// by the budget pass at the top of [`BatchSession::step`]): still
/// mid-prefill but `slot_chunk` is 0. Zero-prompt sequences (nothing to
/// consume) are *not* stalled — they execute an empty iteration 0 exactly
/// as the pre-chunking engine did.
#[inline]
fn slot_stalled(eng: &SimEngine, slot: usize) -> bool {
    eng.slot_iter[slot] == 0
        && eng.slot_chunk[slot] == 0
        && eng.slot_prefill_done[slot] < eng.slot_prompt[slot]
}

/// A resumable batch over the engine: Alg. 1 generalized to
/// iteration-level scheduling. One session owns the engine for its
/// lifetime; the serving loop admits arrivals between steps and retires
/// sequences the iteration they finish (continuous batching), while
/// [`SimEngine::run_batch_into`] drives the same machinery with a fixed
/// membership and deferred feedback to keep the static path bitwise
/// identical.
///
/// Sequences are identified by a caller-chosen external id; the routing
/// trace is looked up through the closure passed to each
/// [`BatchSession::step`], so the session retains no references and the
/// per-slot state can live in the engine's pooled buffers.
pub struct BatchSession<'e> {
    eng: &'e mut SimEngine,
    feedback: FeedbackMode,
    use_matcher: bool,
    /// Virtual time of the next iteration boundary.
    t: f64,
    /// High-water slot count (deferred feedback walks these at finish).
    admitted: usize,
}

impl<'e> BatchSession<'e> {
    /// Virtual time of the current iteration boundary.
    pub fn now(&self) -> f64 {
        self.t
    }

    /// Number of sequences currently in flight.
    pub fn active(&self) -> usize {
        self.eng.slot_active.len()
    }

    /// Read-only view of the underlying engine (stats, EAMC, memory sim).
    pub fn engine(&self) -> &SimEngine {
        self.eng
    }

    /// Set the prefill token budget of the *next* step: at most `limit`
    /// prompt tokens are executed across all prefilling slots, granted
    /// greedily in slot order (`u32::MAX` = unlimited — the historical
    /// whole-prompt iteration 0, which is bitwise identical to the
    /// pre-chunking engine). A prompt longer than its grant continues in
    /// the `Prefilling(consumed..)` state at the next iteration boundary;
    /// prefilling slots granted zero tokens are reported in
    /// [`StepResult::stalled`] and make no progress. Decode tokens are
    /// never budgeted — chunking exists to protect them.
    pub fn set_prefill_limit(&mut self, limit: u32) {
        assert!(limit >= 1, "prefill limit must be >= 1 (u32::MAX = unlimited)");
        self.eng.prefill_limit = limit;
    }

    /// Override `slot`'s prefill-budget precedence: the per-iteration
    /// chunk budget is granted in ascending `(rank, slot)` order. Defaults
    /// to a monotone admission counter (FCFS — an older mid-prefill
    /// sequence is never starved by newer arrivals recycling lower slot
    /// ids); a class-aware scheduler sets `rank = (tier-inverted, seq)` so
    /// higher-priority prefills drain first. Irrelevant while the budget
    /// is unlimited (everyone gets their full prompt).
    pub fn set_prefill_rank(&mut self, slot: usize, rank: u64) {
        self.eng.slot_rank[slot] = rank;
    }

    /// Advance virtual time across an idle gap (no arrivals, no active
    /// slots). Queued and in-flight transfers keep draining, exactly as
    /// they do between static batches.
    pub fn idle_until(&mut self, t: f64) {
        self.eng.idle_until(t);
        if t > self.t {
            self.t = t;
        }
    }

    /// Admit a sequence into the lowest free slot at the current iteration
    /// boundary; returns the slot id. `ext_id` is the caller's handle
    /// (e.g. the request index) and is echoed back in
    /// [`StepResult::executed`] / [`StepResult::finished`]. Only geometry
    /// scalars are taken from `seq`; the routing trace itself is fetched
    /// per step.
    ///
    /// Admission into an **empty** session is a batch boundary: stale
    /// queued prefetches and the combined batch EAM are dropped — the same
    /// reset `run_batch` performs after idling to its start time, which is
    /// what keeps the single-slot continuous replay bitwise identical to
    /// the static path.
    // moelint: hot
    pub fn admit(&mut self, ext_id: u64, seq: &SequenceActivation) -> usize {
        assert_ne!(ext_id, FREE_SLOT, "external id {FREE_SLOT} is reserved");
        assert!(seq.iterations() > 0, "cannot admit an empty sequence");
        let eng = &mut *self.eng;
        reset_if_empty(eng);
        let slot = alloc_slot(eng);
        eng.slot_occupant[slot] = ext_id;
        eng.slot_iter[slot] = 0;
        eng.slot_total[slot] = seq.iterations() as u32;
        eng.slot_prompt[slot] = seq.prompt_len as u32;
        eng.slot_prefill_done[slot] = 0;
        eng.slot_chunk[slot] = 0;
        eng.slot_rank[slot] = eng.next_rank;
        eng.next_rank += 1;
        // Alg. 1 step 2: fresh EAM, matcher synced to the current build
        eng.cur_eams[slot].clear();
        if self.use_matcher {
            eng.matchers[slot].attach(&eng.eamc);
        }
        eng.seq_demands[slot] = 0;
        eng.seq_hits[slot] = 0;
        let pos = eng.slot_active.partition_point(|&s| (s as usize) < slot);
        eng.slot_active.insert(pos, slot as u32);
        self.admitted = self.admitted.max(slot + 1);
        slot
    }

    /// Voluntarily preempt the sequence occupying `slot` at the current
    /// iteration boundary, saving its position, traced EAM and recall
    /// tallies into `out` (buffers recycled via [`Eam::copy_from`]). The
    /// sequence is *suspended*, not finished: no EAMC feedback is given,
    /// its counts leave the combined batch EAM so cache decisions track
    /// only live work, and its slot frees up for the next admission.
    /// Continue it later with [`BatchSession::admit_resumed`].
    ///
    /// Only meaningful under [`FeedbackMode::Immediate`] (the deferred
    /// static path has fixed membership by contract).
    pub fn evict(&mut self, slot: usize, out: &mut PreemptedSeq) {
        assert_eq!(
            self.feedback,
            FeedbackMode::Immediate,
            "evict requires FeedbackMode::Immediate"
        );
        let eng = &mut *self.eng;
        let pos = eng
            .slot_active
            .iter()
            .position(|&s| s as usize == slot)
            .expect("evict: slot not active");
        eng.slot_active.remove(pos);
        out.ext_id = eng.slot_occupant[slot];
        out.iter = eng.slot_iter[slot];
        out.total = eng.slot_total[slot];
        out.prompt = eng.slot_prompt[slot];
        out.prefill_done = eng.slot_prefill_done[slot];
        out.demands = eng.seq_demands[slot];
        out.hits = eng.seq_hits[slot];
        out.eam.copy_from(&eng.cur_eams[slot]);
        eng.batch_eam.subtract(&eng.cur_eams[slot]);
        eng.slot_occupant[slot] = FREE_SLOT;
        eng.cancel_owned_prefetches(slot);
    }

    /// Continue a previously [`BatchSession::evict`]ed sequence: admits it
    /// into the lowest free slot, restores its traced EAM, iteration
    /// position and recall tallies, replays the matcher accumulators
    /// against the current EAMC build, and re-adds its counts to the
    /// combined batch EAM. Returns the slot id. The next
    /// [`BatchSession::step`] executes the iteration it was suspended at —
    /// the per-token expert demands are identical to an uninterrupted run
    /// (pinned by the preempt/resume differential test).
    pub fn admit_resumed(&mut self, saved: &PreemptedSeq) -> usize {
        assert_eq!(
            self.feedback,
            FeedbackMode::Immediate,
            "admit_resumed requires FeedbackMode::Immediate"
        );
        assert_ne!(saved.ext_id, FREE_SLOT, "resume of a vacant holder");
        assert!(
            saved.iter < saved.total,
            "resume of a finished sequence ({} >= {})",
            saved.iter,
            saved.total
        );
        let eng = &mut *self.eng;
        reset_if_empty(eng);
        let slot = alloc_slot(eng);
        eng.slot_occupant[slot] = saved.ext_id;
        eng.slot_iter[slot] = saved.iter;
        eng.slot_total[slot] = saved.total;
        eng.slot_prompt[slot] = saved.prompt;
        eng.slot_prefill_done[slot] = saved.prefill_done;
        eng.slot_chunk[slot] = 0;
        // FCFS default: a resumed prefill re-queues for budget at the back;
        // class-aware schedulers re-rank it right after this call
        eng.slot_rank[slot] = eng.next_rank;
        eng.next_rank += 1;
        eng.cur_eams[slot].copy_from(&saved.eam);
        eng.seq_demands[slot] = saved.demands;
        eng.seq_hits[slot] = saved.hits;
        eng.batch_eam.add(&eng.cur_eams[slot]);
        if self.use_matcher {
            eng.replay_matcher(slot);
        }
        let pos = eng.slot_active.partition_point(|&s| (s as usize) < slot);
        eng.slot_active.insert(pos, slot as u32);
        self.admitted = self.admitted.max(slot + 1);
        slot
    }

    /// Detach the session from the engine, returning a token that
    /// [`SimEngine::resume_session`] re-opens later. No feedback runs and
    /// nothing is reset — the suspended session is still logically open;
    /// the engine clock stays at the session's boundary (it already is
    /// after every step).
    pub fn suspend(self) -> SessionState {
        self.eng.clock = self.t;
        SessionState {
            feedback: self.feedback,
            use_matcher: self.use_matcher,
            t: self.t,
            admitted: self.admitted,
        }
    }

    /// Execute one forward iteration for every active slot (the loop body
    /// of Alg. 1, batch-generalized). `seq_of` maps an external id back to
    /// its routing trace. Returns `false` (touching nothing) when no slot
    /// is active. Finished sequences retire at the iteration's end; with
    /// [`FeedbackMode::Immediate`] their recall feeds the EAMC right away,
    /// their counts leave the batch EAM and their slot frees up.
    // moelint: hot
    pub fn step<'s, F>(&mut self, seq_of: F, out: &mut StepResult) -> bool
    where
        F: Fn(u64) -> &'s SequenceActivation,
    {
        let eng = &mut *self.eng;
        if eng.slot_active.is_empty() {
            return false;
        }
        out.clear();
        out.t_start = self.t;
        let mut t = self.t;
        let (n_layers, n_experts) = (eng.spec.n_layers, eng.spec.experts_per_layer);
        let use_matcher = self.use_matcher;

        // Grant this step's prefill budget greedily in ascending
        // `(slot_rank, slot)` order — FCFS by default, class-ranked under
        // priority scheduling — NOT slot order (slot ids recycle, so a new
        // prompt in a lower slot would otherwise steal the budget from an
        // older mid-prefill sequence every iteration). A prefilling slot
        // takes `min(remaining prompt, remaining budget)` tokens; with the
        // default unlimited budget every prompt runs whole (the historical
        // iteration 0, bitwise-preserved). A prefilling slot granted zero
        // tokens stalls — it stays active but executes nothing this
        // iteration. Decode slots always run one token, unbudgeted.
        let mut grant_scratch = std::mem::take(&mut eng.grant_scratch);
        grant_scratch.clear();
        for i in 0..eng.slot_active.len() {
            let slot = eng.slot_active[i] as usize;
            if eng.slot_iter[slot] == 0 {
                eng.slot_chunk[slot] = 0;
                if eng.slot_prefill_done[slot] < eng.slot_prompt[slot] {
                    let key = (eng.slot_rank[slot], slot);
                    let pos = grant_scratch
                        .partition_point(|&s| (eng.slot_rank[s as usize], s as usize) < key);
                    grant_scratch.insert(pos, slot as u32);
                }
            }
        }
        let mut prefill_left = eng.prefill_limit;
        for idx in 0..grant_scratch.len() {
            let slot = grant_scratch[idx] as usize;
            let rem = eng.slot_prompt[slot] - eng.slot_prefill_done[slot];
            let k = rem.min(prefill_left);
            prefill_left -= k;
            eng.slot_chunk[slot] = k;
        }
        eng.grant_scratch = grant_scratch;
        // emit executed/stalled in slot order — the deterministic step
        // order every downstream consumer (and the bitwise pins) sees
        let mut batch_tokens = 0u32;
        for i in 0..eng.slot_active.len() {
            let slot = eng.slot_active[i] as usize;
            if eng.slot_iter[slot] == 0 {
                if slot_stalled(eng, slot) {
                    out.stalled.push(eng.slot_occupant[slot]);
                    continue;
                }
                out.executed.push(eng.slot_occupant[slot]);
                batch_tokens += eng.slot_chunk[slot];
            } else {
                out.executed.push(eng.slot_occupant[slot]);
                batch_tokens += 1;
            }
        }
        debug_assert!(
            !out.executed.is_empty(),
            "a limit >= 1 always grants some prefilling slot something"
        );

        for l in 0..n_layers {
            // ---- dense part of the layer (attention etc.)
            t += eng.compute.dense_time(&eng.spec, batch_tokens);

            // ---- Alg. 1 step 5: route, steps 6-7: update cur_eam.
            // The per-layer union goes into flat reusable scratch
            // (expert-indexed token totals + touching-slot lists);
            // only the previous layer's active entries are cleared.
            for &e in &eng.union_active {
                eng.union_tokens[e as usize] = 0;
                eng.union_seqs[e as usize].clear();
            }
            eng.union_active.clear();
            for i in 0..eng.slot_active.len() {
                let slot = eng.slot_active[i] as usize;
                if slot_stalled(eng, slot) {
                    continue; // zero prefill grant: nothing routes this step
                }
                let s = seq_of(eng.slot_occupant[slot]);
                let iter = eng.slot_iter[slot] as usize;
                // a prefilling slot routes only its chunk's proportional
                // share of each row cell; the full-range split equals the
                // stored counts, so the unlimited path records identically
                let (done, k, prompt) = (
                    eng.slot_prefill_done[slot],
                    eng.slot_chunk[slot],
                    eng.slot_prompt[slot],
                );
                for &(e, c) in &s.routes[iter][l] {
                    let c = if iter == 0 {
                        prefill_chunk_tokens(c, done, k, prompt)
                    } else {
                        c
                    };
                    if c == 0 {
                        continue; // this chunk carries none of the expert's tokens
                    }
                    eng.cur_eams[slot].record(l, e as usize, c);
                    eng.batch_eam.record(l, e as usize, c);
                    eng.predictor.observe_route(l, e as usize, c);
                    if use_matcher {
                        eng.matchers[slot].record(eng.eamc.index(), l, e as usize, c);
                    }
                    if eng.union_seqs[e as usize].is_empty() {
                        eng.union_active.push(e);
                    }
                    eng.union_tokens[e as usize] += c;
                    eng.union_seqs[e as usize].push(slot as u32);
                }
            }
            // keep the former BTreeMap's deterministic expert order
            eng.union_active.sort_unstable();

            // ---- Alg. 1 step 8: resubmit prefetch priorities
            for i in 0..eng.slot_active.len() {
                let slot = eng.slot_active[i] as usize;
                if slot_stalled(eng, slot) {
                    continue; // no new routing observed: keep the standing prediction
                }
                let iter = eng.slot_iter[slot] as usize;
                if eng.predictor.should_predict(l, iter) {
                    let mut buf = std::mem::take(&mut eng.pred_buf);
                    let matcher = if use_matcher {
                        Some(&eng.matchers[slot])
                    } else {
                        None
                    };
                    eng.predictor
                        .predict(&eng.cur_eams[slot], &eng.eamc, matcher, l, &mut buf);
                    let ctx = CacheCtx::new(&eng.batch_eam, n_layers);
                    for &(key, prio) in buf.iter() {
                        // Only experts with a positive predicted
                        // activation ratio are worth PCIe bandwidth;
                        // zero-ratio entries carry only the EPSILON
                        // term and would be pure thrash traffic
                        // (this is how the paper's system "reduces
                        // prefetching traffic by over 7GB of 13GB").
                        if prio <= crate::prefetch::EPSILON {
                            continue;
                        }
                        let p = if eng.cfg.priority_enabled { prio } else { 0.5 };
                        eng.sim.submit_prefetch(key, p, SimTime::from_f64(t), &ctx);
                        if eng.cfg.cancel_retired_prefetch {
                            // last predictor wins: retirement cancels only
                            // keys nobody re-predicted since
                            eng.prefetch_owner[key.flat(n_experts)] = slot as u32 + 1;
                        }
                    }
                    eng.pred_buf = buf;
                }
            }

            // ---- ZeRO semantics: the whole layer's parameters must be
            // resident before execution, activated or not.
            if eng.cfg.fetch_all_experts {
                for e in 0..n_experts {
                    if !eng.union_seqs[e].is_empty() {
                        continue; // demanded (and counted) below
                    }
                    let key = ExpertKey::new(l, e);
                    let ctx = CacheCtx::new(&eng.batch_eam, n_layers);
                    let ready = eng.sim.demand(key, SimTime::from_f64(t), &ctx).to_f64();
                    t = ready;
                }
            }

            // ---- Alg. 1 steps 9-13: execute experts (on-demand jumps)
            let mut exec_total = 0.0f64;
            for idx in 0..eng.union_active.len() {
                let e = eng.union_active[idx];
                let tokens = eng.union_tokens[e as usize];
                let key = ExpertKey::new(l, e as usize);
                let ctx = CacheCtx::new(&eng.batch_eam, n_layers);
                let on_gpu_before = eng.sim.is_on_gpu(key);
                let ready = eng.sim.demand(key, SimTime::from_f64(t), &ctx).to_f64();
                out.demands += 1;
                out.stalls.push(ready - t);
                for &slot in &eng.union_seqs[e as usize] {
                    eng.seq_demands[slot as usize] += 1;
                    if on_gpu_before {
                        eng.seq_hits[slot as usize] += 1;
                    }
                }
                if on_gpu_before {
                    out.gpu_hits += 1;
                }
                t = ready;
                exec_total += eng.compute.expert_time(&eng.spec, tokens);
            }
            // Distinct experts run in parallel across expert-parallel
            // nodes (Fig. 13); single node executes them serially.
            match &eng.cluster {
                Some(cm) => {
                    t += exec_total / cm.parallel_expert_factor(eng.union_active.len());
                    t += cm.all_to_all_time(&eng.spec, batch_tokens);
                }
                None => t += exec_total,
            }
        }

        out.t_end = t;
        self.t = t;
        eng.clock = t;

        // ---- iteration boundary: advance prefill positions and local
        // iterations, retire finished sequences at their true finish
        // iteration. A slot whose prompt is only partially consumed stays
        // on iteration 0 in the `Prefilling(consumed..)` state.
        let mut i = 0;
        while i < eng.slot_active.len() {
            let slot = eng.slot_active[i] as usize;
            if eng.slot_iter[slot] == 0 {
                if slot_stalled(eng, slot) {
                    i += 1; // zero grant: no progress this iteration
                    continue;
                }
                eng.slot_prefill_done[slot] += eng.slot_chunk[slot];
                if eng.slot_prefill_done[slot] < eng.slot_prompt[slot] {
                    out.prefilling.push(eng.slot_occupant[slot]);
                    i += 1; // mid-prefill: iteration 0 is not done yet
                    continue;
                }
            }
            eng.slot_iter[slot] += 1;
            if eng.slot_iter[slot] >= eng.slot_total[slot] {
                out.finished.push(eng.slot_occupant[slot]);
                eng.slot_active.remove(i);
                if self.feedback == FeedbackMode::Immediate {
                    // §4.3 drift feedback at retirement; the slot's counts
                    // leave the batch EAM so cache decisions track only
                    // the live working set, and the slot frees up.
                    let recall = if eng.seq_demands[slot] == 0 {
                        1.0
                    } else {
                        eng.seq_hits[slot] as f64 / eng.seq_demands[slot] as f64
                    };
                    let rebuilt = eng
                        .eamc
                        .observe(&eng.cur_eams[slot], recall >= eng.cfg.well_predicted_recall);
                    eng.batch_eam.subtract(&eng.cur_eams[slot]);
                    eng.slot_occupant[slot] = FREE_SLOT;
                    eng.cancel_owned_prefetches(slot);
                    if rebuilt && use_matcher {
                        eng.resync_active_matchers();
                    }
                }
                continue; // removal shifted the next slot into position i
            }
            i += 1;
        }
        true
    }

    /// Close the session: deferred-mode recall feedback (every admitted
    /// slot, in slot order — the static `run_batch` observe order) and the
    /// engine-clock handoff. Returns the session's finish time.
    pub fn finish(self) -> f64 {
        let eng = self.eng;
        if self.feedback == FeedbackMode::Deferred {
            for slot in 0..self.admitted {
                let recall = if eng.seq_demands[slot] == 0 {
                    1.0
                } else {
                    eng.seq_hits[slot] as f64 / eng.seq_demands[slot] as f64
                };
                eng.eamc
                    .observe(&eng.cur_eams[slot], recall >= eng.cfg.well_predicted_recall);
            }
        }
        eng.clock = self.t;
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheKind;
    use crate::memory::{Link, Tier};
    use crate::workload::{DatasetPreset, Workload};

    fn spec() -> ModelSpec {
        ModelSpec::preset("switch-base-32").unwrap()
    }

    fn tier(spec: &ModelSpec, gpu: usize, kind: CacheKind) -> TierConfig {
        TierConfig {
            gpu_capacity: gpu,
            dram_capacity: spec.total_experts() / 2,
            backing: Tier::Ssd,
            ssd_to_dram: Link::new(6.0, 50e-6),
            dram_to_gpu: Link::new(32.0, 10e-6),
            n_gpus: 1,
            demand_extra_latency: SimTime::ZERO,
            demand_bw_factor: 1.0,
            gpu_policy: kind,
            dram_policy: kind,
            oracle_trace: Vec::new(),
            activation_terms: (true, true),
            prefetch_gpu_budget: 0.5,
        }
    }

    fn workload(spec: &ModelSpec, seed: u64) -> Workload {
        // 8-task preset: a small EAMC represents it well, keeping the test
        // in the paper's intended operating regime (Fig. 12).
        Workload::new(spec, DatasetPreset::by_name("translation").unwrap(), seed)
    }

    fn eamc_for(spec: &ModelSpec, w: &mut Workload, n: usize, cap: usize) -> Eamc {
        let ds = w.gen_eam_dataset(n);
        Eamc::construct(cap, &ds, 11)
    }

    #[test]
    fn batch_completes_and_advances_clock() {
        let s = spec();
        let mut w = workload(&s, 1);
        let eamc = eamc_for(&s, &mut w, 40, 10);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seq = w.gen_sequence();
        let iters = seq.iterations();
        let r = eng.run_batch(&[seq], 0.0);
        assert_eq!(r.token_latencies.len(), iters);
        assert!(r.finish > 0.0);
        assert_eq!(eng.now(), r.finish);
        assert!(r.token_latencies.iter().all(|&l| l > 0.0));
    }

    #[test]
    fn prefetching_beats_no_prefetching() {
        let s = spec();
        let run = |kind: PredictorKind| -> f64 {
            let mut w = workload(&s, 2);
            let eamc = eamc_for(&s, &mut w, 60, 12);
            let mut eng = SimEngine::new(
                s.clone(),
                tier(&s, 144, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig {
                    predictor: kind,
                    ..Default::default()
                },
            );
            let mut total = 0.0;
            let mut n = 0;
            for _ in 0..8 {
                let seq = w.gen_sequence();
                let r = eng.run_batch(&[seq], eng.now());
                total += r.token_latencies.iter().sum::<f64>();
                n += r.token_latencies.len();
            }
            total / n as f64
        };
        let aware = run(PredictorKind::ActivationAware { refine: true });
        let none = run(PredictorKind::NoPrefetch);
        assert!(
            aware < none,
            "activation-aware {aware} must beat on-demand {none}"
        );
    }

    #[test]
    fn activation_aware_beats_topk_on_recall() {
        let s = spec();
        let run = |kind: PredictorKind| -> f64 {
            let mut w = workload(&s, 3);
            let eamc = eamc_for(&s, &mut w, 60, 16);
            let mut eng = SimEngine::new(
                s.clone(),
                tier(&s, 32, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig {
                    predictor: kind,
                    ..Default::default()
                },
            );
            let mut hits = 0;
            let mut demands = 0;
            for _ in 0..10 {
                let seq = w.gen_sequence();
                let r = eng.run_batch(&[seq], eng.now());
                hits += r.gpu_hits;
                demands += r.demands;
            }
            hits as f64 / demands as f64
        };
        let aware = run(PredictorKind::ActivationAware { refine: true });
        let topk = run(PredictorKind::TopK { k: 4 });
        assert!(aware > topk, "aware recall {aware} vs topk {topk}");
    }

    #[test]
    fn batch_of_many_sequences_counts_all_tokens() {
        let s = spec();
        let mut w = workload(&s, 4);
        let eamc = eamc_for(&s, &mut w, 30, 8);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seqs: Vec<_> = (0..4).map(|_| w.gen_sequence()).collect();
        let max_iters = seqs.iter().map(|x| x.iterations()).max().unwrap();
        let r = eng.run_batch(&seqs, 0.0);
        assert_eq!(r.token_latencies.len(), max_iters);
        assert_eq!(r.seq_recalls.len(), 4);
    }

    #[test]
    fn idle_until_moves_clock_forward_only() {
        let s = spec();
        let mut w = workload(&s, 5);
        let eamc = eamc_for(&s, &mut w, 10, 4);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 16, CacheKind::Lru),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        eng.idle_until(5.0);
        assert_eq!(eng.now(), 5.0);
        eng.idle_until(1.0);
        assert_eq!(eng.now(), 5.0);
    }

    #[test]
    fn demand_trace_covers_all_routed_experts() {
        let s = spec();
        let mut w = workload(&s, 6);
        let seq = w.gen_sequence();
        let trace = SimEngine::demand_trace(&s, &[vec![seq.clone()]]);
        let eam = seq.to_eam(s.n_layers, s.experts_per_layer);
        let distinct: usize = (0..s.n_layers)
            .map(|l| (0..s.experts_per_layer).filter(|&e| eam.count(l, e) > 0).count())
            .sum();
        let mut uniq: Vec<ExpertKey> = trace.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), distinct);
        assert!(trace.len() >= distinct, "reuse appears as repeats");
    }

    #[test]
    fn empty_result_recall_conventions_agree() {
        // nothing demanded ⇒ nothing missed: both the batch-level and the
        // per-sequence accounting must say 1.0 (they used to disagree).
        let r = BatchResult::default();
        assert_eq!(r.recall(), 1.0);
        // a sequence with zero demands (everything warm) reports recall 1.0
        let s = spec();
        let mut w = workload(&s, 9);
        let eamc = eamc_for(&s, &mut w, 20, 6);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, s.total_experts(), CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seq = w.gen_sequence();
        let out = eng.run_batch(&[seq], 0.0);
        for &r in &out.seq_recalls {
            assert!((0.0..=1.0).contains(&r));
        }
        assert!((0.0..=1.0).contains(&out.recall()));
    }

    #[test]
    fn run_batch_into_reuses_buffers_and_matches_run_batch() {
        let s = spec();
        let mut w = workload(&s, 10);
        let eamc = eamc_for(&s, &mut w, 30, 8);
        let make = |eamc: Eamc| {
            SimEngine::new(
                s.clone(),
                tier(&s, 64, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            )
        };
        let seqs: Vec<_> = (0..3).map(|_| w.gen_sequence()).collect();
        // identical engines, identical batches: both entry points agree
        let mut w2 = workload(&s, 10);
        let eamc2 = eamc_for(&s, &mut w2, 30, 8);
        let mut a = make(eamc2);
        let mut b = {
            let mut w3 = workload(&s, 10);
            make(eamc_for(&s, &mut w3, 30, 8))
        };
        let ra = a.run_batch(&seqs, 0.0);
        let mut rb = BatchResult::default();
        b.run_batch_into(&seqs, 0.0, &mut rb);
        assert_eq!(ra.demands, rb.demands);
        assert_eq!(ra.gpu_hits, rb.gpu_hits);
        assert_eq!(ra.token_latencies, rb.token_latencies);
        // the same result struct can be reused across batches
        let more: Vec<_> = (0..2).map(|_| w.gen_sequence()).collect();
        b.run_batch_into(&more, b.now(), &mut rb);
        assert_eq!(rb.seq_recalls.len(), 2);
    }

    #[test]
    fn session_admits_and_retires_at_iteration_boundaries() {
        let s = spec();
        let mut w = workload(&s, 12);
        let eamc = eamc_for(&s, &mut w, 30, 8);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seqs: Vec<_> = (0..3).map(|_| w.gen_sequence()).collect();
        let lookup = |id: u64| &seqs[id as usize];
        let mut step = StepResult::default();
        let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);
        assert_eq!(session.admit(0, &seqs[0]), 0);
        assert_eq!(session.admit(1, &seqs[1]), 1);
        assert!(session.step(&lookup, &mut step));
        assert_eq!(step.executed, vec![0, 1]);
        assert!(step.t_end > step.t_start);
        // run to completion; the third sequence joins mid-flight in a
        // recycled slot the moment one of the first two retires
        let mut finished: Vec<u64> = step.finished.clone();
        let mut late_slot = None;
        loop {
            if !session.step(&lookup, &mut step) {
                break;
            }
            finished.extend_from_slice(&step.finished);
            if late_slot.is_none() && !finished.is_empty() {
                late_slot = Some(session.admit(2, &seqs[2]));
            }
        }
        assert!(late_slot.expect("third sequence admitted") < 2, "retired slot recycled");
        finished.sort_unstable();
        assert_eq!(finished, vec![0, 1, 2], "every sequence retires exactly once");
        let t = session.finish();
        assert_eq!(eng.now(), t);
        assert!(t > 0.0);
    }

    #[test]
    fn immediate_feedback_observes_at_retirement() {
        let s = spec();
        let mut w = workload(&s, 13);
        let eamc = eamc_for(&s, &mut w, 20, 6);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seq = w.gen_sequence();
        let iters = seq.iterations();
        let lookup = |_id: u64| &seq;
        let mut step = StepResult::default();
        let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);
        let before = session.engine().eamc().stats().observed_since_build;
        session.admit(7, &seq);
        let mut n = 0;
        while session.step(&lookup, &mut step) {
            n += 1;
        }
        assert_eq!(n, iters, "one step per iteration");
        assert_eq!(
            session.engine().eamc().stats().observed_since_build,
            before + 1,
            "retirement must feed the EAMC before the session finishes"
        );
        session.finish();
    }

    #[test]
    fn evict_saves_state_and_resume_continues_identically() {
        let s = spec();
        let mut w = workload(&s, 21);
        let mk = |w: &mut Workload| {
            let eamc = {
                let ds = w.gen_eam_dataset(30);
                Eamc::construct(8, &ds, 11)
            };
            SimEngine::new(
                s.clone(),
                tier(&s, 64, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            )
        };
        let mut eng_a = mk(&mut w);
        let mut w2 = workload(&s, 21);
        let mut eng_b = mk(&mut w2);
        let seq = w.gen_sequence();
        let iters = seq.iterations();
        assert!(iters >= 2, "need a multi-iteration sequence");
        let lookup = |_id: u64| &seq;
        let mut step = StepResult::default();

        // reference: uninterrupted run, per-iteration demand counts
        let mut want = Vec::new();
        let mut sa = eng_a.begin_session(0.0, FeedbackMode::Immediate);
        sa.admit(0, &seq);
        while sa.step(&lookup, &mut step) {
            want.push(step.demands);
        }
        sa.finish();

        // interrupted run: evict mid-flight, resume, finish
        let cut = iters / 2;
        let mut got = Vec::new();
        let mut sb = eng_b.begin_session(0.0, FeedbackMode::Immediate);
        sb.admit(0, &seq);
        let mut saved = PreemptedSeq::new(s.n_layers, s.experts_per_layer);
        for _ in 0..cut {
            assert!(sb.step(&lookup, &mut step));
            got.push(step.demands);
        }
        sb.evict(0, &mut saved);
        assert_eq!(saved.ext_id(), 0);
        assert_eq!(saved.iterations_done(), cut as u32);
        assert_eq!(sb.active(), 0, "evicted slot must free");
        // the saved EAM is exactly the prefix trace
        let mut prefix = crate::trace::Eam::new(s.n_layers, s.experts_per_layer);
        for it in 0..cut {
            for l in 0..s.n_layers {
                for &(e, c) in &seq.routes[it][l] {
                    prefix.record(l, e as usize, c);
                }
            }
        }
        assert_eq!(saved.eam(), &prefix, "evict must save the traced EAM");
        let before = sb.engine().eamc().stats().observed_since_build;
        let slot = sb.admit_resumed(&saved);
        assert_eq!(slot, 0, "freed slot is recycled");
        while sb.step(&lookup, &mut step) {
            got.push(step.demands);
        }
        assert_eq!(
            sb.engine().eamc().stats().observed_since_build,
            before + 1,
            "resumed sequence still feeds the EAMC exactly once, at retirement"
        );
        sb.finish();
        assert_eq!(
            got, want,
            "per-iteration expert demands must match the uninterrupted run"
        );
    }

    #[test]
    fn unlimited_prefill_limit_is_identical_to_default() {
        // an explicit u32::MAX budget must replay the historical
        // whole-prompt iteration 0 bitwise (the chunked-scheduler-with-∞ ==
        // continuous pin rests on this)
        let s = spec();
        let run = |explicit: bool| -> (Vec<u64>, Vec<u64>) {
            let mut w = workload(&s, 31);
            let eamc = eamc_for(&s, &mut w, 30, 8);
            let mut eng = SimEngine::new(
                s.clone(),
                tier(&s, 64, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            );
            let seq = w.gen_sequence();
            let lookup = |_id: u64| &seq;
            let mut step = StepResult::default();
            let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);
            session.admit(0, &seq);
            let mut demands = Vec::new();
            let mut lat_bits = Vec::new();
            loop {
                if explicit {
                    session.set_prefill_limit(u32::MAX);
                }
                if !session.step(&lookup, &mut step) {
                    break;
                }
                assert!(step.prefilling.is_empty() && step.stalled.is_empty());
                demands.push(step.demands);
                lat_bits.push(step.latency().to_bits());
            }
            session.finish();
            (demands, lat_bits)
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn chunked_prefill_splits_iteration_zero_and_conserves_row_sums() {
        let s = spec();
        let mut w = workload(&s, 32);
        let eamc = eamc_for(&s, &mut w, 30, 8);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seq = w.gen_sequence();
        let prompt = seq.prompt_len as u32;
        assert!(prompt >= 8, "preset prompts are >= 16");
        let chunk = 5u32;
        let n_chunks = ((prompt + chunk - 1) / chunk) as usize; // ceil (MSRV < div_ceil)
        let lookup = |_id: u64| &seq;
        let mut step = StepResult::default();
        let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);
        session.admit(0, &seq);
        let mut steps = 0usize;
        let mut prefill_steps = 0usize;
        loop {
            session.set_prefill_limit(chunk);
            if !session.step(&lookup, &mut step) {
                break;
            }
            steps += 1;
            if step.prefilling.contains(&0) {
                prefill_steps += 1;
                assert!(step.finished.is_empty(), "mid-prefill never retires");
            }
        }
        // every non-final chunk reports `prefilling`; the final chunk and
        // all decode iterations do not
        assert_eq!(prefill_steps, n_chunks - 1);
        assert_eq!(steps, n_chunks + seq.iterations() - 1);
        let t = session.finish();
        assert_eq!(eng.now(), t);
        // the accumulated per-sequence trace equals the whole-prompt EAM:
        // the proportional split conserved every row cell
        assert_eq!(
            eng.cur_eams[0],
            seq.to_eam(s.n_layers, s.experts_per_layer),
            "chunked prefill must record exactly the sequence's EAM"
        );
    }

    #[test]
    fn shared_prefill_budget_stalls_later_slots_until_granted() {
        let s = spec();
        let mut w = workload(&s, 33);
        let eamc = eamc_for(&s, &mut w, 30, 8);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let a = w.gen_sequence();
        let b = w.gen_sequence();
        let seqs = [a, b];
        let lookup = |id: u64| &seqs[id as usize];
        let mut step = StepResult::default();
        let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);
        session.admit(0, &seqs[0]);
        session.admit(1, &seqs[1]);
        // budget smaller than slot 0's prompt: slot 1 gets nothing yet
        session.set_prefill_limit(4);
        assert!(session.step(&lookup, &mut step));
        assert_eq!(step.executed, vec![0]);
        assert_eq!(step.stalled, vec![1], "slot 1 must report the stall");
        assert_eq!(step.prefilling, vec![0]);
        // run everything dry; both sequences must still complete
        let mut finished = Vec::new();
        loop {
            session.set_prefill_limit(4);
            if !session.step(&lookup, &mut step) {
                break;
            }
            finished.extend_from_slice(&step.finished);
        }
        finished.sort_unstable();
        assert_eq!(finished, vec![0, 1], "stalled prefills must recover");
        session.finish();
    }

    #[test]
    fn prefill_rank_overrides_slot_order_for_budget_grants() {
        // slot ids recycle, so grant order must follow rank, not slot id:
        // demoting slot 0 hands the whole budget to slot 1
        let s = spec();
        let mut w = workload(&s, 35);
        let eamc = eamc_for(&s, &mut w, 30, 8);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let a = w.gen_sequence();
        let b = w.gen_sequence();
        let seqs = [a, b];
        let lookup = |id: u64| &seqs[id as usize];
        let mut step = StepResult::default();
        let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);
        session.admit(0, &seqs[0]); // default FCFS rank 0
        session.admit(1, &seqs[1]); // default FCFS rank 1
        session.set_prefill_rank(0, u64::MAX); // demote the older slot
        session.set_prefill_limit(4);
        assert!(session.step(&lookup, &mut step));
        assert_eq!(step.executed, vec![1], "ranked-first slot gets the budget");
        assert_eq!(step.stalled, vec![0], "demoted slot stalls despite lower id");
        session.finish();
    }

    #[test]
    fn mid_prefill_evict_and_resume_continues_identically() {
        // chunked analogue of the preempt/resume differential: evicting a
        // sequence halfway through its *prefill* and resuming later must
        // replay the remaining chunks' expert demands exactly
        let s = spec();
        let chunk = 5u32;
        let run = |interrupt: bool, seed: u64| -> Vec<u64> {
            let mut w = workload(&s, seed);
            let eamc = eamc_for(&s, &mut w, 30, 8);
            let mut eng = SimEngine::new(
                s.clone(),
                tier(&s, 64, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            );
            let seq = w.gen_sequence();
            let lookup = |_id: u64| &seq;
            let mut step = StepResult::default();
            let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);
            session.admit(0, &seq);
            let mut saved = PreemptedSeq::new(s.n_layers, s.experts_per_layer);
            let mut demands = Vec::new();
            // two prefill chunks, then (optionally) evict mid-prefill
            for _ in 0..2 {
                session.set_prefill_limit(chunk);
                assert!(session.step(&lookup, &mut step));
                demands.push(step.demands);
            }
            if interrupt {
                session.evict(0, &mut saved);
                assert_eq!(saved.ext_id(), 0);
                assert_eq!(saved.iterations_done(), 0, "still on iteration 0");
                let slot = session.admit_resumed(&saved);
                assert_eq!(slot, 0);
            }
            loop {
                session.set_prefill_limit(chunk);
                if !session.step(&lookup, &mut step) {
                    break;
                }
                demands.push(step.demands);
            }
            session.finish();
            demands
        };
        assert_eq!(
            run(false, 34),
            run(true, 34),
            "mid-prefill preemption must not change per-step expert demands"
        );
    }

    #[test]
    fn prefill_chunk_tokens_full_range_is_identity() {
        for (c, prompt) in [(0u32, 7u32), (3, 7), (7, 7), (123, 456)] {
            assert_eq!(prefill_chunk_tokens(c, 0, prompt, prompt), c);
        }
        // telescoping: any partition sums back to c
        let (c, prompt) = (17u32, 40u32);
        let mut total = 0;
        let mut done = 0;
        for k in [3u32, 10, 1, 26] {
            total += prefill_chunk_tokens(c, done, k, prompt);
            done += k;
        }
        assert_eq!(done, prompt);
        assert_eq!(total, c);
    }

    #[test]
    fn retirement_cancels_owned_queued_prefetches_when_enabled() {
        let s = spec();
        let run = |cancel: bool| -> usize {
            let mut w = workload(&s, 22);
            let eamc = eamc_for(&s, &mut w, 30, 8);
            // tiny GPU cache + narrow prefetch budget: predictions pile up
            // in the queues instead of transferring immediately
            let mut t = tier(&s, 8, CacheKind::Activation);
            t.prefetch_gpu_budget = 0.2;
            let mut eng = SimEngine::new(
                s.clone(),
                t,
                eamc,
                ComputeModel::a5000(),
                EngineConfig {
                    cancel_retired_prefetch: cancel,
                    ..Default::default()
                },
            );
            let seq = w.gen_sequence();
            let lookup = |_id: u64| &seq;
            let mut step = StepResult::default();
            let mut session = eng.begin_session(0.0, FeedbackMode::Immediate);
            session.admit(0, &seq);
            while session.step(&lookup, &mut step) {}
            // the sequence just retired; anything still queued is dead
            // traffic its retirement could have cancelled
            let queued = session.engine().sim().queued();
            session.finish();
            queued
        };
        let kept = run(false);
        let cancelled = run(true);
        // the two runs share one timeline up to the (single) retirement, so
        // the queue depths differ exactly by what cancellation dropped
        assert!(
            kept > 0,
            "scenario must leave a queued-prediction backlog at retirement"
        );
        assert!(
            cancelled < kept,
            "retirement must cancel owned queued prefetches ({cancelled} vs {kept})"
        );
    }

    #[test]
    fn suspend_resume_roundtrips_session() {
        let s = spec();
        let mut w = workload(&s, 23);
        let eamc = eamc_for(&s, &mut w, 20, 6);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seq = w.gen_sequence();
        let lookup = |_id: u64| &seq;
        let mut step = StepResult::default();
        let session = eng.begin_session(0.0, FeedbackMode::Immediate);
        let state = session.suspend();
        assert_eq!(state.now(), 0.0);
        let mut session = eng.resume_session(state);
        session.admit(0, &seq);
        let mut n = 0;
        loop {
            let state = session.suspend();
            session = eng.resume_session(state);
            if !session.step(&lookup, &mut step) {
                break;
            }
            n += 1;
        }
        assert_eq!(n, seq.iterations(), "suspension must not lose slots");
        session.finish();
    }

    #[test]
    fn eamc_observes_completed_sequences() {
        let s = spec();
        let mut w = workload(&s, 7);
        let eamc = eamc_for(&s, &mut w, 10, 4);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 32, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let before = eng.eamc().stats().observed_since_build;
        let seq = w.gen_sequence();
        eng.run_batch(&[seq], 0.0);
        assert_eq!(eng.eamc().stats().observed_since_build, before + 1);
    }
}
