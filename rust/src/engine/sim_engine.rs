//! Algorithm 1 over the discrete-event memory simulator.

use crate::cache::CacheCtx;
use crate::cluster::ClusterModel;
use crate::engine::ComputeModel;
use crate::memory::{MemorySim, TierConfig};
use crate::model::{ExpertKey, ModelSpec};
use crate::prefetch::{Predictor, PredictorKind};
use crate::trace::{Eam, Eamc, EamcMatcher};
use crate::workload::SequenceActivation;

/// Engine policy knobs (the ablation surface of §8.3/§8.4).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub predictor: PredictorKind,
    /// §8.3 "effects of activation-aware priority": when false, prefetches
    /// all carry one flat priority (FIFO order); on-demand still jumps.
    pub priority_enabled: bool,
    /// Recall threshold under which a sequence counts as poorly predicted
    /// (feeds EAMC online reconstruction, §4.3).
    pub well_predicted_recall: f64,
    /// Minimum predicted activation ratio worth a prefetch transfer
    /// (precision gate; see `Predictor::with_min_ratio`).
    pub min_prefetch_ratio: f64,
    /// ZeRO semantics: fetch every expert of a layer before executing it
    /// (no router visibility — see `baselines::fetch_all_for`).
    pub fetch_all_experts: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            predictor: PredictorKind::ActivationAware { refine: true },
            priority_enabled: true,
            well_predicted_recall: 0.5,
            min_prefetch_ratio: 0.05,
            fetch_all_experts: false,
        }
    }
}

/// Outcome of one batch generation (all sequences run to completion).
#[derive(Debug, Clone, Default)]
pub struct BatchResult {
    /// Latency of each forward iteration (per-token latency, §2.1).
    pub token_latencies: Vec<f64>,
    /// Virtual time when the batch finished.
    pub finish: f64,
    /// Per-sequence prefetch recall: fraction of expert demands that hit GPU.
    pub seq_recalls: Vec<f64>,
    /// Total expert demands / GPU hits in this batch.
    pub demands: u64,
    pub gpu_hits: u64,
    /// Expert-ready waits observed (expert demand stall per event).
    pub stalls: Vec<f64>,
}

impl BatchResult {
    pub fn mean_token_latency(&self) -> f64 {
        if self.token_latencies.is_empty() {
            0.0
        } else {
            self.token_latencies.iter().sum::<f64>() / self.token_latencies.len() as f64
        }
    }

    /// Batch prefetch recall. Nothing demanded ⇒ nothing missed ⇒ 1.0
    /// (the same convention the per-sequence recall path uses).
    pub fn recall(&self) -> f64 {
        if self.demands == 0 {
            1.0
        } else {
            self.gpu_hits as f64 / self.demands as f64
        }
    }
}

/// The simulated-backend engine (one model replica).
///
/// All per-batch working state (per-sequence EAMs, matcher handles, the
/// per-layer routing union, demand/hit tallies) lives in engine-owned
/// buffers that are cleared — not reallocated — at batch boundaries, so a
/// steady-state decode iteration performs no heap allocation (pinned by
/// `tests/alloc_guard.rs`).
pub struct SimEngine {
    spec: ModelSpec,
    sim: MemorySim,
    eamc: Eamc,
    predictor: Predictor,
    compute: ComputeModel,
    cfg: EngineConfig,
    clock: f64,
    /// Expert-parallel cluster execution model (None = single node).
    cluster: Option<ClusterModel>,
    /// Reusable prediction buffer (hot path, no per-layer allocation).
    pred_buf: Vec<(ExpertKey, f64)>,
    /// Per-sequence incremental matcher handles (re-attached per batch).
    matchers: Vec<EamcMatcher>,
    /// Pooled per-sequence EAMs (Alg. 1 step 2 clears these).
    cur_eams: Vec<Eam>,
    /// Batch-combined EAM driving cache decisions.
    batch_eam: Eam,
    /// All-zero EAM for idle-time cache contexts.
    idle_eam: Eam,
    /// Per-layer routing union scratch (replaces a per-layer BTreeMap):
    /// token totals and touching sequences per expert id, plus the sorted
    /// list of experts active in the current layer.
    union_tokens: Vec<u32>,
    union_seqs: Vec<Vec<u32>>,
    union_active: Vec<u16>,
    /// Per-sequence demand/GPU-hit tallies for the recall feedback loop.
    seq_demands: Vec<u64>,
    seq_hits: Vec<u64>,
}

impl SimEngine {
    pub fn new(
        spec: ModelSpec,
        tier: TierConfig,
        eamc: Eamc,
        compute: ComputeModel,
        cfg: EngineConfig,
    ) -> SimEngine {
        let sim = MemorySim::new(&spec, tier);
        let predictor = Predictor::new(cfg.predictor, spec.n_layers, spec.experts_per_layer)
            .with_min_ratio(cfg.min_prefetch_ratio);
        let (n_layers, n_experts) = (spec.n_layers, spec.experts_per_layer);
        SimEngine {
            spec,
            sim,
            eamc,
            predictor,
            compute,
            cfg,
            clock: 0.0,
            cluster: None,
            pred_buf: Vec::new(),
            matchers: Vec::new(),
            cur_eams: Vec::new(),
            batch_eam: Eam::new(n_layers, n_experts),
            idle_eam: Eam::new(n_layers, n_experts),
            union_tokens: vec![0; n_experts],
            union_seqs: vec![Vec::new(); n_experts],
            union_active: Vec::with_capacity(n_experts),
            seq_demands: Vec::new(),
            seq_hits: Vec::new(),
        }
    }

    /// Enable expert-parallel cluster execution (§7, Fig. 13): per-layer
    /// all-to-all exchanges are charged and distinct experts execute in
    /// parallel across nodes.
    pub fn with_cluster(mut self, cluster: ClusterModel) -> SimEngine {
        self.cluster = Some(cluster);
        self
    }

    pub fn now(&self) -> f64 {
        self.clock
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn sim(&self) -> &MemorySim {
        &self.sim
    }

    pub fn eamc(&self) -> &Eamc {
        &self.eamc
    }

    pub fn eamc_mut(&mut self) -> &mut Eamc {
        &mut self.eamc
    }

    /// Idle the engine until `t` (arrivals later than the current clock).
    pub fn idle_until(&mut self, t: f64) {
        if t > self.clock {
            let ctx = CacheCtx {
                cur_eam: &self.idle_eam,
                n_layers: self.spec.n_layers,
            };
            self.sim.advance_to(t, &ctx);
            self.clock = t;
        }
    }

    /// Run one batch to completion (Alg. 1, batch-generalized):
    /// per-sequence `cur_eam`s are traced independently (the paper's
    /// sequence-level insight); prefetch predictions from all active
    /// sequences are merged into the shared priority queue; the cache
    /// context uses the batch-combined EAM.
    pub fn run_batch(&mut self, seqs: &[SequenceActivation], start: f64) -> BatchResult {
        let mut result = BatchResult::default();
        self.run_batch_into(seqs, start, &mut result);
        result
    }

    /// [`SimEngine::run_batch`] writing into a caller-owned result whose
    /// buffers are reused. Together with the engine-owned scratch this makes
    /// a warmed steady-state batch fully allocation-free (see
    /// `tests/alloc_guard.rs`).
    pub fn run_batch_into(
        &mut self,
        seqs: &[SequenceActivation],
        start: f64,
        result: &mut BatchResult,
    ) {
        assert!(!seqs.is_empty());
        self.idle_until(start);
        let mut t = self.clock.max(start);
        let (n_layers, n_experts) = (self.spec.n_layers, self.spec.experts_per_layer);

        // Alg. 1 step 2: fresh EAM per sequence (pooled buffers) and a
        // matcher handle synced to the current EAMC build.
        if self.cur_eams.len() < seqs.len() {
            self.cur_eams
                .resize_with(seqs.len(), || Eam::new(n_layers, n_experts));
        }
        for m in self.cur_eams.iter_mut().take(seqs.len()) {
            m.clear();
        }
        // matcher accumulators only pay off when the activation-aware
        // predictor consumes them; the §8.3/§8.4 baselines skip the upkeep
        let use_matcher = matches!(self.cfg.predictor, PredictorKind::ActivationAware { .. });
        if use_matcher {
            if self.matchers.len() < seqs.len() {
                self.matchers.resize_with(seqs.len(), EamcMatcher::new);
            }
            for m in self.matchers.iter_mut().take(seqs.len()) {
                m.attach(&self.eamc);
            }
        }
        self.batch_eam.clear();
        // stale predictions from the previous batch are dropped
        self.sim.clear_queues();

        result.token_latencies.clear();
        result.seq_recalls.clear();
        result.stalls.clear();
        result.demands = 0;
        result.gpu_hits = 0;
        self.seq_demands.clear();
        self.seq_demands.resize(seqs.len(), 0);
        self.seq_hits.clear();
        self.seq_hits.resize(seqs.len(), 0);

        let max_iters = seqs.iter().map(|s| s.iterations()).max().unwrap();

        for iter in 0..max_iters {
            let iter_start = t;
            let mut batch_tokens = 0u32;
            for s in seqs {
                if iter < s.iterations() {
                    batch_tokens += if iter == 0 { s.prompt_len as u32 } else { 1 };
                }
            }
            for l in 0..n_layers {
                // ---- dense part of the layer (attention etc.)
                t += self.compute.dense_time(&self.spec, batch_tokens);

                // ---- Alg. 1 step 5: route, steps 6-7: update cur_eam.
                // The per-layer union goes into flat reusable scratch
                // (expert-indexed token totals + touching-sequence lists);
                // only the previous layer's active entries are cleared.
                for &e in &self.union_active {
                    self.union_tokens[e as usize] = 0;
                    self.union_seqs[e as usize].clear();
                }
                self.union_active.clear();
                for (si, s) in seqs.iter().enumerate() {
                    if iter >= s.iterations() {
                        continue;
                    }
                    for &(e, c) in &s.routes[iter][l] {
                        self.cur_eams[si].record(l, e as usize, c);
                        self.batch_eam.record(l, e as usize, c);
                        self.predictor.observe_route(l, e as usize, c);
                        if use_matcher {
                            self.matchers[si].record(self.eamc.index(), l, e as usize, c);
                        }
                        if self.union_seqs[e as usize].is_empty() {
                            self.union_active.push(e);
                        }
                        self.union_tokens[e as usize] += c;
                        self.union_seqs[e as usize].push(si as u32);
                    }
                }
                // keep the former BTreeMap's deterministic expert order
                self.union_active.sort_unstable();

                // ---- Alg. 1 step 8: resubmit prefetch priorities
                for (si, s) in seqs.iter().enumerate() {
                    if iter >= s.iterations() {
                        continue;
                    }
                    if self.predictor.should_predict(l, iter) {
                        let mut buf = std::mem::take(&mut self.pred_buf);
                        let matcher = if use_matcher {
                            Some(&self.matchers[si])
                        } else {
                            None
                        };
                        self.predictor.predict(&self.cur_eams[si], &self.eamc, matcher, l, &mut buf);
                        let ctx = CacheCtx {
                            cur_eam: &self.batch_eam,
                            n_layers,
                        };
                        for &(key, prio) in buf.iter() {
                            // Only experts with a positive predicted
                            // activation ratio are worth PCIe bandwidth;
                            // zero-ratio entries carry only the EPSILON
                            // term and would be pure thrash traffic
                            // (this is how the paper's system "reduces
                            // prefetching traffic by over 7GB of 13GB").
                            if prio <= crate::prefetch::EPSILON {
                                continue;
                            }
                            let p = if self.cfg.priority_enabled { prio } else { 0.5 };
                            self.sim.submit_prefetch(key, p, t, &ctx);
                        }
                        self.pred_buf = buf;
                    }
                }

                // ---- ZeRO semantics: the whole layer's parameters must be
                // resident before execution, activated or not.
                if self.cfg.fetch_all_experts {
                    for e in 0..n_experts {
                        if !self.union_seqs[e].is_empty() {
                            continue; // demanded (and counted) below
                        }
                        let key = ExpertKey::new(l, e);
                        let ctx = CacheCtx {
                            cur_eam: &self.batch_eam,
                            n_layers,
                        };
                        let ready = self.sim.demand(key, t, &ctx);
                        t = ready;
                    }
                }

                // ---- Alg. 1 steps 9-13: execute experts (on-demand jumps)
                let mut exec_total = 0.0f64;
                for idx in 0..self.union_active.len() {
                    let e = self.union_active[idx];
                    let tokens = self.union_tokens[e as usize];
                    let key = ExpertKey::new(l, e as usize);
                    let ctx = CacheCtx {
                        cur_eam: &self.batch_eam,
                        n_layers,
                    };
                    let on_gpu_before = self.sim.is_on_gpu(key);
                    let ready = self.sim.demand(key, t, &ctx);
                    result.demands += 1;
                    result.stalls.push(ready - t);
                    for &si in &self.union_seqs[e as usize] {
                        self.seq_demands[si as usize] += 1;
                        if on_gpu_before {
                            self.seq_hits[si as usize] += 1;
                        }
                    }
                    if on_gpu_before {
                        result.gpu_hits += 1;
                    }
                    t = ready;
                    exec_total += self.compute.expert_time(&self.spec, tokens);
                }
                // Distinct experts run in parallel across expert-parallel
                // nodes (Fig. 13); single node executes them serially.
                match &self.cluster {
                    Some(cm) => {
                        t += exec_total / cm.parallel_expert_factor(self.union_active.len());
                        t += cm.all_to_all_time(&self.spec, batch_tokens);
                    }
                    None => t += exec_total,
                }
            }
            result.token_latencies.push(t - iter_start);
        }

        // §4.3: feed completed EAMs back for drift handling.
        for si in 0..seqs.len() {
            let recall = if self.seq_demands[si] == 0 {
                1.0
            } else {
                self.seq_hits[si] as f64 / self.seq_demands[si] as f64
            };
            result.seq_recalls.push(recall);
            self.eamc
                .observe(&self.cur_eams[si], recall >= self.cfg.well_predicted_recall);
        }

        self.clock = t;
        result.finish = t;
    }

    /// The exact order of expert demands `run_batch` will issue — used to
    /// build the ORACLE cache policy's future trace (§8.4).
    pub fn demand_trace(spec: &ModelSpec, batches: &[Vec<SequenceActivation>]) -> Vec<ExpertKey> {
        let mut out = Vec::new();
        for seqs in batches {
            let max_iters = seqs.iter().map(|s| s.iterations()).max().unwrap_or(0);
            for iter in 0..max_iters {
                for l in 0..spec.n_layers {
                    let mut union: std::collections::BTreeSet<u16> = Default::default();
                    for s in seqs {
                        if iter < s.iterations() {
                            for &(e, _) in &s.routes[iter][l] {
                                union.insert(e);
                            }
                        }
                    }
                    for e in union {
                        out.push(ExpertKey::new(l, e as usize));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheKind;
    use crate::memory::{Link, Tier};
    use crate::workload::{DatasetPreset, Workload};

    fn spec() -> ModelSpec {
        ModelSpec::preset("switch-base-32").unwrap()
    }

    fn tier(spec: &ModelSpec, gpu: usize, kind: CacheKind) -> TierConfig {
        TierConfig {
            gpu_capacity: gpu,
            dram_capacity: spec.total_experts() / 2,
            backing: Tier::Ssd,
            ssd_to_dram: Link::new(6.0, 50e-6),
            dram_to_gpu: Link::new(32.0, 10e-6),
            n_gpus: 1,
            demand_extra_latency: 0.0,
            demand_bw_factor: 1.0,
            cache_kind: kind,
            oracle_trace: Vec::new(),
            activation_terms: (true, true),
            prefetch_gpu_budget: 0.5,
        }
    }

    fn workload(spec: &ModelSpec, seed: u64) -> Workload {
        // 8-task preset: a small EAMC represents it well, keeping the test
        // in the paper's intended operating regime (Fig. 12).
        Workload::new(spec, DatasetPreset::by_name("translation").unwrap(), seed)
    }

    fn eamc_for(spec: &ModelSpec, w: &mut Workload, n: usize, cap: usize) -> Eamc {
        let ds = w.gen_eam_dataset(n);
        Eamc::construct(cap, &ds, 11)
    }

    #[test]
    fn batch_completes_and_advances_clock() {
        let s = spec();
        let mut w = workload(&s, 1);
        let eamc = eamc_for(&s, &mut w, 40, 10);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seq = w.gen_sequence();
        let iters = seq.iterations();
        let r = eng.run_batch(&[seq], 0.0);
        assert_eq!(r.token_latencies.len(), iters);
        assert!(r.finish > 0.0);
        assert_eq!(eng.now(), r.finish);
        assert!(r.token_latencies.iter().all(|&l| l > 0.0));
    }

    #[test]
    fn prefetching_beats_no_prefetching() {
        let s = spec();
        let run = |kind: PredictorKind| -> f64 {
            let mut w = workload(&s, 2);
            let eamc = eamc_for(&s, &mut w, 60, 12);
            let mut eng = SimEngine::new(
                s.clone(),
                tier(&s, 144, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig {
                    predictor: kind,
                    ..Default::default()
                },
            );
            let mut total = 0.0;
            let mut n = 0;
            for _ in 0..8 {
                let seq = w.gen_sequence();
                let r = eng.run_batch(&[seq], eng.now());
                total += r.token_latencies.iter().sum::<f64>();
                n += r.token_latencies.len();
            }
            total / n as f64
        };
        let aware = run(PredictorKind::ActivationAware { refine: true });
        let none = run(PredictorKind::NoPrefetch);
        assert!(
            aware < none,
            "activation-aware {aware} must beat on-demand {none}"
        );
    }

    #[test]
    fn activation_aware_beats_topk_on_recall() {
        let s = spec();
        let run = |kind: PredictorKind| -> f64 {
            let mut w = workload(&s, 3);
            let eamc = eamc_for(&s, &mut w, 60, 16);
            let mut eng = SimEngine::new(
                s.clone(),
                tier(&s, 32, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig {
                    predictor: kind,
                    ..Default::default()
                },
            );
            let mut hits = 0;
            let mut demands = 0;
            for _ in 0..10 {
                let seq = w.gen_sequence();
                let r = eng.run_batch(&[seq], eng.now());
                hits += r.gpu_hits;
                demands += r.demands;
            }
            hits as f64 / demands as f64
        };
        let aware = run(PredictorKind::ActivationAware { refine: true });
        let topk = run(PredictorKind::TopK { k: 4 });
        assert!(aware > topk, "aware recall {aware} vs topk {topk}");
    }

    #[test]
    fn batch_of_many_sequences_counts_all_tokens() {
        let s = spec();
        let mut w = workload(&s, 4);
        let eamc = eamc_for(&s, &mut w, 30, 8);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 64, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seqs: Vec<_> = (0..4).map(|_| w.gen_sequence()).collect();
        let max_iters = seqs.iter().map(|x| x.iterations()).max().unwrap();
        let r = eng.run_batch(&seqs, 0.0);
        assert_eq!(r.token_latencies.len(), max_iters);
        assert_eq!(r.seq_recalls.len(), 4);
    }

    #[test]
    fn idle_until_moves_clock_forward_only() {
        let s = spec();
        let mut w = workload(&s, 5);
        let eamc = eamc_for(&s, &mut w, 10, 4);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 16, CacheKind::Lru),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        eng.idle_until(5.0);
        assert_eq!(eng.now(), 5.0);
        eng.idle_until(1.0);
        assert_eq!(eng.now(), 5.0);
    }

    #[test]
    fn demand_trace_covers_all_routed_experts() {
        let s = spec();
        let mut w = workload(&s, 6);
        let seq = w.gen_sequence();
        let trace = SimEngine::demand_trace(&s, &[vec![seq.clone()]]);
        let eam = seq.to_eam(s.n_layers, s.experts_per_layer);
        let distinct: usize = (0..s.n_layers)
            .map(|l| (0..s.experts_per_layer).filter(|&e| eam.count(l, e) > 0).count())
            .sum();
        let mut uniq: Vec<ExpertKey> = trace.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), distinct);
        assert!(trace.len() >= distinct, "reuse appears as repeats");
    }

    #[test]
    fn empty_result_recall_conventions_agree() {
        // nothing demanded ⇒ nothing missed: both the batch-level and the
        // per-sequence accounting must say 1.0 (they used to disagree).
        let r = BatchResult::default();
        assert_eq!(r.recall(), 1.0);
        // a sequence with zero demands (everything warm) reports recall 1.0
        let s = spec();
        let mut w = workload(&s, 9);
        let eamc = eamc_for(&s, &mut w, 20, 6);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, s.total_experts(), CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let seq = w.gen_sequence();
        let out = eng.run_batch(&[seq], 0.0);
        for &r in &out.seq_recalls {
            assert!((0.0..=1.0).contains(&r));
        }
        assert!((0.0..=1.0).contains(&out.recall()));
    }

    #[test]
    fn run_batch_into_reuses_buffers_and_matches_run_batch() {
        let s = spec();
        let mut w = workload(&s, 10);
        let eamc = eamc_for(&s, &mut w, 30, 8);
        let make = |eamc: Eamc| {
            SimEngine::new(
                s.clone(),
                tier(&s, 64, CacheKind::Activation),
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            )
        };
        let seqs: Vec<_> = (0..3).map(|_| w.gen_sequence()).collect();
        // identical engines, identical batches: both entry points agree
        let mut w2 = workload(&s, 10);
        let eamc2 = eamc_for(&s, &mut w2, 30, 8);
        let mut a = make(eamc2);
        let mut b = {
            let mut w3 = workload(&s, 10);
            make(eamc_for(&s, &mut w3, 30, 8))
        };
        let ra = a.run_batch(&seqs, 0.0);
        let mut rb = BatchResult::default();
        b.run_batch_into(&seqs, 0.0, &mut rb);
        assert_eq!(ra.demands, rb.demands);
        assert_eq!(ra.gpu_hits, rb.gpu_hits);
        assert_eq!(ra.token_latencies, rb.token_latencies);
        // the same result struct can be reused across batches
        let more: Vec<_> = (0..2).map(|_| w.gen_sequence()).collect();
        b.run_batch_into(&more, b.now(), &mut rb);
        assert_eq!(rb.seq_recalls.len(), 2);
    }

    #[test]
    fn eamc_observes_completed_sequences() {
        let s = spec();
        let mut w = workload(&s, 7);
        let eamc = eamc_for(&s, &mut w, 10, 4);
        let mut eng = SimEngine::new(
            s.clone(),
            tier(&s, 32, CacheKind::Activation),
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        let before = eng.eamc().stats().observed_since_build;
        let seq = w.gen_sequence();
        eng.run_batch(&[seq], 0.0);
        assert_eq!(eng.eamc().stats().observed_since_build, before + 1);
    }
}
