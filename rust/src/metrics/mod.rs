//! Latency/throughput metrics: online histogram, percentiles, CDF export.

/// A simple exact-sample latency recorder. Serving experiments record at
/// most a few hundred thousand points, so exact storage beats approximate
/// sketches for reproducibility.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    sorted: bool,
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    /// Pre-size for `additional` more samples. Serving schedulers reserve at
    /// request submission so steady-state recording never reallocates (the
    /// router's warmed-iteration allocation guard depends on this).
    pub fn reserve(&mut self, additional: usize) {
        self.samples.reserve(additional);
    }

    /// Append `other`'s samples in their insertion order (the router merges
    /// per-replica reports this way; with one replica it is the identity).
    pub fn append(&mut self, other: &LatencyRecorder) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Raw samples (insertion order until a percentile/CDF call sorts them
    /// in place). The grid-replay differential tests compare these bitwise.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Percentile in [0, 100] by nearest-rank.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = ((p / 100.0) * self.samples.len() as f64).ceil() as usize;
        self.samples[rank.clamp(1, self.samples.len()) - 1]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.last().copied().unwrap_or(0.0)
    }

    /// CDF points `(value, fraction <= value)` at `n` evenly spaced ranks —
    /// the Fig. 5 export format.
    pub fn cdf(&mut self, n: usize) -> Vec<(f64, f64)> {
        if self.samples.is_empty() || n == 0 {
            return Vec::new();
        }
        self.ensure_sorted();
        let len = self.samples.len();
        (1..=n)
            .map(|i| {
                let frac = i as f64 / n as f64;
                let idx = ((frac * len as f64).ceil() as usize).clamp(1, len) - 1;
                (self.samples[idx], frac)
            })
            .collect()
    }
}

/// Throughput counter over virtual time.
#[derive(Debug, Clone, Default)]
pub struct Throughput {
    pub events: u64,
    pub start: f64,
    pub end: f64,
}

impl Throughput {
    pub fn new(start: f64) -> Throughput {
        Throughput {
            events: 0,
            start,
            end: start,
        }
    }

    pub fn record(&mut self, t: f64, n: u64) {
        self.events += n;
        if t > self.end {
            self.end = t;
        }
    }

    /// Events per second over the observed window.
    pub fn rate(&self) -> f64 {
        let dt = self.end - self.start;
        if dt <= 0.0 {
            0.0
        } else {
            self.events as f64 / dt
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert!((r.mean() - 50.5).abs() < 1e-9);
        assert_eq!(r.p50(), 50.0);
        assert_eq!(r.p99(), 99.0);
        assert_eq!(r.percentile(100.0), 100.0);
        assert_eq!(r.max(), 100.0);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let mut r = LatencyRecorder::new();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.p99(), 0.0);
        assert!(r.cdf(10).is_empty());
    }

    #[test]
    fn cdf_monotone() {
        let mut r = LatencyRecorder::new();
        for i in 0..1000 {
            r.record(((i * 7919) % 997) as f64);
        }
        let cdf = r.cdf(20);
        assert_eq!(cdf.len(), 20);
        for w in cdf.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 > w[0].1);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn append_preserves_order_and_reserve_prevents_growth() {
        let mut a = LatencyRecorder::new();
        a.record(3.0);
        let mut b = LatencyRecorder::new();
        b.record(1.0);
        b.record(2.0);
        a.append(&b);
        assert_eq!(a.samples(), &[3.0, 1.0, 2.0]);
        let mut r = LatencyRecorder::new();
        r.reserve(4);
        let cap_probe = r.samples.capacity();
        for i in 0..4 {
            r.record(i as f64);
        }
        assert_eq!(r.samples.capacity(), cap_probe, "reserved pushes must not grow");
    }

    #[test]
    fn record_after_percentile_resorts() {
        let mut r = LatencyRecorder::new();
        r.record(5.0);
        assert_eq!(r.p50(), 5.0);
        r.record(1.0);
        assert_eq!(r.percentile(1.0), 1.0);
    }

    #[test]
    fn throughput_rate() {
        let mut t = Throughput::new(10.0);
        t.record(11.0, 50);
        t.record(12.0, 50);
        assert!((t.rate() - 50.0).abs() < 1e-9);
        let empty = Throughput::new(0.0);
        assert_eq!(empty.rate(), 0.0);
    }
}
