//! Configuration system: TOML-serializable experiment/serving configs used
//! by the CLI, examples and benches (parsed with the in-tree TOML subset,
//! `util::tomlmini` — the image has no external TOML crate).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::cache::CacheKind;
use crate::faults::{Brownout, FaultLink, FaultPlan, RetryPolicy};
use crate::memory::{Link, Tier, TierConfig};
use crate::model::ModelSpec;
use crate::prefetch::PredictorKind;
use crate::server::{check_max_wait, AdmissionPolicy, RoutingPolicy};
use crate::util::tomlmini::TomlDoc;
use crate::util::units::{floor_bytes, SimTime};

/// Iteration-level scheduling policy of the serving loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    /// AlpaServe-style run-to-completion batches (the paper's §8.2
    /// methodology): a batch is formed, dispatched, and holds the engine
    /// until its longest sequence completes.
    #[default]
    Static,
    /// Continuous batching on the resumable stepping engine: arrivals join
    /// free slots at every iteration boundary, sequences retire the
    /// iteration they finish.
    Continuous,
    /// Continuous batching plus chunked prefill: a joining prompt executes
    /// at most `prefill_chunk` tokens per iteration, so prompt bursts no
    /// longer stall in-flight decodes (`prefill_chunk = 0` means
    /// unlimited, which is bitwise the continuous scheduler).
    Chunked,
}

impl SchedulerKind {
    pub fn by_name(s: &str) -> Option<SchedulerKind> {
        match s {
            "static" => Some(SchedulerKind::Static),
            "continuous" => Some(SchedulerKind::Continuous),
            "chunked" => Some(SchedulerKind::Chunked),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Static => "static",
            SchedulerKind::Continuous => "continuous",
            SchedulerKind::Chunked => "chunked",
        }
    }

    /// Schedulers built on the resumable session substrate (everything the
    /// router and priority classes require).
    pub fn is_continuous_family(self) -> bool {
        matches!(self, SchedulerKind::Continuous | SchedulerKind::Chunked)
    }
}

/// Top-level serving configuration (what `moe-infinity serve` consumes).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Model preset name (see [`crate::model::PRESETS`]).
    pub model: String,
    /// Dataset preset (see [`crate::workload::DATASETS`]).
    pub dataset: String,
    /// System policy bundle: "moe-infinity", "zero-infinity", "zero-offload"
    /// or "pytorch-um".
    pub system: String,
    /// Serving-loop scheduler: "static", "continuous" or "chunked".
    pub scheduler: SchedulerKind,
    /// Chunked-prefill per-iteration prompt-token budget (used by
    /// `scheduler = "chunked"`; 0 = unlimited — bitwise the continuous
    /// scheduler).
    pub prefill_chunk: usize,
    /// Continuous-scheduler admission: "fifo" (strict arrival order) or
    /// "classes" (priority tiers + SLO slack + voluntary preemption).
    pub priority: AdmissionPolicy,
    /// Engine replicas behind the request router (1 = bare scheduler, no
    /// router). Replicas >1 require the continuous scheduler.
    pub replicas: usize,
    /// Multi-replica routing policy: "round-robin", "least-loaded" or
    /// "task-affinity" (only used when `replicas > 1`).
    pub routing: RoutingPolicy,
    /// Cancel a retired/preempted sequence's still-queued prefetches (see
    /// `EngineConfig::cancel_retired_prefetch`; on by default — pure
    /// dead-traffic savings per `BENCH_scheduler.json` `cancel_*` rows,
    /// with the no-p99-cost contract asserted by `perf_scheduler`. The
    /// bitwise differential pins that replay the uncancelled history set
    /// this to false explicitly).
    pub cancel_retired_prefetch: bool,
    pub workload: WorkloadConfig,
    pub batching: BatchConfig,
    pub memory: MemoryConfig,
    pub eamc: EamcConfig,
    pub faults: FaultsConfig,
    pub seed: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// Requests per second.
    pub rps: f64,
    /// Burstiness: 1.0 = Poisson, >1 = Azure-style bursts.
    pub cv: f64,
    /// Virtual duration of the replay in seconds.
    pub duration: f64,
    /// Fraction of requests tagged `Priority::Interactive` (the rest stay
    /// on the default class). 0.0 — the default — generates exactly the
    /// pre-priority request stream.
    pub interactive_frac: f64,
    /// SLO deadline (seconds from arrival) attached to interactive-tagged
    /// requests. 0.0 — the default — attaches no SLO, generating exactly
    /// the historical class tagging; with an SLO attached, goodput and the
    /// shedding/timeout machinery become meaningful.
    pub interactive_slo: f64,
    /// Flash-crowd overlay: while `flash_start <= t < flash_end`, arrival
    /// gaps draw at this rate instead of `rps` (burstiness `cv` applies in
    /// both phases). 0.0 — the default — disables the overlay and
    /// generates exactly the historical single-rate arrival stream.
    pub flash_rps: f64,
    /// Flash-crowd window start, seconds of virtual time.
    pub flash_start: f64,
    /// Flash-crowd window end, seconds (>= start; an empty window is a
    /// no-op).
    pub flash_end: f64,
}

/// Deterministic fault-injection knobs (the config-expressible subset of
/// [`crate::faults::FaultPlan`]: per-link transient failure probabilities,
/// the retry/backoff policy, one bandwidth-brownout window on the
/// DRAM→GPU link, and SLO deadline shedding). Replica crash/recover
/// windows carry a replica index + two instants each and are programmatic
/// only (the TOML subset has no arrays); `perf_faults` builds them
/// directly. All-default = no plan installed — the bitwise-pinned
/// fault-free replay.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultsConfig {
    /// Per-attempt failure probability of SSD→DRAM transfers, in [0, 1).
    pub ssd_failure_p: f64,
    /// Per-attempt failure probability of DRAM→GPU transfers, in [0, 1).
    pub gpu_failure_p: f64,
    /// First retry backoff delay, seconds (doubles per retry).
    pub retry_base: f64,
    /// Backoff cap, seconds.
    pub retry_max_delay: f64,
    /// Retries before a transfer permanently fails (prefetches drop to
    /// on-demand; demanded transfers force-land and count
    /// `demand_failures`).
    pub max_retries: usize,
    /// Bandwidth multiplier of the brownout window, in (0, 1]; 1.0 = no
    /// brownout.
    pub brownout: f64,
    /// Brownout window start, seconds of virtual time.
    pub brownout_start: f64,
    /// Brownout window end, seconds (must be >= start; an empty window is
    /// a no-op).
    pub brownout_end: f64,
    /// Enable SLO deadline shedding / timeout aborts on the continuous
    /// scheduler family.
    pub shedding: bool,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        let retry = RetryPolicy::default();
        FaultsConfig {
            ssd_failure_p: 0.0,
            gpu_failure_p: 0.0,
            retry_base: retry.base_delay.to_f64(),
            retry_max_delay: retry.max_delay.to_f64(),
            max_retries: retry.max_retries as usize,
            brownout: 1.0,
            brownout_start: 0.0,
            brownout_end: 0.0,
            shedding: false,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct BatchConfig {
    /// Max sequences per batch (paper: 16, from AlpaServe).
    pub max_batch: usize,
    /// Max waiting time before a partial batch is dispatched (paper: 1s).
    pub max_wait: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct MemoryConfig {
    /// GPU memory per device, GB.
    pub gpu_gb: f64,
    /// Host memory, GB.
    pub dram_gb: f64,
    /// SSD→DRAM bandwidth, GB/s.
    pub ssd_bw: f64,
    /// DRAM→GPU (PCIe) bandwidth, GB/s.
    pub pcie_bw: f64,
    pub n_gpus: usize,
    /// GPU-tier eviction policy override: a [`CacheKind`] name
    /// ("activation", "lru", "lfu", "lfuda", "slru", "gdsf", "neighbor"),
    /// or "auto" to keep whatever the system bundle selects. "oracle"
    /// is rejected here — it needs a programmatic future trace and is
    /// bench-only.
    pub gpu_policy: String,
    /// DRAM-tier eviction policy override (same names as `gpu_policy`).
    pub dram_policy: String,
    /// SSD rated IOPS for the per-op cost model on the SSD→DRAM link
    /// (FlashMoE: per-op service cost, not bandwidth, bottlenecks expert
    /// reads on edge SSDs). 0.0 — the default — disables the term, which
    /// is the bitwise-pinned pre-IOPS link model.
    pub ssd_iops: f64,
    /// Queue depth the IOPS term charges per op (>= 1.0; only read when
    /// `ssd_iops > 0`).
    pub ssd_queue_depth: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct EamcConfig {
    /// EAMC capacity (number of representative EAMs).
    pub capacity: usize,
    /// Offline trace size used for construction.
    pub trace_sequences: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "switch-base-128".into(),
            dataset: "mixed".into(),
            system: "moe-infinity".into(),
            scheduler: SchedulerKind::Static,
            prefill_chunk: 64,
            priority: AdmissionPolicy::Fifo,
            replicas: 1,
            routing: RoutingPolicy::RoundRobin,
            cancel_retired_prefetch: true,
            workload: WorkloadConfig {
                rps: 1.0,
                cv: 1.0,
                duration: 120.0,
                interactive_frac: 0.0,
                interactive_slo: 0.0,
                flash_rps: 0.0,
                flash_start: 0.0,
                flash_end: 0.0,
            },
            batching: BatchConfig {
                max_batch: 16,
                max_wait: 1.0,
            },
            memory: MemoryConfig {
                gpu_gb: 24.0,
                dram_gb: 128.0,
                ssd_bw: 6.0,
                pcie_bw: 32.0,
                n_gpus: 1,
                gpu_policy: "auto".into(),
                dram_policy: "auto".into(),
                ssd_iops: 0.0,
                ssd_queue_depth: 1.0,
            },
            eamc: EamcConfig {
                capacity: 120,
                trace_sequences: 600,
            },
            faults: FaultsConfig::default(),
            seed: 42,
        }
    }
}

impl ServeConfig {
    /// Parse from TOML text. Missing keys fall back to defaults, so configs
    /// can be partial overrides.
    pub fn from_toml(text: &str) -> Result<ServeConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("config parse error: {e}"))?;
        let mut c = ServeConfig::default();
        let gs = |d: &TomlDoc, k: &str, cur: &str| -> String {
            d.get(k).and_then(|v| v.as_str().map(String::from)).unwrap_or_else(|| cur.into())
        };
        let gf = |d: &TomlDoc, k: &str, cur: f64| d.get(k).and_then(|v| v.as_f64()).unwrap_or(cur);
        let gu = |d: &TomlDoc, k: &str, cur: usize| d.get(k).and_then(|v| v.as_usize()).unwrap_or(cur);
        c.model = gs(&doc, "model", &c.model);
        c.dataset = gs(&doc, "dataset", &c.dataset);
        c.system = gs(&doc, "system", &c.system);
        if let Some(v) = doc.get("scheduler") {
            let s = v.as_str().ok_or_else(|| anyhow!("scheduler must be a string"))?;
            c.scheduler = SchedulerKind::by_name(s).ok_or_else(|| {
                anyhow!("unknown scheduler '{s}' (expected 'static', 'continuous' or 'chunked')")
            })?;
        }
        c.prefill_chunk = gu(&doc, "prefill_chunk", c.prefill_chunk);
        if let Some(v) = doc.get("priority") {
            let s = v.as_str().ok_or_else(|| anyhow!("priority must be a string"))?;
            c.priority = AdmissionPolicy::by_name(s).ok_or_else(|| {
                anyhow!("unknown priority policy '{s}' (expected 'fifo' or 'classes')")
            })?;
        }
        if let Some(v) = doc.get("routing") {
            let s = v.as_str().ok_or_else(|| anyhow!("routing must be a string"))?;
            c.routing = RoutingPolicy::by_name(s).ok_or_else(|| {
                anyhow!(
                    "unknown routing policy '{s}' (expected 'round-robin', \
                     'least-loaded' or 'task-affinity')"
                )
            })?;
        }
        c.replicas = gu(&doc, "replicas", c.replicas);
        if let Some(v) = doc.get("cancel_retired_prefetch") {
            c.cancel_retired_prefetch = v
                .as_bool()
                .ok_or_else(|| anyhow!("cancel_retired_prefetch must be a bool"))?;
        }
        c.seed = doc.get("seed").and_then(|v| v.as_u64()).unwrap_or(c.seed);
        c.workload.rps = gf(&doc, "workload.rps", c.workload.rps);
        c.workload.cv = gf(&doc, "workload.cv", c.workload.cv);
        c.workload.duration = gf(&doc, "workload.duration", c.workload.duration);
        c.workload.interactive_frac =
            gf(&doc, "workload.interactive_frac", c.workload.interactive_frac);
        c.workload.interactive_slo =
            gf(&doc, "workload.interactive_slo", c.workload.interactive_slo);
        c.workload.flash_rps = gf(&doc, "workload.flash_rps", c.workload.flash_rps);
        c.workload.flash_start = gf(&doc, "workload.flash_start", c.workload.flash_start);
        c.workload.flash_end = gf(&doc, "workload.flash_end", c.workload.flash_end);
        c.batching.max_batch = gu(&doc, "batching.max_batch", c.batching.max_batch);
        c.batching.max_wait = gf(&doc, "batching.max_wait", c.batching.max_wait);
        c.memory.gpu_gb = gf(&doc, "memory.gpu_gb", c.memory.gpu_gb);
        c.memory.dram_gb = gf(&doc, "memory.dram_gb", c.memory.dram_gb);
        c.memory.ssd_bw = gf(&doc, "memory.ssd_bw", c.memory.ssd_bw);
        c.memory.pcie_bw = gf(&doc, "memory.pcie_bw", c.memory.pcie_bw);
        c.memory.n_gpus = gu(&doc, "memory.n_gpus", c.memory.n_gpus);
        c.memory.gpu_policy = gs(&doc, "memory.gpu_policy", &c.memory.gpu_policy);
        c.memory.dram_policy = gs(&doc, "memory.dram_policy", &c.memory.dram_policy);
        c.memory.ssd_iops = gf(&doc, "memory.ssd_iops", c.memory.ssd_iops);
        c.memory.ssd_queue_depth = gf(&doc, "memory.ssd_queue_depth", c.memory.ssd_queue_depth);
        c.eamc.capacity = gu(&doc, "eamc.capacity", c.eamc.capacity);
        c.eamc.trace_sequences = gu(&doc, "eamc.trace_sequences", c.eamc.trace_sequences);
        c.faults.ssd_failure_p = gf(&doc, "faults.ssd_failure_p", c.faults.ssd_failure_p);
        c.faults.gpu_failure_p = gf(&doc, "faults.gpu_failure_p", c.faults.gpu_failure_p);
        c.faults.retry_base = gf(&doc, "faults.retry_base", c.faults.retry_base);
        c.faults.retry_max_delay = gf(&doc, "faults.retry_max_delay", c.faults.retry_max_delay);
        c.faults.max_retries = gu(&doc, "faults.max_retries", c.faults.max_retries);
        c.faults.brownout = gf(&doc, "faults.brownout", c.faults.brownout);
        c.faults.brownout_start = gf(&doc, "faults.brownout_start", c.faults.brownout_start);
        c.faults.brownout_end = gf(&doc, "faults.brownout_end", c.faults.brownout_end);
        if let Some(v) = doc.get("faults.shedding") {
            c.faults.shedding = v
                .as_bool()
                .ok_or_else(|| anyhow!("faults.shedding must be a bool"))?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn from_toml_file(path: &Path) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        ServeConfig::from_toml(&text)
    }

    pub fn to_toml(&self) -> String {
        let mut d = TomlDoc::default();
        d.set_str("model", &self.model);
        d.set_str("dataset", &self.dataset);
        d.set_str("system", &self.system);
        d.set_str("scheduler", self.scheduler.name());
        d.set_num("prefill_chunk", self.prefill_chunk as f64);
        d.set_str("priority", self.priority.name());
        d.set_num("replicas", self.replicas as f64);
        d.set_str("routing", self.routing.name());
        d.set_bool("cancel_retired_prefetch", self.cancel_retired_prefetch);
        d.set_num("seed", self.seed as f64);
        d.set_num("workload.rps", self.workload.rps);
        d.set_num("workload.cv", self.workload.cv);
        d.set_num("workload.duration", self.workload.duration);
        d.set_num("workload.interactive_frac", self.workload.interactive_frac);
        d.set_num("workload.interactive_slo", self.workload.interactive_slo);
        d.set_num("workload.flash_rps", self.workload.flash_rps);
        d.set_num("workload.flash_start", self.workload.flash_start);
        d.set_num("workload.flash_end", self.workload.flash_end);
        d.set_num("batching.max_batch", self.batching.max_batch as f64);
        d.set_num("batching.max_wait", self.batching.max_wait);
        d.set_num("memory.gpu_gb", self.memory.gpu_gb);
        d.set_num("memory.dram_gb", self.memory.dram_gb);
        d.set_num("memory.ssd_bw", self.memory.ssd_bw);
        d.set_num("memory.pcie_bw", self.memory.pcie_bw);
        d.set_num("memory.n_gpus", self.memory.n_gpus as f64);
        d.set_str("memory.gpu_policy", &self.memory.gpu_policy);
        d.set_str("memory.dram_policy", &self.memory.dram_policy);
        d.set_num("memory.ssd_iops", self.memory.ssd_iops);
        d.set_num("memory.ssd_queue_depth", self.memory.ssd_queue_depth);
        d.set_num("eamc.capacity", self.eamc.capacity as f64);
        d.set_num("eamc.trace_sequences", self.eamc.trace_sequences as f64);
        d.set_num("faults.ssd_failure_p", self.faults.ssd_failure_p);
        d.set_num("faults.gpu_failure_p", self.faults.gpu_failure_p);
        d.set_num("faults.retry_base", self.faults.retry_base);
        d.set_num("faults.retry_max_delay", self.faults.retry_max_delay);
        d.set_num("faults.max_retries", self.faults.max_retries as f64);
        d.set_num("faults.brownout", self.faults.brownout);
        d.set_num("faults.brownout_start", self.faults.brownout_start);
        d.set_num("faults.brownout_end", self.faults.brownout_end);
        d.set_bool("faults.shedding", self.faults.shedding);
        d.to_string_pretty()
    }

    pub fn validate(&self) -> Result<()> {
        self.model_spec()?;
        if crate::workload::DatasetPreset::by_name(&self.dataset).is_none() {
            return Err(anyhow!("unknown dataset '{}'", self.dataset));
        }
        crate::baselines::predictor_for(&self.system)?;
        if self.batching.max_batch == 0 {
            return Err(anyhow!("batching.max_batch must be >= 1"));
        }
        // the one shared batching-window check (Batcher::new asserts the
        // same contract; this is the soft, per-grid-point form)
        check_max_wait(self.batching.max_wait).map_err(|e| anyhow!("batching.{e}"))?;
        if self.workload.rps <= 0.0 || self.workload.duration <= 0.0 {
            return Err(anyhow!("workload.rps and duration must be positive"));
        }
        if !(0.0..=1.0).contains(&self.workload.interactive_frac) {
            return Err(anyhow!(
                "workload.interactive_frac must be in [0, 1], got {}",
                self.workload.interactive_frac
            ));
        }
        if self.replicas == 0 {
            return Err(anyhow!("replicas must be >= 1"));
        }
        if self.replicas > 1 && !self.scheduler.is_continuous_family() {
            return Err(anyhow!(
                "multi-replica routing requires scheduler = \"continuous\" or \
                 \"chunked\" (the router drives per-replica session schedulers)"
            ));
        }
        if self.priority == AdmissionPolicy::Classes && !self.scheduler.is_continuous_family() {
            return Err(anyhow!(
                "priority = \"classes\" requires scheduler = \"continuous\" or \
                 \"chunked\" (the static batcher never consults request classes — \
                 a priority experiment on it would silently bench plain FIFO)"
            ));
        }
        if self.prefill_chunk > u32::MAX as usize {
            return Err(anyhow!(
                "prefill_chunk {} exceeds the engine's u32 token budget",
                self.prefill_chunk
            ));
        }
        if !self.workload.interactive_slo.is_finite() || self.workload.interactive_slo < 0.0 {
            return Err(anyhow!(
                "workload.interactive_slo must be finite and >= 0, got {}",
                self.workload.interactive_slo
            ));
        }
        if !self.workload.flash_rps.is_finite() || self.workload.flash_rps < 0.0 {
            return Err(anyhow!(
                "workload.flash_rps must be finite and >= 0 (0 disables the \
                 flash-crowd overlay), got {}",
                self.workload.flash_rps
            ));
        }
        if !self.workload.flash_start.is_finite()
            || !self.workload.flash_end.is_finite()
            || self.workload.flash_end < self.workload.flash_start
        {
            return Err(anyhow!(
                "workload flash window [{}, {}) must be finite with end >= start",
                self.workload.flash_start,
                self.workload.flash_end
            ));
        }
        for (knob, name) in [
            ("memory.gpu_policy", &self.memory.gpu_policy),
            ("memory.dram_policy", &self.memory.dram_policy),
        ] {
            if name.as_str() == "auto" {
                continue; // keep the system bundle's choice
            }
            match CacheKind::by_name(name) {
                Some(CacheKind::Oracle) => {
                    return Err(anyhow!(
                        "{knob} = \"oracle\" is bench-only: Belady needs a \
                         programmatic future access trace, which a static \
                         config cannot carry (perf_tiers builds one)"
                    ));
                }
                Some(_) => {}
                None => {
                    return Err(anyhow!(
                        "unknown {knob} '{name}' (expected \"auto\" or one of \
                         activation|lru|lfu|lfuda|slru|gdsf|neighbor)"
                    ));
                }
            }
        }
        if !self.memory.ssd_iops.is_finite() || self.memory.ssd_iops < 0.0 {
            return Err(anyhow!(
                "memory.ssd_iops must be finite and >= 0 (0 disables the \
                 per-op cost model), got {}",
                self.memory.ssd_iops
            ));
        }
        if !self.memory.ssd_queue_depth.is_finite() || self.memory.ssd_queue_depth <= 0.0 {
            return Err(anyhow!(
                "memory.ssd_queue_depth must be finite and > 0 (each op \
                 queues behind that many outstanding ops), got {}",
                self.memory.ssd_queue_depth
            ));
        }
        let f = &self.faults;
        for (name, p) in [
            ("faults.ssd_failure_p", f.ssd_failure_p),
            ("faults.gpu_failure_p", f.gpu_failure_p),
        ] {
            // p = 1 would never land a prefetch and is a degenerate plan,
            // not a brownout — reject it with the NaNs
            if !(0.0..1.0).contains(&p) {
                return Err(anyhow!("{name} must be in [0, 1), got {p}"));
            }
        }
        if !f.retry_base.is_finite() || f.retry_base < 0.0 {
            return Err(anyhow!(
                "faults.retry_base must be finite and >= 0, got {}",
                f.retry_base
            ));
        }
        if !f.retry_max_delay.is_finite() || f.retry_max_delay < f.retry_base {
            return Err(anyhow!(
                "faults.retry_max_delay must be finite and >= retry_base, got {}",
                f.retry_max_delay
            ));
        }
        if f.max_retries > u32::MAX as usize {
            return Err(anyhow!("faults.max_retries {} exceeds u32", f.max_retries));
        }
        if !(f.brownout > 0.0 && f.brownout <= 1.0) {
            return Err(anyhow!(
                "faults.brownout must be in (0, 1], got {} (a zero-bandwidth \
                 link never completes any transfer)",
                f.brownout
            ));
        }
        if !f.brownout_start.is_finite()
            || !f.brownout_end.is_finite()
            || f.brownout_end < f.brownout_start
        {
            return Err(anyhow!(
                "faults.brownout window [{}, {}) must be finite with end >= start",
                f.brownout_start,
                f.brownout_end
            ));
        }
        if f.shedding && !self.scheduler.is_continuous_family() {
            return Err(anyhow!(
                "faults.shedding requires scheduler = \"continuous\" or \
                 \"chunked\" (the static batcher runs whole batches to \
                 completion — it has no iteration boundary to shed at)"
            ));
        }
        Ok(())
    }

    /// The engine-facing fault plan this config describes, or `None` when
    /// every link-fault knob is at its no-fault default (no plan installed
    /// — the bitwise-pinned fault-free replay; `faults.shedding` is a
    /// scheduler knob, not part of the plan). The plan's RNG seed derives
    /// from the config seed through a dedicated constant, so fault draws
    /// never perturb workload/arrival streams.
    pub fn fault_plan(&self) -> Option<FaultPlan> {
        let f = &self.faults;
        let browned = f.brownout < 1.0 && f.brownout_end > f.brownout_start;
        if f.ssd_failure_p <= 0.0 && f.gpu_failure_p <= 0.0 && !browned {
            return None;
        }
        let mut plan = FaultPlan::new(self.seed ^ 0xFA57);
        plan.ssd_failure_p = f.ssd_failure_p;
        plan.gpu_failure_p = f.gpu_failure_p;
        plan.retry = RetryPolicy {
            base_delay: SimTime::from_f64(f.retry_base),
            max_delay: SimTime::from_f64(f.retry_max_delay),
            max_retries: f.max_retries as u32,
        };
        if browned {
            plan.brownouts.push(Brownout {
                link: FaultLink::DramToGpu,
                start: SimTime::from_f64(f.brownout_start),
                end: SimTime::from_f64(f.brownout_end),
                factor: f.brownout,
            });
        }
        Some(plan)
    }

    /// The engine-facing chunk budget: `0` (unlimited) maps to `u32::MAX`.
    pub fn prefill_chunk_u32(&self) -> u32 {
        if self.prefill_chunk == 0 {
            u32::MAX
        } else {
            self.prefill_chunk as u32
        }
    }

    pub fn model_spec(&self) -> Result<ModelSpec> {
        ModelSpec::preset(&self.model)
            .ok_or_else(|| anyhow!("unknown model preset '{}'", self.model))
    }

    /// Build the memory-tier config for the selected system bundle.
    pub fn tier_config(&self) -> Result<TierConfig> {
        let spec = self.model_spec()?;
        let eb = spec.expert_bytes();
        // §6.2: dense part is pinned on GPU, and memory for intermediate
        // results (KV cache at max batch/output length, activations,
        // runtime) is reserved before the leftover becomes expert cache.
        // 40% reservation matches the paper's Fig. 11 operating point
        // (switch-large-128 on a 24GB A5000 -> ~15GB expert cache).
        let gpu_bytes = floor_bytes(self.memory.gpu_gb * 1e9 * 0.6);
        let dram_bytes = floor_bytes(self.memory.dram_gb * 1e9);
        let gpu_capacity = (gpu_bytes.saturating_sub(spec.dense_bytes) / eb) as usize;
        let dram_capacity = (dram_bytes / eb) as usize;
        let base = TierConfig {
            gpu_capacity,
            dram_capacity,
            backing: Tier::Ssd,
            ssd_to_dram: Link::new(self.memory.ssd_bw, 50e-6),
            dram_to_gpu: Link::new(self.memory.pcie_bw, 10e-6),
            n_gpus: self.memory.n_gpus,
            demand_extra_latency: SimTime::ZERO,
            demand_bw_factor: 1.0,
            gpu_policy: CacheKind::Activation,
            dram_policy: CacheKind::Activation,
            oracle_trace: Vec::new(),
            activation_terms: (true, true),
            prefetch_gpu_budget: 0.5,
        };
        let mut t = crate::baselines::apply_system(&self.system, base)?;
        // per-tier overrides layer on top of the bundle ("auto" = keep);
        // validate() already rejected unknown names and "oracle"
        if self.memory.gpu_policy != "auto" {
            if let Some(kind) = CacheKind::by_name(&self.memory.gpu_policy) {
                t.gpu_policy = kind;
            }
        }
        if self.memory.dram_policy != "auto" {
            if let Some(kind) = CacheKind::by_name(&self.memory.dram_policy) {
                t.dram_policy = kind;
            }
        }
        if self.memory.ssd_iops > 0.0 {
            t.ssd_to_dram = t
                .ssd_to_dram
                .with_iops(self.memory.ssd_iops, self.memory.ssd_queue_depth);
        }
        Ok(t)
    }

    pub fn predictor_kind(&self) -> Result<PredictorKind> {
        crate::baselines::predictor_for(&self.system)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_toml() {
        let c = ServeConfig::default();
        let text = c.to_toml();
        let back = ServeConfig::from_toml(&text).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn partial_override_keeps_defaults() {
        let c = ServeConfig::from_toml("model = \"nllb-moe-128\"\n[workload]\nrps = 2.5\n").unwrap();
        assert_eq!(c.model, "nllb-moe-128");
        assert_eq!(c.workload.rps, 2.5);
        assert_eq!(c.batching.max_batch, 16); // default preserved
    }

    #[test]
    fn model_spec_resolution() {
        let c = ServeConfig::default();
        assert_eq!(c.model_spec().unwrap().name, "switch-base-128");
        let bad = ServeConfig {
            model: "nope".into(),
            ..Default::default()
        };
        assert!(bad.model_spec().is_err());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(ServeConfig::from_toml("dataset = \"imagenet\"").is_err());
        assert!(ServeConfig::from_toml("system = \"vllm\"").is_err());
        assert!(ServeConfig::from_toml("[batching]\nmax_batch = 0").is_err());
        assert!(ServeConfig::from_toml("scheduler = \"orca\"").is_err());
    }

    #[test]
    fn scheduler_parses_and_roundtrips() {
        let c = ServeConfig::from_toml("scheduler = \"continuous\"").unwrap();
        assert_eq!(c.scheduler, SchedulerKind::Continuous);
        let back = ServeConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.scheduler, SchedulerKind::Continuous);
        // default stays the paper's static methodology
        assert_eq!(ServeConfig::default().scheduler, SchedulerKind::Static);
        assert_eq!(SchedulerKind::by_name("static"), Some(SchedulerKind::Static));
        assert_eq!(SchedulerKind::by_name("orca"), None);
    }

    #[test]
    fn chunked_scheduler_parses_and_roundtrips() {
        let c =
            ServeConfig::from_toml("scheduler = \"chunked\"\nprefill_chunk = 128").unwrap();
        assert_eq!(c.scheduler, SchedulerKind::Chunked);
        assert_eq!(c.prefill_chunk, 128);
        assert_eq!(c.prefill_chunk_u32(), 128);
        let back = ServeConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c, back);
        // 0 = unlimited maps to the engine's "no budget" sentinel
        let inf = ServeConfig::from_toml("scheduler = \"chunked\"\nprefill_chunk = 0").unwrap();
        assert_eq!(inf.prefill_chunk_u32(), u32::MAX);
        // chunked is a continuous-family scheduler: router + classes compose
        assert!(ServeConfig::from_toml("scheduler = \"chunked\"\nreplicas = 2").is_ok());
        assert!(
            ServeConfig::from_toml("scheduler = \"chunked\"\npriority = \"classes\"").is_ok()
        );
        assert!(SchedulerKind::Chunked.is_continuous_family());
        assert!(!SchedulerKind::Static.is_continuous_family());
    }

    #[test]
    fn routing_and_priority_parse_and_roundtrip() {
        let c = ServeConfig::from_toml(
            "scheduler = \"continuous\"\npriority = \"classes\"\nreplicas = 4\nrouting = \"task-affinity\"\ncancel_retired_prefetch = true\n[workload]\ninteractive_frac = 0.25\n",
        )
        .unwrap();
        assert_eq!(c.priority, AdmissionPolicy::Classes);
        assert_eq!(c.replicas, 4);
        assert_eq!(c.routing, RoutingPolicy::TaskAffinity);
        assert!(c.cancel_retired_prefetch);
        assert_eq!(c.workload.interactive_frac, 0.25);
        let back = ServeConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c, back);
        // defaults preserve the pre-router serving surface
        let d = ServeConfig::default();
        assert_eq!(d.priority, AdmissionPolicy::Fifo);
        assert_eq!(d.replicas, 1);
        assert_eq!(d.routing, RoutingPolicy::RoundRobin);
        // cancellation graduated to default-on (BENCH_scheduler cancel_*
        // rows: dead-traffic savings at no p99 cost)
        assert!(d.cancel_retired_prefetch);
        assert_eq!(d.workload.interactive_frac, 0.0);
    }

    #[test]
    fn invalid_router_configs_rejected() {
        assert!(ServeConfig::from_toml("priority = \"vip\"").is_err());
        assert!(ServeConfig::from_toml("routing = \"random\"").is_err());
        assert!(ServeConfig::from_toml("replicas = 0").is_err());
        // replicas > 1 without the continuous scheduler is a config error
        assert!(ServeConfig::from_toml("replicas = 2").is_err());
        assert!(ServeConfig::from_toml("scheduler = \"continuous\"\nreplicas = 2").is_ok());
        assert!(ServeConfig::from_toml("[workload]\ninteractive_frac = 1.5").is_err());
        assert!(ServeConfig::from_toml("cancel_retired_prefetch = 3").is_err());
        // classes admission on the static batcher would be a silent no-op
        assert!(ServeConfig::from_toml("priority = \"classes\"").is_err());
        assert!(
            ServeConfig::from_toml("scheduler = \"continuous\"\npriority = \"classes\"").is_ok()
        );
    }

    #[test]
    fn invalid_max_wait_rejected() {
        let mut c = ServeConfig::default();
        c.model = "switch-base-32".into();
        c.batching.max_wait = f64::NAN;
        assert!(c.validate().is_err(), "NaN max_wait must not validate");
        c.batching.max_wait = -1.0;
        assert!(c.validate().is_err(), "negative max_wait must not validate");
        c.batching.max_wait = f64::INFINITY;
        assert!(c.validate().is_err(), "infinite max_wait must not validate");
        c.batching.max_wait = 0.0;
        assert!(c.validate().is_ok(), "zero window is a valid policy");
    }

    #[test]
    fn faults_parse_roundtrip_and_map_to_a_plan() {
        let c = ServeConfig::from_toml(
            "scheduler = \"continuous\"\nseed = 7\n[workload]\ninteractive_frac = 0.5\ninteractive_slo = 2.5\n[faults]\nssd_failure_p = 0.1\ngpu_failure_p = 0.05\nmax_retries = 3\nbrownout = 0.5\nbrownout_start = 1.0\nbrownout_end = 4.0\nshedding = true\n",
        )
        .unwrap();
        assert_eq!(c.faults.ssd_failure_p, 0.1);
        assert_eq!(c.faults.gpu_failure_p, 0.05);
        assert_eq!(c.faults.max_retries, 3);
        assert!(c.faults.shedding);
        assert_eq!(c.workload.interactive_slo, 2.5);
        let back = ServeConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c, back);
        let plan = c.fault_plan().expect("non-default faults yield a plan");
        assert_eq!(plan.ssd_failure_p, 0.1);
        assert_eq!(plan.gpu_failure_p, 0.05);
        assert_eq!(plan.retry.max_retries, 3);
        assert_eq!(plan.brownouts.len(), 1);
        assert_eq!(plan.seed, 7 ^ 0xFA57);
        assert!(plan.crashes.is_empty(), "crash windows are programmatic-only");
        // the default config carries no plan at all
        assert!(ServeConfig::default().fault_plan().is_none());
        // a brownout with an empty window is a no-op, not a plan
        let mut d = ServeConfig::default();
        d.faults.brownout = 0.5;
        assert!(d.fault_plan().is_none());
        d.faults.brownout_end = 2.0;
        assert!(d.fault_plan().is_some());
    }

    #[test]
    fn invalid_fault_configs_rejected() {
        assert!(ServeConfig::from_toml("[faults]\nssd_failure_p = 1.0").is_err());
        assert!(ServeConfig::from_toml("[faults]\ngpu_failure_p = -0.1").is_err());
        assert!(ServeConfig::from_toml("[faults]\nbrownout = 0.0").is_err());
        assert!(ServeConfig::from_toml("[faults]\nbrownout = 1.5").is_err());
        assert!(
            ServeConfig::from_toml("[faults]\nbrownout_start = 5.0\nbrownout_end = 1.0").is_err()
        );
        assert!(ServeConfig::from_toml("[faults]\nretry_base = -1.0").is_err());
        assert!(
            ServeConfig::from_toml("[faults]\nretry_base = 0.01\nretry_max_delay = 0.001")
                .is_err()
        );
        assert!(ServeConfig::from_toml("[faults]\nshedding = 3").is_err());
        // shedding needs an iteration boundary: static batching is rejected
        assert!(ServeConfig::from_toml("[faults]\nshedding = true").is_err());
        assert!(
            ServeConfig::from_toml("scheduler = \"continuous\"\n[faults]\nshedding = true")
                .is_ok()
        );
        assert!(ServeConfig::from_toml("[workload]\ninteractive_slo = -1.0").is_err());
    }

    #[test]
    fn flash_crowd_knobs_parse_roundtrip_and_validate() {
        let c = ServeConfig::from_toml(
            "scheduler = \"continuous\"\n[workload]\nrps = 10.0\nflash_rps = 2000.0\nflash_start = 3.0\nflash_end = 5.0\n",
        )
        .unwrap();
        assert_eq!(c.workload.flash_rps, 2000.0);
        assert_eq!(c.workload.flash_start, 3.0);
        assert_eq!(c.workload.flash_end, 5.0);
        let back = ServeConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c, back);
        // the default overlay is off: historical single-rate stream
        let d = ServeConfig::default();
        assert_eq!(d.workload.flash_rps, 0.0);
        assert_eq!((d.workload.flash_start, d.workload.flash_end), (0.0, 0.0));
        // rejected shapes
        assert!(ServeConfig::from_toml("[workload]\nflash_rps = -5.0").is_err());
        assert!(
            ServeConfig::from_toml("[workload]\nflash_start = 5.0\nflash_end = 1.0").is_err()
        );
        // a zero-width window with a rate is a no-op, not an error (the
        // brownout-window convention)
        assert!(
            ServeConfig::from_toml("[workload]\nflash_rps = 100.0\nflash_start = 2.0\nflash_end = 2.0")
                .is_ok()
        );
    }

    #[test]
    fn per_tier_policies_parse_roundtrip_and_apply() {
        let c = ServeConfig::from_toml(
            "[memory]\ngpu_policy = \"slru\"\ndram_policy = \"gdsf\"\nssd_iops = 50000.0\nssd_queue_depth = 8.0\n",
        )
        .unwrap();
        assert_eq!(c.memory.gpu_policy, "slru");
        assert_eq!(c.memory.dram_policy, "gdsf");
        let back = ServeConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c, back);
        let t = c.tier_config().unwrap();
        assert_eq!(t.gpu_policy, CacheKind::Slru);
        assert_eq!(t.dram_policy, CacheKind::Gdsf);
        assert!(t.ssd_to_dram.iops.is_some(), "iops term attached to SSD link");
        assert!(t.dram_to_gpu.iops.is_none(), "PCIe link stays pure-bandwidth");
        // "auto" defers to the system bundle and leaves the link plain —
        // the bitwise-default serving path
        let d = ServeConfig::default();
        assert_eq!(d.memory.gpu_policy, "auto");
        assert_eq!(d.memory.ssd_iops, 0.0);
        let td = d.tier_config().unwrap();
        assert_eq!(td.gpu_policy, CacheKind::Activation);
        assert_eq!(td.dram_policy, CacheKind::Activation);
        assert!(td.ssd_to_dram.iops.is_none());
        // an override on one tier keeps the bundle's choice on the other
        let g = ServeConfig::from_toml("[memory]\ndram_policy = \"lfuda\"\n").unwrap();
        let tg = g.tier_config().unwrap();
        assert_eq!(tg.gpu_policy, CacheKind::Activation);
        assert_eq!(tg.dram_policy, CacheKind::Lfuda);
    }

    #[test]
    fn invalid_tier_policy_configs_rejected() {
        assert!(ServeConfig::from_toml("[memory]\ngpu_policy = \"belady\"").is_err());
        assert!(ServeConfig::from_toml("[memory]\ndram_policy = \"fifo\"").is_err());
        // oracle is bench-only: a static config cannot carry its trace
        assert!(ServeConfig::from_toml("[memory]\ngpu_policy = \"oracle\"").is_err());
        assert!(ServeConfig::from_toml("[memory]\nssd_iops = -1.0").is_err());
        assert!(ServeConfig::from_toml("[memory]\nssd_queue_depth = 0.0").is_err());
        assert!(ServeConfig::from_toml("[memory]\nssd_queue_depth = -2.0").is_err());
        // every non-oracle zoo member is accepted on either tier
        for kind in ["activation", "lru", "lfu", "lfuda", "slru", "gdsf", "neighbor"] {
            let toml = format!("[memory]\ngpu_policy = \"{kind}\"\ndram_policy = \"{kind}\"\n");
            assert!(ServeConfig::from_toml(&toml).is_ok(), "{kind} must validate");
        }
    }

    #[test]
    fn tier_config_respects_budgets() {
        let c = ServeConfig::default();
        let spec = c.model_spec().unwrap();
        let t = c.tier_config().unwrap();
        let eb = spec.expert_bytes();
        assert!(t.gpu_capacity as u64 * eb <= floor_bytes(c.memory.gpu_gb * 1e9));
        assert!(t.dram_capacity as u64 * eb <= floor_bytes(c.memory.dram_gb * 1e9));
    }

    #[test]
    fn file_load_missing_errors() {
        assert!(ServeConfig::from_toml_file(Path::new("/nonexistent.toml")).is_err());
    }
}
