//! `moe-infinity` CLI: the L3 leader entrypoint.
//!
//! Subcommands (hand-rolled parser — the image has no clap):
//!   serve     — replay an Azure-style workload through the simulated
//!               serving stack and print the latency/throughput report
//!   generate  — run the REAL tiny MoE end-to-end via PJRT artifacts
//!   models    — list model presets with geometry
//!   config    — print the default serving config TOML
//!   systems   — list system policy bundles

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use moe_infinity::baselines::SYSTEMS;
use moe_infinity::benchsuite;
use moe_infinity::config::ServeConfig;
use moe_infinity::engine::RealMoeEngine;
use moe_infinity::memory::TierConfig;
use moe_infinity::model::{ModelSpec, PRESETS};
use moe_infinity::prefetch::PredictorKind;
use moe_infinity::util::{fmt_bytes, fmt_secs, Pool, Rng};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{}'", argv[i]))?;
            let v = argv
                .get(i + 1)
                .ok_or_else(|| anyhow!("missing value for --{k}"))?;
            flags.insert(k.to_string(), v.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn get_f64(&self, k: &str) -> Result<Option<f64>> {
        self.get(k)
            .map(|v| v.parse::<f64>().map_err(|e| anyhow!("--{k}: {e}")))
            .transpose()
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match argv.first().map(|s| s.as_str()) {
        Some("serve") => cmd_serve(&argv[1..]),
        Some("generate") => cmd_generate(&argv[1..]),
        Some("models") => cmd_models(),
        Some("systems") => {
            for s in SYSTEMS {
                println!("{s}");
            }
            Ok(())
        }
        Some("config") => {
            print!("{}", ServeConfig::default().to_toml());
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: moe-infinity <serve|generate|models|systems|config> [--flag value ...]\n\
                 \n\
                 serve    --config <toml> | --model <preset> --system <name> --rps <f> --duration <s>\n\
                 \x20        [--scheduler static|continuous|chunked]  batching discipline (default:\n\
                 \x20        static run-to-completion; continuous admits/retires at iteration\n\
                 \x20        boundaries; chunked additionally splits joining prompts)\n\
                 \x20        [--prefill-chunk <n>]  chunked per-iteration prompt-token budget\n\
                 \x20        (0 = unlimited, bitwise identical to continuous)\n\
                 \x20        [--priority fifo|classes]  continuous admission: strict FIFO or\n\
                 \x20        priority classes with SLO slack + voluntary preemption\n\
                 \x20        [--replicas <n>]  engine replicas behind the request router\n\
                 \x20        [--routing round-robin|least-loaded|task-affinity]  replica dispatch\n\
                 \x20        [--interactive-frac <f>]  fraction of requests tagged interactive\n\
                 \x20        [--interactive-slo <s>]  deadline attached to interactive requests\n\
                 \x20        (0 = none; enables goodput accounting and --shedding)\n\
                 \x20        [--flash-rps <f>] [--flash-start <s>] [--flash-end <s>]  flash-crowd\n\
                 \x20        overlay: arrivals draw at flash-rps inside the window (0 = off,\n\
                 \x20        the historical single-rate stream)\n\
                 \x20        [--gpu-policy <kind>] [--dram-policy <kind>]  per-tier eviction\n\
                 \x20        override: activation|lru|lfu|lfuda|slru|gdsf|neighbor (default\n\
                 \x20        \"auto\" keeps the system bundle's choice; oracle is bench-only)\n\
                 \x20        [--ssd-iops <f>] [--ssd-queue-depth <f>]  SSD per-op cost model:\n\
                 \x20        each SSD->DRAM transfer pays queue-depth/IOPS on top of the\n\
                 \x20        bandwidth term (0 IOPS = off, the pre-IOPS link model)\n\
                 \x20        [--ssd-failure-p <p>] [--gpu-failure-p <p>]  per-transfer transient\n\
                 \x20        failure probability on each link (deterministic, seeded; retried\n\
                 \x20        with capped exponential backoff in simulated time)\n\
                 \x20        [--brownout <f>] [--brownout-start <s>] [--brownout-end <s>]\n\
                 \x20        bandwidth multiplier in (0,1] over a virtual-time window\n\
                 \x20        (no window = whole replay)\n\
                 \x20        [--shedding on|off]  shed/abort requests whose SLO deadline already\n\
                 \x20        passed (continuous/chunked schedulers only)\n\
                 \x20        [--threads <n>]  offline-construction workers (default:\n\
                 \x20        MOE_POOL_THREADS or all cores; results identical at any count)\n\
                 generate --artifacts <dir> --prompts <n> --tokens <n>\n"
            );
            Err(anyhow!("missing or unknown subcommand"))
        }
    }
}

fn cmd_models() -> Result<()> {
    println!(
        "{:<18} {:>7} {:>8} {:>8} {:>10} {:>12}",
        "preset", "layers", "experts", "total", "expert", "all-experts"
    );
    for name in PRESETS {
        let s = ModelSpec::preset(name).unwrap();
        println!(
            "{:<18} {:>7} {:>8} {:>8} {:>10} {:>12}",
            s.name,
            s.n_layers,
            s.experts_per_layer,
            s.total_experts(),
            fmt_bytes(s.expert_bytes()),
            fmt_bytes(s.total_expert_bytes()),
        );
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let mut cfg = if let Some(path) = args.get("config") {
        ServeConfig::from_toml_file(&PathBuf::from(path))?
    } else {
        ServeConfig::default()
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.into();
    }
    if let Some(s) = args.get("system") {
        cfg.system = s.into();
    }
    if let Some(d) = args.get("dataset") {
        cfg.dataset = d.into();
    }
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = moe_infinity::config::SchedulerKind::by_name(s)
            .ok_or_else(|| anyhow!("--scheduler: unknown '{s}' (static|continuous|chunked)"))?;
    }
    if let Some(n) = args.get("prefill-chunk") {
        cfg.prefill_chunk = n.parse::<usize>().map_err(|e| anyhow!("--prefill-chunk: {e}"))?;
    }
    if let Some(p) = args.get("priority") {
        cfg.priority = moe_infinity::server::AdmissionPolicy::by_name(p)
            .ok_or_else(|| anyhow!("--priority: unknown '{p}' (fifo|classes)"))?;
    }
    if let Some(n) = args.get("replicas") {
        cfg.replicas = n.parse::<usize>().map_err(|e| anyhow!("--replicas: {e}"))?;
    }
    if let Some(r) = args.get("routing") {
        cfg.routing = moe_infinity::server::RoutingPolicy::by_name(r).ok_or_else(|| {
            anyhow!("--routing: unknown '{r}' (round-robin|least-loaded|task-affinity)")
        })?;
    }
    if let Some(f) = args.get_f64("interactive-frac")? {
        cfg.workload.interactive_frac = f;
    }
    if let Some(s) = args.get_f64("interactive-slo")? {
        cfg.workload.interactive_slo = s;
    }
    if let Some(r) = args.get_f64("rps")? {
        cfg.workload.rps = r;
    }
    if let Some(d) = args.get_f64("duration")? {
        cfg.workload.duration = d;
    }
    if let Some(r) = args.get_f64("flash-rps")? {
        cfg.workload.flash_rps = r;
    }
    if let Some(t) = args.get_f64("flash-start")? {
        cfg.workload.flash_start = t;
    }
    if let Some(t) = args.get_f64("flash-end")? {
        cfg.workload.flash_end = t;
    }
    if let Some(p) = args.get("gpu-policy") {
        cfg.memory.gpu_policy = p.into();
    }
    if let Some(p) = args.get("dram-policy") {
        cfg.memory.dram_policy = p.into();
    }
    if let Some(i) = args.get_f64("ssd-iops")? {
        cfg.memory.ssd_iops = i;
    }
    if let Some(q) = args.get_f64("ssd-queue-depth")? {
        cfg.memory.ssd_queue_depth = q;
    }
    if let Some(p) = args.get_f64("ssd-failure-p")? {
        cfg.faults.ssd_failure_p = p;
    }
    if let Some(p) = args.get_f64("gpu-failure-p")? {
        cfg.faults.gpu_failure_p = p;
    }
    if let Some(t) = args.get_f64("brownout-start")? {
        cfg.faults.brownout_start = t;
    }
    if let Some(t) = args.get_f64("brownout-end")? {
        cfg.faults.brownout_end = t;
    }
    if let Some(b) = args.get_f64("brownout")? {
        cfg.faults.brownout = b;
        // a factor without a window means "the whole replay" (the window
        // must stay finite for validate(), so use the largest finite bound)
        if cfg.faults.brownout_end <= cfg.faults.brownout_start {
            cfg.faults.brownout_end = f64::MAX;
        }
    }
    if let Some(s) = args.get("shedding") {
        cfg.faults.shedding = match s {
            "true" | "on" | "1" => true,
            "false" | "off" | "0" => false,
            other => return Err(anyhow!("--shedding: expected on|off, got '{other}'")),
        };
    }
    cfg.validate()?;
    // worker count for the offline side (EAMC construction); the replay
    // itself is one engine's virtual timeline and the results are bitwise
    // identical at any thread count
    let pool = match args.get("threads") {
        Some(t) => Pool::new(t.parse::<usize>().map_err(|e| anyhow!("--threads: {e}"))?),
        None => Pool::from_env(),
    };

    let chunk_desc = if cfg.scheduler == moe_infinity::config::SchedulerKind::Chunked {
        if cfg.prefill_chunk == 0 {
            " prefill-chunk=unlimited".to_string()
        } else {
            format!(" prefill-chunk={}", cfg.prefill_chunk)
        }
    } else {
        String::new()
    };
    let flash_desc = if cfg.workload.flash_rps > 0.0 && cfg.workload.flash_end > cfg.workload.flash_start
    {
        format!(
            " flash={}rps@[{},{})s",
            cfg.workload.flash_rps, cfg.workload.flash_start, cfg.workload.flash_end
        )
    } else {
        String::new()
    };
    println!(
        "serving {} [{}] dataset={} scheduler={}{} priority={} replicas={} routing={} rps={}{} duration={}s (offline pool: {} threads) ...",
        cfg.model,
        cfg.system,
        cfg.dataset,
        cfg.scheduler.name(),
        chunk_desc,
        cfg.priority.name(),
        cfg.replicas,
        cfg.routing.name(),
        cfg.workload.rps,
        flash_desc,
        cfg.workload.duration,
        pool.threads()
    );
    let mut report = benchsuite::run_serve_with(&cfg, &pool)?;
    println!("requests        : {}", report.requests);
    println!(
        "{}: {}",
        if cfg.scheduler.is_continuous_family() {
            "iterations      "
        } else {
            "batches         "
        },
        report.batches
    );
    println!("tokens          : {}", report.tokens);
    println!("mean token lat  : {}", fmt_secs(report.token_latency.mean()));
    println!("p50  token lat  : {}", fmt_secs(report.token_latency.p50()));
    println!("p99  token lat  : {}", fmt_secs(report.token_latency.p99()));
    println!("p50  request lat: {}", fmt_secs(report.request_latency.p50()));
    println!("p99  request lat: {}", fmt_secs(report.request_latency.p99()));
    println!("p50  TTFT       : {}", fmt_secs(report.ttft.p50()));
    println!("p99  TTFT       : {}", fmt_secs(report.ttft.p99()));
    println!("p50  TPOT       : {}", fmt_secs(report.tpot.p50()));
    println!("p99  TPOT       : {}", fmt_secs(report.tpot.p99()));
    if report.decode_latency.len() > 0 {
        println!(
            "p99  decode step: {}",
            fmt_secs(report.decode_latency.p99())
        );
    }
    println!("GPU hit ratio   : {:.3}", report.gpu_hit_ratio());
    println!("throughput      : {:.1} tokens/s", report.token_throughput());
    println!("goodput         : {:.1} tokens/s", report.goodput());
    if report.shed + report.timed_out > 0 {
        println!("shed            : {}", report.shed);
        println!("timed out       : {}", report.timed_out);
    }
    if report.transfer_retries + report.demand_failures > 0 {
        println!("transfer retries: {}", report.transfer_retries);
        println!("demand failures : {}", report.demand_failures);
    }
    Ok(())
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let n_prompts: usize = args
        .get("prompts")
        .unwrap_or("4")
        .parse()
        .map_err(|e| anyhow!("--prompts: {e}"))?;
    let tokens: usize = args
        .get("tokens")
        .unwrap_or("16")
        .parse()
        .map_err(|e| anyhow!("--tokens: {e}"))?;

    let tier = {
        let cfg = moe_infinity::model::weights::TinyConfig::from_manifest(&artifacts)?;
        let spec = moe_infinity::engine::real::tiny_spec(&cfg);
        let mut t = TierConfig::default_for(&spec, spec.total_bytes() / 3, spec.total_bytes());
        t.gpu_capacity = (spec.total_experts() / 3).max(2);
        t
    };
    let mut eng = RealMoeEngine::new(
        &artifacts,
        7,
        4,
        tier,
        PredictorKind::ActivationAware { refine: true },
    )?;
    let cfg = eng.cfg().clone();
    println!(
        "loaded tiny MoE: {} layers x {} experts, d_model {}, vocab {}",
        cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.vocab
    );

    // task-clustered prompts: tokens drawn from one vocab slice per prompt
    let mut rng = Rng::new(99);
    let per = cfg.vocab / 4;
    let batch = cfg.batch;
    let vocab_slices = 4;
    let mk_prompts = |rng: &mut Rng, n: usize| -> Vec<Vec<i32>> {
        (0..n.min(batch))
            .map(|_| {
                let task = rng.below(vocab_slices);
                (0..8)
                    .map(|_| (task * per + rng.below(per)) as i32)
                    .collect()
            })
            .collect()
    };

    // offline tracing phase to build the EAMC
    let trace_sets: Vec<Vec<Vec<i32>>> = (0..6).map(|_| mk_prompts(&mut rng, batch)).collect();
    eng.build_eamc(&trace_sets, 8, 16)?;
    println!("EAMC built: {} entries", eng.eamc().len());

    let prompts = mk_prompts(&mut rng, n_prompts);
    let out = eng.generate(&prompts, tokens)?;
    for (i, row) in out.tokens.iter().enumerate() {
        println!("seq {i}: {row:?}");
    }
    let lats = out.token_latencies();
    let mean = lats.iter().sum::<f64>() / lats.len() as f64;
    println!(
        "tokens/seq={} mean-token-latency={} (compute {} + stall {}) recall={:.2}",
        tokens,
        fmt_secs(mean),
        fmt_secs(out.compute_wall.iter().sum::<f64>() / lats.len() as f64),
        fmt_secs(out.fetch_stall.iter().sum::<f64>() / lats.len() as f64),
        out.recall()
    );
    Ok(())
}
