//! A minimal hand-rolled Rust tokenizer for `moelint`.
//!
//! This is *not* a Rust parser: it only has to be precise about the things
//! a token-level lint can get wrong — comments (so pragmas are found and
//! code in doc examples is ignored), string/char literals (so rule fixtures
//! embedded as strings are never mistaken for code), raw strings, lifetimes
//! vs char literals, numeric literals (int vs float, for rule R4), and the
//! `::` path separator (so `HashMap::new` / `Instant::now` match as token
//! triples). Everything else is a single-character punct.

/// Token kinds relevant to the rule walkers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`HashMap`, `as`, `unsafe`, ...).
    Ident,
    /// `'a` — distinguished from char literals.
    Lifetime,
    /// String, raw-string, byte-string or char literal (contents opaque).
    Str,
    /// Integer literal (`42`, `0xFF`, `1_000u64`).
    Int,
    /// Float literal (`1.0`, `1e9`, `2f64`).
    Float,
    /// `::`
    PathSep,
    /// Any other single character (`!`, `(`, `<`, ...).
    Punct(char),
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    /// Identifier text (empty for non-identifiers — the rules only ever
    /// match on identifier spelling).
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// A `//` line comment (block comments are skipped entirely — pragmas must
/// be line comments so their anchor line is unambiguous).
#[derive(Debug, Clone)]
pub struct Comment {
    /// Text after the `//` (doc-comment markers included verbatim).
    pub text: String,
    pub line: u32,
    /// `true` when code tokens precede the comment on its line (a trailing
    /// pragma applies to that line); `false` for a standalone comment line
    /// (a standalone pragma applies to the next code line).
    pub trailing: bool,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

struct Lexer {
    cs: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
    line_had_token: bool,
    out: Lexed,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.cs.get(self.i + ahead).copied()
    }

    /// Advance one char, maintaining line/col counters.
    fn bump(&mut self) {
        if self.cs[self.i] == '\n' {
            self.line += 1;
            self.col = 1;
            self.line_had_token = false;
        } else {
            self.col += 1;
        }
        self.i += 1;
    }

    fn push(&mut self, kind: TokKind, text: String, line: u32, col: u32) {
        self.line_had_token = true;
        self.out.tokens.push(Token { kind, text, line, col });
    }

    fn line_comment(&mut self) {
        let (line, trailing) = (self.line, self.line_had_token);
        self.bump();
        self.bump(); // the two slashes
        let start = self.i;
        while self.i < self.cs.len() && self.cs[self.i] != '\n' {
            self.bump();
        }
        let text: String = self.cs[start..self.i].iter().collect();
        self.out.comments.push(Comment { text, line, trailing });
    }

    fn block_comment(&mut self) {
        self.bump();
        self.bump();
        let mut depth = 1usize;
        while self.i < self.cs.len() && depth > 0 {
            if self.cs[self.i] == '/' && self.peek(1) == Some('*') {
                depth += 1;
                self.bump();
                self.bump();
            } else if self.cs[self.i] == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                self.bump();
                self.bump();
            } else {
                self.bump();
            }
        }
    }

    /// Normal (escaped) string body; the opening quote is current.
    fn quoted_string(&mut self) {
        self.bump(); // opening "
        while self.i < self.cs.len() {
            match self.cs[self.i] {
                '\\' => {
                    self.bump();
                    if self.i < self.cs.len() {
                        self.bump(); // the escaped char
                    }
                }
                '"' => {
                    self.bump();
                    return;
                }
                _ => self.bump(),
            }
        }
    }

    /// Raw string body starting at the first `#` or `"` after the `r`
    /// prefix. Returns `false` if this is not actually a raw string (e.g. a
    /// raw identifier `r#foo`), in which case nothing is consumed.
    fn raw_string(&mut self) -> bool {
        let mut hashes = 0usize;
        while self.peek(hashes) == Some('#') {
            hashes += 1;
        }
        if self.peek(hashes) != Some('"') {
            return false;
        }
        for _ in 0..=hashes {
            self.bump(); // hashes + opening quote
        }
        'scan: while self.i < self.cs.len() {
            if self.cs[self.i] == '"' {
                for k in 0..hashes {
                    if self.peek(1 + k) != Some('#') {
                        self.bump();
                        continue 'scan;
                    }
                }
                for _ in 0..=hashes {
                    self.bump(); // closing quote + hashes
                }
                return true;
            }
            self.bump();
        }
        true
    }

    /// Char literal or lifetime; the `'` is current.
    fn char_or_lifetime(&mut self) {
        let (line, col) = (self.line, self.col);
        self.bump(); // '
        match self.peek(0) {
            Some('\\') => {
                // escaped char literal: scan to the closing quote
                while self.i < self.cs.len() {
                    match self.cs[self.i] {
                        '\\' => {
                            self.bump();
                            if self.i < self.cs.len() {
                                self.bump();
                            }
                        }
                        '\'' => {
                            self.bump();
                            break;
                        }
                        _ => self.bump(),
                    }
                }
                self.push(TokKind::Str, String::new(), line, col);
            }
            Some(c) if is_ident_start(c) && self.peek(1) != Some('\'') => {
                // lifetime: 'ident not closed by a quote
                self.bump();
                while self.peek(0).is_some_and(is_ident_continue) {
                    self.bump();
                }
                self.push(TokKind::Lifetime, String::new(), line, col);
            }
            Some(_) => {
                // plain char literal 'x' (including non-ident chars)
                self.bump();
                if self.peek(0) == Some('\'') {
                    self.bump();
                }
                self.push(TokKind::Str, String::new(), line, col);
            }
            None => {}
        }
    }

    /// Numeric literal; first digit is current.
    fn number(&mut self) {
        let (line, col) = (self.line, self.col);
        let mut float = false;
        if self.cs[self.i] == '0' && matches!(self.peek(1), Some('x' | 'o' | 'b' | 'X' | 'O' | 'B'))
        {
            self.bump();
            self.bump();
            while self.peek(0).is_some_and(|c| c.is_ascii_alphanumeric() || c == '_') {
                self.bump();
            }
            self.push(TokKind::Int, String::new(), line, col);
            return;
        }
        while self.peek(0).is_some_and(|c| c.is_ascii_digit() || c == '_') {
            self.bump();
        }
        if self.peek(0) == Some('.') && self.peek(1).is_some_and(|c| c.is_ascii_digit()) {
            float = true;
            self.bump();
            while self.peek(0).is_some_and(|c| c.is_ascii_digit() || c == '_') {
                self.bump();
            }
        }
        if matches!(self.peek(0), Some('e' | 'E'))
            && (self.peek(1).is_some_and(|c| c.is_ascii_digit())
                || (matches!(self.peek(1), Some('+' | '-'))
                    && self.peek(2).is_some_and(|c| c.is_ascii_digit())))
        {
            float = true;
            self.bump(); // e
            if matches!(self.peek(0), Some('+' | '-')) {
                self.bump();
            }
            while self.peek(0).is_some_and(|c| c.is_ascii_digit()) {
                self.bump();
            }
        }
        // type suffix (u64, f32, ...)
        let suffix_start = self.i;
        while self.peek(0).is_some_and(is_ident_continue) {
            self.bump();
        }
        if self.cs.get(suffix_start) == Some(&'f') {
            float = true;
        }
        let kind = if float { TokKind::Float } else { TokKind::Int };
        self.push(kind, String::new(), line, col);
    }

    fn ident(&mut self) {
        let (line, col) = (self.line, self.col);
        let start = self.i;
        while self.peek(0).is_some_and(is_ident_continue) {
            self.bump();
        }
        let text: String = self.cs[start..self.i].iter().collect();
        // raw / byte string prefixes
        if matches!(text.as_str(), "r" | "br" | "rb") {
            match self.peek(0) {
                Some('"') | Some('#') => {
                    if self.raw_string() {
                        self.push(TokKind::Str, String::new(), line, col);
                        return;
                    }
                    // r#ident — a raw identifier: fall through, consuming
                    // the hash and the identifier proper
                    if self.peek(0) == Some('#') {
                        self.bump();
                        let rs = self.i;
                        while self.peek(0).is_some_and(is_ident_continue) {
                            self.bump();
                        }
                        let raw: String = self.cs[rs..self.i].iter().collect();
                        self.push(TokKind::Ident, raw, line, col);
                        return;
                    }
                }
                _ => {}
            }
        }
        if text == "b" && self.peek(0) == Some('"') {
            self.quoted_string();
            self.push(TokKind::Str, String::new(), line, col);
            return;
        }
        self.push(TokKind::Ident, text, line, col);
    }

    fn run(mut self) -> Lexed {
        while self.i < self.cs.len() {
            let c = self.cs[self.i];
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment();
            } else if c == '"' {
                let (line, col) = (self.line, self.col);
                self.quoted_string();
                self.push(TokKind::Str, String::new(), line, col);
            } else if c == '\'' {
                self.char_or_lifetime();
            } else if c.is_ascii_digit() {
                self.number();
            } else if is_ident_start(c) {
                self.ident();
            } else if c == ':' && self.peek(1) == Some(':') {
                let (line, col) = (self.line, self.col);
                self.bump();
                self.bump();
                self.push(TokKind::PathSep, String::new(), line, col);
            } else {
                let (line, col) = (self.line, self.col);
                self.bump();
                self.push(TokKind::Punct(c), String::new(), line, col);
            }
        }
        self.out
    }
}

/// Tokenize `src`, returning code tokens and line comments.
pub fn lex(src: &str) -> Lexed {
    Lexer {
        cs: src.chars().collect(),
        i: 0,
        line: 1,
        col: 1,
        line_had_token: false,
        out: Lexed::default(),
    }
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn idents_paths_and_macros() {
        let l = lex("let m = HashMap::new(); q!();");
        let kinds: Vec<_> = l.tokens.iter().map(|t| t.kind.clone()).collect();
        assert_eq!(
            idents("let m = HashMap::new(); q!();"),
            vec!["let", "m", "HashMap", "new", "q"]
        );
        assert!(kinds.contains(&TokKind::PathSep));
        assert!(kinds.contains(&TokKind::Punct('!')));
    }

    #[test]
    fn strings_hide_code() {
        // code inside string literals must not produce identifier tokens
        assert_eq!(idents(r##"let s = "HashMap::new()"; "##), vec!["let", "s"]);
        assert_eq!(
            idents("let s = r#\"unsafe { Instant::now() }\"#;"),
            vec!["let", "s"]
        );
        assert_eq!(idents("let s = \"esc \\\" HashMap\";"), vec!["let", "s"]);
        assert_eq!(idents("let b = b\"HashMap\";"), vec!["let", "b"]);
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let l = lex("let x = 1; // trailing HashMap\n// standalone\nlet y = 2;");
        assert_eq!(idents("let x = 1; // trailing HashMap\nlet y = 2;"), vec!["let", "x", "let", "y"]);
        assert_eq!(l.comments.len(), 2);
        assert!(l.comments[0].trailing && l.comments[0].text.contains("trailing"));
        assert!(!l.comments[1].trailing);
        assert_eq!(l.comments[1].line, 2);
    }

    #[test]
    fn block_comments_nest() {
        assert_eq!(idents("/* a /* nested */ still */ let z = 3;"), vec!["let", "z"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes = l.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).count();
        let chars = l.tokens.iter().filter(|t| t.kind == TokKind::Str).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 1);
        // escaped char + whitespace char
        let l2 = lex(r"let a = '\n'; let b = ' ';");
        assert_eq!(l2.tokens.iter().filter(|t| t.kind == TokKind::Str).count(), 2);
    }

    #[test]
    fn numbers_int_vs_float() {
        let l = lex("let a = 1; let b = 1.5; let c = 1e9; let d = 2f64; let e = 0xFF; let r = 0..10;");
        let floats = l.tokens.iter().filter(|t| t.kind == TokKind::Float).count();
        let ints = l.tokens.iter().filter(|t| t.kind == TokKind::Int).count();
        assert_eq!(floats, 3, "1.5, 1e9, 2f64");
        assert_eq!(ints, 4, "1, 0xFF, 0, 10");
    }

    #[test]
    fn line_and_col_positions() {
        let l = lex("a\n  bb ccc");
        let t: Vec<_> = l.tokens.iter().map(|t| (t.text.clone(), t.line, t.col)).collect();
        assert_eq!(
            t,
            vec![
                ("a".to_string(), 1, 1),
                ("bb".to_string(), 2, 3),
                ("ccc".to_string(), 2, 6)
            ]
        );
    }
}
