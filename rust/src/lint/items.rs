//! Flow-aware item parsing for `moelint` v2.
//!
//! PR 8's rules were line-scoped token walkers; the R7–R10 family needs
//! *spans*: which tokens form a `fn` signature, where its body starts and
//! ends, whether it sits under `#[cfg(test)]`, and which `fn` a
//! `// moelint: hot` annotation anchors to. This module is a lightweight
//! brace-matched pass over the existing [`Lexed`] token stream — still
//! not a Rust parser (no expressions, no types), just enough item
//! structure for function-scope rules:
//!
//! * [`FnItem`] — every `fn`, with its signature-paren span, body-brace
//!   span, test-scope flag and hot annotation;
//! * [`TypeBody`] — every braced `struct`/`enum` body (named fields live
//!   here; tuple structs have no field names and are skipped);
//! * stray `hot` annotations that anchored to nothing (R9 reports them —
//!   a mis-anchored annotation is a silently unguarded window).
//!
//! Test scope is tracked two ways: a `#[cfg(test)]`/`#[test]` attribute
//! directly on the item, or an enclosing `mod` carrying `#[cfg(test)]`.
//! Between a `hot` annotation and its `fn`, only attribute/visibility
//! tokens may appear (`#[inline]`, `pub(crate)`, `const`, `unsafe`,
//! `async`, `extern`); anything else (a statement, another item's body)
//! breaks the anchor and the annotation is reported stray.

use super::lex::{Lexed, TokKind, Token};

/// One `fn` item (free, inherent, trait-default or trait-declaration).
#[derive(Debug)]
pub struct FnItem {
    /// Function name (`fn` followed by a non-identifier is skipped — that
    /// shape is a `fn(...)` pointer type, not an item).
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: u32,
    /// Token index of the `(` opening the parameter list.
    pub sig_open: usize,
    /// Token index of the matching `)`.
    pub sig_close: usize,
    /// Token index of the body `{`, or `usize::MAX` for bodyless
    /// declarations (trait method signatures).
    pub body_open: usize,
    /// Token index of the matching `}` (meaningless when bodyless).
    pub body_close: usize,
    /// Inside `#[cfg(test)]` scope or annotated `#[test]`.
    pub in_test: bool,
    /// Carries an anchored `// moelint: hot` annotation (R9 scope).
    pub is_hot: bool,
}

impl FnItem {
    /// Token-index range of the parameter list, exclusive of the parens.
    pub fn sig_range(&self) -> std::ops::Range<usize> {
        if self.sig_open == usize::MAX || self.sig_open + 1 > self.sig_close {
            return 0..0;
        }
        self.sig_open + 1..self.sig_close
    }

    /// Token-index range of the body, exclusive of the braces; empty for
    /// bodyless declarations.
    pub fn body_range(&self) -> std::ops::Range<usize> {
        if self.body_open == usize::MAX || self.body_open + 1 > self.body_close {
            return 0..0;
        }
        self.body_open + 1..self.body_close
    }
}

/// A braced `struct` or `enum` body (named fields — including named
/// fields of enum variants, which nest inside the enum's braces).
#[derive(Debug)]
pub struct TypeBody {
    /// Token index of the opening `{`.
    pub body_open: usize,
    /// Token index of the matching `}`.
    pub body_close: usize,
    pub in_test: bool,
}

/// Parsed item structure of one source file.
#[derive(Debug, Default)]
pub struct Items {
    pub fns: Vec<FnItem>,
    pub types: Vec<TypeBody>,
    /// Lines of `// moelint: hot` annotations that did not anchor to a
    /// `fn` (reported by R9 — never silently dropped).
    pub stray_hot: Vec<u32>,
}

impl Items {
    /// Whether token index `i` falls inside any (non-bodyless) fn body —
    /// used to exclude fn-local `struct`s from field rules and locals
    /// from signature rules.
    pub fn inside_fn_body(&self, i: usize) -> bool {
        self.fns
            .iter()
            .any(|f| f.body_open != usize::MAX && i > f.body_open && i < f.body_close)
    }
}

/// `// moelint: hot` (exact word after the `moelint:` prefix).
pub fn is_hot_comment(text: &str) -> bool {
    let t = text.trim_start_matches('/').trim();
    match t.strip_prefix("moelint:") {
        Some(rest) => rest.trim() == "hot",
        None => false,
    }
}

fn is_punct(t: &Token, c: char) -> bool {
    t.kind == TokKind::Punct(c)
}

fn ident_text<'a>(t: &'a Token) -> Option<&'a str> {
    if t.kind == TokKind::Ident {
        Some(&t.text)
    } else {
        None
    }
}

/// Skip a matched `<...>` generic-parameter span starting at `toks[i]`
/// (which must be `<`); returns the index just past the closing `>`.
/// `->` arrows inside bounds (`F: Fn(u64) -> u64`) are recognized so
/// their `>` does not close the span.
fn skip_generics(toks: &[Token], mut i: usize) -> usize {
    let mut depth = 0usize;
    while i < toks.len() {
        if is_punct(&toks[i], '<') {
            depth += 1;
        } else if is_punct(&toks[i], '>') {
            let arrow = i > 0 && is_punct(&toks[i - 1], '-');
            if !arrow {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
        }
        i += 1;
    }
    i
}

/// Skip a matched bracket span (`(`/`[`/`{`) starting at `toks[i]`;
/// returns the index of the closing token (or `toks.len()` if
/// unbalanced — the walkers treat that as end-of-scan).
pub(super) fn match_bracket(toks: &[Token], i: usize, open: char, close: char) -> usize {
    let mut depth = 0usize;
    let mut j = i;
    while j < toks.len() {
        if is_punct(&toks[j], open) {
            depth += 1;
        } else if is_punct(&toks[j], close) {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    toks.len()
}

/// Tokens that may sit between a `hot` annotation (or an attribute) and
/// the item it decorates: attributes and visibility/qualifier keywords.
fn is_item_prelude(t: &Token) -> bool {
    match &t.kind {
        TokKind::Ident => true, // attr names, pub/const/unsafe/async/extern
        TokKind::Str | TokKind::Int | TokKind::Lifetime => true, // attr args
        TokKind::PathSep => true,
        TokKind::Punct(c) => matches!(c, '#' | '[' | ']' | '(' | ')' | ',' | '=' | ':'),
        _ => false,
    }
}

/// Parse the item structure of a lexed file.
pub fn parse_items(lexed: &Lexed) -> Items {
    let toks = &lexed.tokens;
    let mut items = Items::default();

    // hot annotations, in line order (comments are emitted in order)
    let hot_lines: Vec<u32> = lexed
        .comments
        .iter()
        .filter(|c| is_hot_comment(&c.text))
        .map(|c| c.line)
        .collect();
    let mut next_hot = 0usize;
    // armed annotation line waiting for its fn
    let mut hot_armed: Option<u32> = None;

    let mut brace_depth = 0usize;
    // depth at which #[cfg(test)] scope began (a test mod's body)
    let mut test_depth: Option<usize> = None;
    // attributes seen since the last item/statement boundary
    let mut pending_test = false;
    // the next `{` opens a #[cfg(test)]-marked mod
    let mut arm_test_mod = false;

    let mut i = 0usize;
    while i < toks.len() {
        // absorb hot annotations that precede this token
        while next_hot < hot_lines.len() && hot_lines[next_hot] < toks[i].line {
            if let Some(prev) = hot_armed.replace(hot_lines[next_hot]) {
                items.stray_hot.push(prev); // doubled annotation
            }
            next_hot += 1;
        }
        if hot_armed.is_some() && !is_item_prelude(&toks[i]) {
            let fn_kw = ident_text(&toks[i]) == Some("fn");
            if !fn_kw {
                items.stray_hot.push(hot_armed.take().unwrap_or(0));
            }
        }

        match &toks[i].kind {
            TokKind::Punct('#') => {
                // attribute: #[...] (or #![...]); test-marking if any
                // inner identifier is `test` (#[test], #[cfg(test)])
                let mut j = i + 1;
                if j < toks.len() && is_punct(&toks[j], '!') {
                    j += 1;
                }
                if j < toks.len() && is_punct(&toks[j], '[') {
                    let end = match_bracket(toks, j, '[', ']');
                    for t in &toks[j..end.min(toks.len())] {
                        if ident_text(t) == Some("test") {
                            pending_test = true;
                        }
                    }
                    i = end + 1;
                    continue;
                }
            }
            TokKind::Punct('{') => {
                brace_depth += 1;
                if arm_test_mod && test_depth.is_none() {
                    test_depth = Some(brace_depth);
                }
                arm_test_mod = false;
                pending_test = false;
            }
            TokKind::Punct('}') => {
                if test_depth == Some(brace_depth) {
                    test_depth = None;
                }
                brace_depth = brace_depth.saturating_sub(1);
                pending_test = false;
            }
            TokKind::Punct(';') | TokKind::Punct('=') => {
                pending_test = false;
            }
            TokKind::Ident => {
                let in_test = test_depth.is_some() || pending_test;
                match toks[i].text.as_str() {
                    "mod" => {
                        // `mod name {` opens a scope; `mod name;` is a
                        // file reference. Only the brace form scopes.
                        if pending_test
                            && i + 2 < toks.len()
                            && toks[i + 1].kind == TokKind::Ident
                            && is_punct(&toks[i + 2], '{')
                        {
                            arm_test_mod = true;
                        }
                        // keep pending_test until the `{`/`;` resets it
                    }
                    "fn" => {
                        let hot = hot_armed.take();
                        if i + 1 < toks.len() && toks[i + 1].kind == TokKind::Ident {
                            let f = parse_fn(toks, i, in_test, hot.is_some());
                            items.fns.push(f);
                        } else if let Some(line) = hot {
                            // `fn(...)` pointer type — not an item
                            items.stray_hot.push(line);
                        }
                        pending_test = false;
                    }
                    "struct" | "enum" | "union" => {
                        if let Some(tb) = parse_type_body(toks, i, in_test) {
                            items.types.push(tb);
                        }
                        pending_test = false;
                    }
                    _ => {}
                }
            }
            _ => {}
        }
        i += 1;
    }
    // trailing annotations past the last token never anchor; flush them
    while next_hot < hot_lines.len() {
        if let Some(prev) = hot_armed.replace(hot_lines[next_hot]) {
            items.stray_hot.push(prev);
        }
        next_hot += 1;
    }
    if let Some(line) = hot_armed {
        items.stray_hot.push(line);
    }
    items
}

/// Parse one `fn` item starting at the `fn` keyword (`toks[at]`); the
/// caller guarantees `toks[at + 1]` is the name identifier.
fn parse_fn(toks: &[Token], at: usize, in_test: bool, is_hot: bool) -> FnItem {
    let name = toks[at + 1].text.clone();
    let line = toks[at].line;
    let mut j = at + 2;
    if j < toks.len() && is_punct(&toks[j], '<') {
        j = skip_generics(toks, j);
    }
    let (mut sig_open, mut sig_close) = (usize::MAX, usize::MAX);
    if j < toks.len() && is_punct(&toks[j], '(') {
        sig_open = j;
        sig_close = match_bracket(toks, j, '(', ')');
        j = sig_close + 1;
    }
    // return type / where clause: scan to the body `{` or a `;` at
    // paren/bracket depth 0 (tuple returns carry parens, array types
    // carry brackets; neither carries braces)
    let (mut body_open, mut body_close) = (usize::MAX, usize::MAX);
    let mut depth = 0i32;
    while j < toks.len() {
        match &toks[j].kind {
            TokKind::Punct('(') | TokKind::Punct('[') => depth += 1,
            TokKind::Punct(')') | TokKind::Punct(']') => depth -= 1,
            TokKind::Punct('{') if depth == 0 => {
                body_open = j;
                body_close = match_bracket(toks, j, '{', '}');
                break;
            }
            TokKind::Punct(';') if depth == 0 => break,
            _ => {}
        }
        j += 1;
    }
    FnItem {
        name,
        line,
        sig_open,
        sig_close,
        body_open,
        body_close,
        in_test,
        is_hot,
    }
}

/// Parse a `struct`/`enum`/`union` braced body starting at the keyword;
/// returns `None` for tuple structs and unit structs (no named fields).
fn parse_type_body(toks: &[Token], at: usize, in_test: bool) -> Option<TypeBody> {
    let mut j = at + 1;
    if j < toks.len() && toks[j].kind == TokKind::Ident {
        j += 1;
    } else {
        return None;
    }
    if j < toks.len() && is_punct(&toks[j], '<') {
        j = skip_generics(toks, j);
    }
    // where clause: scan to `{`, `;` or `(` at depth 0
    let mut depth = 0i32;
    while j < toks.len() {
        match &toks[j].kind {
            TokKind::Punct('(') if depth == 0 => return None, // tuple struct
            TokKind::Punct('(') | TokKind::Punct('[') => depth += 1,
            TokKind::Punct(')') | TokKind::Punct(']') => depth -= 1,
            TokKind::Punct(';') if depth == 0 => return None, // unit struct
            TokKind::Punct('{') if depth == 0 => {
                let close = match_bracket(toks, j, '{', '}');
                return Some(TypeBody {
                    body_open: j,
                    body_close: close,
                    in_test,
                });
            }
            _ => {}
        }
        j += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::super::lex::lex;
    use super::*;

    fn parse(src: &str) -> Items {
        parse_items(&lex(src))
    }

    #[test]
    fn finds_fns_with_spans_and_names() {
        let items = parse(
            "pub fn alpha(x: u32) -> u32 { x + 1 }\n\
             fn beta<F: Fn(u64) -> u64>(f: F) -> (f64, bool) where F: Clone { (0.0, f(1) > 0) }\n",
        );
        assert_eq!(items.fns.len(), 2);
        assert_eq!(items.fns[0].name, "alpha");
        assert_eq!(items.fns[1].name, "beta");
        for f in &items.fns {
            assert!(f.sig_open != usize::MAX && f.body_open != usize::MAX);
            assert!(f.sig_open < f.sig_close && f.body_open < f.body_close);
        }
        // beta's generics contain a paren'd Fn bound and an arrow — the
        // signature must still be the real param list
        let beta = &items.fns[1];
        assert!(!beta.sig_range().is_empty());
    }

    #[test]
    fn trait_declarations_are_bodyless() {
        let items = parse("trait S { fn tick(&mut self) -> bool; fn done(&self) -> bool { true } }");
        assert_eq!(items.fns.len(), 2);
        assert_eq!(items.fns[0].body_open, usize::MAX);
        assert!(items.fns[1].body_open != usize::MAX);
    }

    #[test]
    fn cfg_test_mod_and_test_attr_mark_fns() {
        let items = parse(
            "fn live() {}\n\
             #[cfg(test)]\nmod tests {\n  fn helper() {}\n  #[test]\n  fn case() {}\n}\n\
             fn live2() {}\n\
             #[test]\nfn top_level_case() {}\n",
        );
        let by_name = |n: &str| items.fns.iter().find(|f| f.name == n).unwrap();
        assert!(!by_name("live").in_test);
        assert!(by_name("helper").in_test);
        assert!(by_name("case").in_test);
        assert!(!by_name("live2").in_test);
        assert!(by_name("top_level_case").in_test);
    }

    #[test]
    fn hot_annotation_anchors_through_attrs_and_qualifiers() {
        let items = parse(
            "// moelint: hot\n#[inline]\npub(crate) fn window(&mut self) {}\n\
             fn cold() {}\n",
        );
        assert!(items.fns[0].is_hot);
        assert!(!items.fns[1].is_hot);
        assert!(items.stray_hot.is_empty());
    }

    #[test]
    fn hot_annotation_broken_by_interleaving_code_is_stray() {
        let items = parse("// moelint: hot\nconst X: u32 = 5;\nfn later() {}\n");
        assert!(!items.fns[0].is_hot);
        assert_eq!(items.stray_hot, vec![1]);
        let items = parse("fn only() {}\n// moelint: hot\n");
        assert!(!items.fns[0].is_hot);
        assert_eq!(items.stray_hot, vec![2]);
    }

    #[test]
    fn struct_bodies_found_tuple_structs_skipped() {
        let items = parse(
            "pub struct Named { pub t: f64 }\n\
             pub struct Tup(f64);\n\
             pub enum E { A { delay: f64 }, B }\n\
             struct Unit;\n",
        );
        assert_eq!(items.types.len(), 2);
    }

    #[test]
    fn fn_pointer_types_are_not_items() {
        let items = parse("struct S { cb: fn(u32) -> u32 }\nfn real() {}\n");
        assert_eq!(items.fns.len(), 1);
        assert_eq!(items.fns[0].name, "real");
    }

    #[test]
    fn nested_fns_and_bodies_tracked() {
        let items = parse("fn outer() { fn inner() { let v = 1; } inner(); }");
        assert_eq!(items.fns.len(), 2);
        let outer = &items.fns[0];
        let inner = &items.fns[1];
        assert!(outer.body_open < inner.body_open && inner.body_close < outer.body_close);
        assert!(items.inside_fn_body(inner.body_open + 1));
    }
}
