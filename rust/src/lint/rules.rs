//! The `moelint` rule walkers (R1–R6).
//!
//! Each rule is a pure function over the token stream of one file plus its
//! path-derived [`FileClass`]; findings are reported pre-suppression (the
//! pragma filter in [`crate::lint`] applies `// moelint: allow(...)`
//! afterwards). The catalogue, scopes and rationale are documented in
//! EXPERIMENTS.md §Lint; rule text lives here so the binary, the fixtures
//! and the docs can't drift apart silently.

use super::lex::{Lexed, TokKind, Token};
use super::Finding;

/// Modules whose decision paths feed the replay/differential guarantees —
/// rule R1 forbids default-hasher containers here.
pub const SIM_MODULES: [&str; 7] = [
    "cache", "prefetch", "memory", "server", "engine", "trace", "faults",
];

/// Integer target types of a truncating `as` cast (rule R4).
const INT_TYPES: [&str; 12] = [
    "usize", "isize", "u8", "u16", "u32", "u64", "u128", "i8", "i16", "i32", "i64", "i128",
];

/// Identifier fragments that mark a line as carrying simulated-time or
/// byte-count quantities (rule R4's scope heuristic; substring match,
/// case-insensitive).
const QUANTITY_HINTS: [&str; 13] = [
    "time", "secs", "byte", "bandwidth", "budget", "latenc", "duration", "deadline", "elapsed",
    "clock", "rps", "_mb", "_gb",
];

/// One lint rule's identity: stable id, pragma name, one-line summary.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    pub id: &'static str,
    pub name: &'static str,
    pub summary: &'static str,
}

/// The rule catalogue. `pragma` is the meta-rule for malformed/reasonless
/// suppressions; it cannot itself be suppressed.
pub const RULES: [Rule; 7] = [
    Rule {
        id: "R1",
        name: "det-map",
        summary: "no default-hasher HashMap/HashSet in sim/serving modules (use DetMap/DetSet)",
    },
    Rule {
        id: "R2",
        name: "wall-clock",
        summary: "no Instant::now/SystemTime::now outside benches (sim time is the only clock)",
    },
    Rule {
        id: "R3",
        name: "thread",
        summary: "no thread spawning or rayon outside util/pool.rs (the deterministic pool)",
    },
    Rule {
        id: "R4",
        name: "float-cast",
        summary: "no truncating float->int `as` cast on sim-time/byte-count expressions",
    },
    Rule {
        id: "R5",
        name: "unsafe",
        summary: "no unsafe outside util/alloc.rs and util/pool.rs",
    },
    Rule {
        id: "R6",
        name: "print",
        summary: "no println!/eprintln!/print!/eprint!/dbg! in library modules",
    },
    Rule {
        id: "P0",
        name: "pragma",
        summary: "every moelint pragma must name a known rule and carry a reason",
    },
];

/// Resolve a pragma's rule argument (accepts the name or the id, any case)
/// to the canonical rule name. `pragma` itself is not a valid target.
pub fn resolve_rule(arg: &str) -> Option<&'static str> {
    let a = arg.trim().to_ascii_lowercase();
    RULES
        .iter()
        .find(|r| r.name != "pragma" && (a == r.name || a == r.id.to_ascii_lowercase()))
        .map(|r| r.name)
}

/// Path-derived scope of one file (paths are repo-relative with forward
/// slashes, e.g. `rust/src/cache/policies.rs`).
#[derive(Debug, Clone)]
pub struct FileClass {
    pub rel: String,
    /// `rust/src/<module>/...` → `Some(module)`; top-level files → `None`.
    pub module: Option<String>,
    pub is_bench: bool,
    pub is_test: bool,
    /// `rust/src/main.rs` or anything under `rust/src/bin/`.
    pub is_bin: bool,
}

impl FileClass {
    pub fn classify(rel: &str) -> FileClass {
        let rel = rel.replace('\\', "/");
        let module = rel
            .strip_prefix("rust/src/")
            .and_then(|rest| rest.split_once('/'))
            .map(|(m, _)| m.to_string());
        FileClass {
            is_bench: rel.starts_with("rust/benches/"),
            is_test: rel.starts_with("rust/tests/"),
            is_bin: rel == "rust/src/main.rs" || rel.starts_with("rust/src/bin/"),
            module,
            rel,
        }
    }

    fn in_sim_module(&self) -> bool {
        self.module
            .as_deref()
            .is_some_and(|m| SIM_MODULES.contains(&m))
    }

    fn ends_with(&self, suffix: &str) -> bool {
        self.rel.ends_with(suffix)
    }
}

fn ident_is(t: &Token, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn finding(class: &FileClass, t: &Token, rule: &'static str, msg: String) -> Finding {
    Finding {
        path: class.rel.clone(),
        line: t.line,
        col: t.col,
        rule,
        msg,
    }
}

/// R1 `det-map`: any `HashMap`/`HashSet` identifier inside a sim/serving
/// module — imports, fields, turbofish and constructions alike. After the
/// DetMap migration those modules have no legitimate mention left, so the
/// strictest possible match keeps the ratchet simple.
fn r1_det_map(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if !class.in_sim_module() {
        return;
    }
    for t in &lexed.tokens {
        if ident_is(t, "HashMap") || ident_is(t, "HashSet") {
            out.push(finding(
                class,
                t,
                "det-map",
                format!(
                    "default-hasher `{}` in sim/serving module `{}`: decision paths must use \
                     `util::detmap::{{DetMap, DetSet}}` so iteration order is replayable",
                    t.text,
                    class.module.as_deref().unwrap_or("?"),
                ),
            ));
        }
    }
}

/// R2 `wall-clock`: `Instant::now` / `SystemTime::now` anywhere outside
/// `rust/benches/`. Host time on a decision path breaks bitwise replay;
/// legitimate host-timing helpers carry a pragma with a reason.
fn r2_wall_clock(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if class.is_bench {
        return;
    }
    let ts = &lexed.tokens;
    for w in ts.windows(3) {
        if (ident_is(&w[0], "Instant") || ident_is(&w[0], "SystemTime"))
            && w[1].kind == TokKind::PathSep
            && ident_is(&w[2], "now")
        {
            out.push(finding(
                class,
                &w[0],
                "wall-clock",
                format!(
                    "`{}::now` outside benches: simulated time is the only clock on \
                     replayable paths",
                    w[0].text
                ),
            ));
        }
    }
}

/// R3 `thread`: `thread::spawn`/`thread::scope`/`thread::Builder` or any
/// `rayon` mention outside `util/pool.rs`. All parallelism goes through the
/// deterministic pool, whose ordered reduction is what keeps pooled ≡
/// serial bitwise.
fn r3_thread(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if class.ends_with("util/pool.rs") {
        return;
    }
    let ts = &lexed.tokens;
    for (i, t) in ts.iter().enumerate() {
        if ident_is(t, "rayon") {
            out.push(finding(
                class,
                t,
                "thread",
                "`rayon` outside util/pool.rs: use util::Pool (deterministic ordered reduction)"
                    .to_string(),
            ));
        }
        if ident_is(t, "thread")
            && ts.get(i + 1).is_some_and(|n| n.kind == TokKind::PathSep)
            && ts.get(i + 2).is_some_and(|n| {
                ident_is(n, "spawn") || ident_is(n, "scope") || ident_is(n, "Builder")
            })
        {
            out.push(finding(
                class,
                t,
                "thread",
                format!(
                    "`thread::{}` outside util/pool.rs: use util::Pool (deterministic \
                     ordered reduction)",
                    ts[i + 2].text
                ),
            ));
        }
    }
}

/// R4 `float-cast`: a truncating `as <int>` cast on a line that both (a)
/// shows float evidence *before* the cast (a float literal or an `f64`/`f32`
/// token) and (b) mentions a sim-time/byte-count quantity (identifier
/// containing one of [`QUANTITY_HINTS`]). Line-scoped by design — the
/// heuristic documents itself via the pragma it forces on intentional
/// truncations.
fn r4_float_cast(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    let ts = &lexed.tokens;
    let mut i = 0;
    while i < ts.len() {
        let line = ts[i].line;
        let end = ts[i..].iter().position(|t| t.line != line).map_or(ts.len(), |p| i + p);
        let toks = &ts[i..end];
        let quantity = toks.iter().any(|t| {
            t.kind == TokKind::Ident && {
                let low = t.text.to_ascii_lowercase();
                QUANTITY_HINTS.iter().any(|h| low.contains(h))
            }
        });
        if quantity {
            for j in 0..toks.len().saturating_sub(1) {
                if ident_is(&toks[j], "as")
                    && toks[j + 1].kind == TokKind::Ident
                    && INT_TYPES.contains(&toks[j + 1].text.as_str())
                {
                    let float_before = toks[..j].iter().any(|t| {
                        t.kind == TokKind::Float || ident_is(t, "f64") || ident_is(t, "f32")
                    });
                    if float_before {
                        out.push(finding(
                            class,
                            &toks[j],
                            "float-cast",
                            format!(
                                "float->`{}` truncation on a sim-time/byte-count line: make \
                                 the rounding explicit or pragma the intentional floor",
                                toks[j + 1].text
                            ),
                        ));
                    }
                }
            }
        }
        i = end;
    }
}

/// R5 `unsafe`: the keyword anywhere outside the two audited homes
/// (`util/alloc.rs` counting allocator, `util/pool.rs` scoped workers) —
/// the same two files the CI Miri job executes.
fn r5_unsafe(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if class.ends_with("util/alloc.rs") || class.ends_with("util/pool.rs") {
        return;
    }
    for t in &lexed.tokens {
        if ident_is(t, "unsafe") {
            out.push(finding(
                class,
                t,
                "unsafe",
                "`unsafe` outside util/alloc.rs and util/pool.rs (the Miri-covered files)"
                    .to_string(),
            ));
        }
    }
}

/// R6 `print`: `println!`-family macros in library modules. Libraries
/// return data; narration belongs to `main.rs`, `bin/`, benches and tests.
fn r6_print(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if class.is_bench || class.is_test || class.is_bin {
        return;
    }
    const MACROS: [&str; 5] = ["println", "eprintln", "print", "eprint", "dbg"];
    let ts = &lexed.tokens;
    for w in ts.windows(2) {
        if w[0].kind == TokKind::Ident
            && MACROS.contains(&w[0].text.as_str())
            && w[1].kind == TokKind::Punct('!')
        {
            out.push(finding(
                class,
                &w[0],
                "print",
                format!(
                    "`{}!` in a library module: return data; narration belongs to main/benches",
                    w[0].text
                ),
            ));
        }
    }
}

/// Run every rule over one lexed file.
pub fn check_all(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    r1_det_map(class, lexed, out);
    r2_wall_clock(class, lexed, out);
    r3_thread(class, lexed, out);
    r4_float_cast(class, lexed, out);
    r5_unsafe(class, lexed, out);
    r6_print(class, lexed, out);
}
