//! The `moelint` rule walkers (R1–R10, minus the retired R4).
//!
//! Each rule is a pure function over the token stream of one file plus its
//! path-derived [`FileClass`]; findings are reported pre-suppression (the
//! pragma filter in [`crate::lint`] applies `// moelint: allow(...)`
//! afterwards). R7–R10 additionally receive the flow-aware
//! [`Items`] structure (fn/struct spans, test scope, `hot` anchors) built
//! by [`super::items`]. The catalogue, scopes and rationale are documented
//! in EXPERIMENTS.md §Lint; rule text lives here so the binary, the
//! fixtures and the docs can't drift apart silently.
//!
//! **R4 `float-cast` is retired**: it was a line-scoped heuristic for the
//! silent-truncation problem R7 now solves structurally — quantities carry
//! their unit in the type (`util::units`), so a truncation requires a
//! visible escape hatch (`to_f64`/`floor_bytes`) instead of a guessed-at
//! pragma.

use super::items::{self, Items};
use super::lex::{Lexed, TokKind, Token};
use super::Finding;

/// Modules whose decision paths feed the replay/differential guarantees —
/// rule R1 forbids default-hasher containers here.
pub const SIM_MODULES: [&str; 7] = [
    "cache", "prefetch", "memory", "server", "engine", "trace", "faults",
];

/// The sim/serving modules under the typed-units regime: R7 bans
/// hint-named raw-`f64` params/fields here, and R8 requires their serving
/// paths to be panic-free.
pub const UNITS_MODULES: [&str; 5] = ["memory", "faults", "server", "cache", "prefetch"];

/// Identifier fragments that mark a param/field as carrying a simulated
/// time or byte quantity (rule R7; substring match, case-insensitive).
/// `slo` is special-cased so `slot`-family names don't trip it.
pub const UNIT_HINTS: [&str; 15] = [
    "time", "secs", "bytes", "latency", "deadline", "duration", "delay", "wait", "elapsed",
    "makespan", "ttft", "stall", "bandwidth", "backoff", "slo",
];

/// Replica methods that mutate a replica's `next_event_bound` — rule R10
/// requires `refresh` in any `server/router.rs` function calling them.
const BOUND_MUTATORS: [&str; 4] = ["submit", "tick", "fail_over", "submit_failover"];

/// Allocation surfaces banned inside `// moelint: hot` windows (rule R9).
const HOT_ALLOC_MACROS: [&str; 2] = ["vec", "format"];
const HOT_ALLOC_METHODS: [&str; 2] = ["collect", "to_string"];
const HOT_ALLOC_PATHS: [&str; 2] = ["Vec", "Box"];

/// One lint rule's identity: stable id, pragma name, one-line summary.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    pub id: &'static str,
    pub name: &'static str,
    pub summary: &'static str,
}

/// The rule catalogue. `pragma` is the meta-rule for malformed/reasonless
/// suppressions; it cannot itself be suppressed.
pub const RULES: [Rule; 10] = [
    Rule {
        id: "R1",
        name: "det-map",
        summary: "no default-hasher HashMap/HashSet in sim/serving modules (use DetMap/DetSet)",
    },
    Rule {
        id: "R2",
        name: "wall-clock",
        summary: "no Instant::now/SystemTime::now outside benches (sim time is the only clock)",
    },
    Rule {
        id: "R3",
        name: "thread",
        summary: "no thread spawning or rayon outside util/pool.rs (the deterministic pool)",
    },
    Rule {
        id: "R5",
        name: "unsafe",
        summary: "no unsafe outside util/alloc.rs and util/pool.rs",
    },
    Rule {
        id: "R6",
        name: "print",
        summary: "no println!/eprintln!/print!/eprint!/dbg! in library modules",
    },
    Rule {
        id: "R7",
        name: "raw-units",
        summary: "no hint-named raw-f64 params/fields in sim/serving modules (use util::units)",
    },
    Rule {
        id: "R8",
        name: "panic-free",
        summary: "no unwrap/expect/panic!/unreachable! in serving-path functions",
    },
    Rule {
        id: "R9",
        name: "hot-alloc",
        summary: "no Vec::new/vec!/format!/collect/Box::new/to_string in `moelint: hot` functions",
    },
    Rule {
        id: "R10",
        name: "refresh-contract",
        summary: "bound-mutating replica calls in server/router.rs must pair with refresh",
    },
    Rule {
        id: "P0",
        name: "pragma",
        summary: "every moelint pragma must name a known rule and carry a reason",
    },
];

/// Resolve a pragma's rule argument (accepts the name or the id, any case)
/// to the canonical rule name. `pragma` itself is not a valid target.
pub fn resolve_rule(arg: &str) -> Option<&'static str> {
    let a = arg.trim().to_ascii_lowercase();
    RULES
        .iter()
        .find(|r| r.name != "pragma" && (a == r.name || a == r.id.to_ascii_lowercase()))
        .map(|r| r.name)
}

/// Path-derived scope of one file (paths are repo-relative with forward
/// slashes, e.g. `rust/src/cache/policies.rs`).
#[derive(Debug, Clone)]
pub struct FileClass {
    pub rel: String,
    /// `rust/src/<module>/...` → `Some(module)`; top-level files → `None`.
    pub module: Option<String>,
    pub is_bench: bool,
    pub is_test: bool,
    /// `rust/src/main.rs` or anything under `rust/src/bin/`.
    pub is_bin: bool,
}

impl FileClass {
    pub fn classify(rel: &str) -> FileClass {
        let rel = rel.replace('\\', "/");
        let module = rel
            .strip_prefix("rust/src/")
            .and_then(|rest| rest.split_once('/'))
            .map(|(m, _)| m.to_string());
        FileClass {
            is_bench: rel.starts_with("rust/benches/"),
            is_test: rel.starts_with("rust/tests/"),
            is_bin: rel == "rust/src/main.rs" || rel.starts_with("rust/src/bin/"),
            module,
            rel,
        }
    }

    fn in_sim_module(&self) -> bool {
        self.module
            .as_deref()
            .is_some_and(|m| SIM_MODULES.contains(&m))
    }

    fn in_units_module(&self) -> bool {
        self.module
            .as_deref()
            .is_some_and(|m| UNITS_MODULES.contains(&m))
    }

    fn ends_with(&self, suffix: &str) -> bool {
        self.rel.ends_with(suffix)
    }
}

fn ident_is(t: &Token, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn finding(class: &FileClass, t: &Token, rule: &'static str, msg: String) -> Finding {
    Finding {
        path: class.rel.clone(),
        line: t.line,
        col: t.col,
        rule,
        msg,
    }
}

/// R1 `det-map`: any `HashMap`/`HashSet` identifier inside a sim/serving
/// module — imports, fields, turbofish and constructions alike. After the
/// DetMap migration those modules have no legitimate mention left, so the
/// strictest possible match keeps the ratchet simple.
fn r1_det_map(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if !class.in_sim_module() {
        return;
    }
    for t in &lexed.tokens {
        if ident_is(t, "HashMap") || ident_is(t, "HashSet") {
            out.push(finding(
                class,
                t,
                "det-map",
                format!(
                    "default-hasher `{}` in sim/serving module `{}`: decision paths must use \
                     `util::detmap::{{DetMap, DetSet}}` so iteration order is replayable",
                    t.text,
                    class.module.as_deref().unwrap_or("?"),
                ),
            ));
        }
    }
}

/// R2 `wall-clock`: `Instant::now` / `SystemTime::now` anywhere outside
/// `rust/benches/`. Host time on a decision path breaks bitwise replay;
/// legitimate host-timing helpers carry a pragma with a reason.
fn r2_wall_clock(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if class.is_bench {
        return;
    }
    let ts = &lexed.tokens;
    for w in ts.windows(3) {
        if (ident_is(&w[0], "Instant") || ident_is(&w[0], "SystemTime"))
            && w[1].kind == TokKind::PathSep
            && ident_is(&w[2], "now")
        {
            out.push(finding(
                class,
                &w[0],
                "wall-clock",
                format!(
                    "`{}::now` outside benches: simulated time is the only clock on \
                     replayable paths",
                    w[0].text
                ),
            ));
        }
    }
}

/// R3 `thread`: `thread::spawn`/`thread::scope`/`thread::Builder` or any
/// `rayon` mention outside `util/pool.rs`. All parallelism goes through the
/// deterministic pool, whose ordered reduction is what keeps pooled ≡
/// serial bitwise.
fn r3_thread(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if class.ends_with("util/pool.rs") {
        return;
    }
    let ts = &lexed.tokens;
    for (i, t) in ts.iter().enumerate() {
        if ident_is(t, "rayon") {
            out.push(finding(
                class,
                t,
                "thread",
                "`rayon` outside util/pool.rs: use util::Pool (deterministic ordered reduction)"
                    .to_string(),
            ));
        }
        if ident_is(t, "thread")
            && ts.get(i + 1).is_some_and(|n| n.kind == TokKind::PathSep)
            && ts.get(i + 2).is_some_and(|n| {
                ident_is(n, "spawn") || ident_is(n, "scope") || ident_is(n, "Builder")
            })
        {
            out.push(finding(
                class,
                t,
                "thread",
                format!(
                    "`thread::{}` outside util/pool.rs: use util::Pool (deterministic \
                     ordered reduction)",
                    ts[i + 2].text
                ),
            ));
        }
    }
}

/// The [`UNIT_HINTS`] fragment a name carries, if any. `slo` is skipped
/// for `slot`-family names (`slots`, `slot_rank`, ...).
fn unit_hint(name: &str) -> Option<&'static str> {
    let low = name.to_ascii_lowercase();
    UNIT_HINTS
        .iter()
        .find(|&&h| low.contains(h) && !(h == "slo" && low.contains("slot")))
        .copied()
}

/// R7 `raw-units`: a `name: f64` param or field whose name carries a
/// time/byte hint, inside a [`UNITS_MODULES`] module and outside test
/// scope. The token shape is exactly `Ident ':' Ident(f64)` — `Vec<f64>`
/// buffers, `Option<f64>` knobs and fn-local `let` bindings don't match
/// (locals live in body spans, which are not scanned). The fix is a
/// `util::units` newtype on the field, or a neutral-named raw param
/// converted at the boundary (`window_s: f64` → `SimTime::from_f64`).
fn r7_raw_units(class: &FileClass, lexed: &Lexed, items: &Items, out: &mut Vec<Finding>) {
    if !class.in_units_module() {
        return;
    }
    let ts = &lexed.tokens;
    let mut scan = |range: std::ops::Range<usize>, what: &str, out: &mut Vec<Finding>| {
        for j in range.start..range.end.saturating_sub(2) {
            if ts[j].kind == TokKind::Ident
                && ts[j + 1].kind == TokKind::Punct(':')
                && ident_is(&ts[j + 2], "f64")
            {
                if let Some(hint) = unit_hint(&ts[j].text) {
                    out.push(finding(
                        class,
                        &ts[j],
                        "raw-units",
                        format!(
                            "raw `f64` {what} `{}` carries a unit hint (`{hint}`): use \
                             util::units::{{SimTime, Bytes, Bandwidth}} or a neutral-named \
                             boundary param converted via from_f64",
                            ts[j].text
                        ),
                    ));
                }
            }
        }
    };
    for f in &items.fns {
        if !f.in_test {
            scan(f.sig_range(), "param", out);
        }
    }
    for tb in &items.types {
        if !tb.in_test && !items.inside_fn_body(tb.body_open) {
            scan(tb.body_open + 1..tb.body_close, "field", out);
        }
    }
}

/// R8 `panic-free`: no `.unwrap()`/`.expect(...)`/`panic!`/`unreachable!`
/// inside non-test functions of the serving-path modules
/// ([`UNITS_MODULES`]). Degraded-mode serving (PR 6) only holds if the
/// serving path propagates instead of aborting; `assert!` stays legal —
/// invariant checks that *should* stop a corrupted replay are not the
/// same as convenience unwraps. Structural can't-fail sites carry a
/// reasoned pragma.
fn r8_panic_free(class: &FileClass, lexed: &Lexed, items: &Items, out: &mut Vec<Finding>) {
    if !class.in_units_module() {
        return;
    }
    let ts = &lexed.tokens;
    for f in &items.fns {
        if f.in_test {
            continue;
        }
        for j in f.body_range() {
            if ts[j].kind != TokKind::Ident {
                continue;
            }
            let name = ts[j].text.as_str();
            let method_pos = j > 0
                && (ts[j - 1].kind == TokKind::Punct('.') || ts[j - 1].kind == TokKind::PathSep)
                && ts.get(j + 1).is_some_and(|n| n.kind == TokKind::Punct('('));
            let macro_pos = ts.get(j + 1).is_some_and(|n| n.kind == TokKind::Punct('!'));
            let hit = match name {
                "unwrap" | "expect" => method_pos,
                "panic" | "unreachable" => macro_pos,
                _ => false,
            };
            if hit {
                out.push(finding(
                    class,
                    &ts[j],
                    "panic-free",
                    format!(
                        "`{name}` in serving-path fn `{}`: propagate a Result / early-return \
                         (let-else) instead, or pragma a structural can't-fail with its reason",
                        f.name
                    ),
                ));
            }
        }
    }
}

/// R9 `hot-alloc`: functions annotated `// moelint: hot` (the windows
/// `tests/alloc_guard.rs` pins dynamically) must not reach an allocation
/// surface: `Vec::new`/`Box::new`, `vec!`/`format!`, `.collect()`,
/// `.to_string()`. A stray annotation (anchored to nothing) is itself a
/// finding — a silently unguarded window is worse than a missing one.
fn r9_hot_alloc(class: &FileClass, lexed: &Lexed, items: &Items, out: &mut Vec<Finding>) {
    let ts = &lexed.tokens;
    for &line in &items.stray_hot {
        out.push(Finding {
            path: class.rel.clone(),
            line,
            col: 1,
            rule: "hot-alloc",
            msg: "`moelint: hot` annotation does not anchor to a fn (only attributes and \
                  visibility qualifiers may sit between the annotation and its `fn`)"
                .to_string(),
        });
    }
    for f in &items.fns {
        if !f.is_hot {
            continue;
        }
        for j in f.body_range() {
            if ts[j].kind != TokKind::Ident {
                continue;
            }
            let name = ts[j].text.as_str();
            let next_bang = ts.get(j + 1).is_some_and(|n| n.kind == TokKind::Punct('!'));
            let after_dot = j > 0 && ts[j - 1].kind == TokKind::Punct('.');
            let path_new = HOT_ALLOC_PATHS.contains(&name)
                && ts.get(j + 1).is_some_and(|n| n.kind == TokKind::PathSep)
                && ts.get(j + 2).is_some_and(|n| ident_is(n, "new"));
            let hit = (HOT_ALLOC_MACROS.contains(&name) && next_bang)
                || (HOT_ALLOC_METHODS.contains(&name) && after_dot)
                || path_new;
            if hit {
                let label = if path_new {
                    format!("{}::new", name)
                } else if next_bang {
                    format!("{name}!")
                } else {
                    format!(".{name}()")
                };
                out.push(finding(
                    class,
                    &ts[j],
                    "hot-alloc",
                    format!(
                        "`{label}` inside hot window `{}`: this fn is an alloc_guard-pinned \
                         allocation-free window — reuse engine-owned scratch instead",
                        f.name
                    ),
                ));
            }
        }
    }
}

/// R10 `refresh-contract`: in `server/router.rs`, any function calling a
/// bound-mutating replica method (`replicas[..].submit/tick/fail_over/`
/// `submit_failover`) must also call `refresh` — PR 7's calendar memoizes
/// `next_event_bound` per replica, and a mutation without a re-push
/// leaves a stale entry that can stall the event loop. The lockstep
/// reference (`tick_lockstep`) invalidates wholesale via its stale flag
/// and carries reasoned pragmas.
fn r10_refresh_contract(class: &FileClass, lexed: &Lexed, items: &Items, out: &mut Vec<Finding>) {
    if !class.ends_with("server/router.rs") {
        return;
    }
    let ts = &lexed.tokens;
    for f in &items.fns {
        if f.in_test {
            continue;
        }
        let body = f.body_range();
        let has_refresh = body.clone().any(|j| ident_is(&ts[j], "refresh"));
        if has_refresh {
            continue;
        }
        for j in body.clone() {
            if !ident_is(&ts[j], "replicas") {
                continue;
            }
            let mut k = j + 1;
            if ts.get(k).is_some_and(|t| t.kind == TokKind::Punct('[')) {
                k = items::match_bracket(ts, k, '[', ']') + 1;
            }
            if ts.get(k).is_some_and(|t| t.kind == TokKind::Punct('.'))
                && ts.get(k + 1).is_some_and(|t| {
                    t.kind == TokKind::Ident && BOUND_MUTATORS.contains(&t.text.as_str())
                })
                && ts.get(k + 2).is_some_and(|t| t.kind == TokKind::Punct('('))
            {
                out.push(finding(
                    class,
                    &ts[k + 1],
                    "refresh-contract",
                    format!(
                        "`replicas[..].{}` in `{}` without a `refresh` call: the calendar's \
                         memoized bound goes stale (see PR 7's bound-stability contract)",
                        ts[k + 1].text, f.name
                    ),
                ));
            }
        }
    }
}

/// R5 `unsafe`: the keyword anywhere outside the two audited homes
/// (`util/alloc.rs` counting allocator, `util/pool.rs` scoped workers) —
/// the same two files the CI Miri job executes.
fn r5_unsafe(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if class.ends_with("util/alloc.rs") || class.ends_with("util/pool.rs") {
        return;
    }
    for t in &lexed.tokens {
        if ident_is(t, "unsafe") {
            out.push(finding(
                class,
                t,
                "unsafe",
                "`unsafe` outside util/alloc.rs and util/pool.rs (the Miri-covered files)"
                    .to_string(),
            ));
        }
    }
}

/// R6 `print`: `println!`-family macros in library modules. Libraries
/// return data; narration belongs to `main.rs`, `bin/`, benches and tests.
fn r6_print(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    if class.is_bench || class.is_test || class.is_bin {
        return;
    }
    const MACROS: [&str; 5] = ["println", "eprintln", "print", "eprint", "dbg"];
    let ts = &lexed.tokens;
    for w in ts.windows(2) {
        if w[0].kind == TokKind::Ident
            && MACROS.contains(&w[0].text.as_str())
            && w[1].kind == TokKind::Punct('!')
        {
            out.push(finding(
                class,
                &w[0],
                "print",
                format!(
                    "`{}!` in a library module: return data; narration belongs to main/benches",
                    w[0].text
                ),
            ));
        }
    }
}

/// Run every rule over one lexed file. The flow-aware items pass runs
/// once and feeds R7–R10.
pub fn check_all(class: &FileClass, lexed: &Lexed, out: &mut Vec<Finding>) {
    r1_det_map(class, lexed, out);
    r2_wall_clock(class, lexed, out);
    r3_thread(class, lexed, out);
    r5_unsafe(class, lexed, out);
    r6_print(class, lexed, out);
    let items = items::parse_items(lexed);
    r7_raw_units(class, lexed, &items, out);
    r8_panic_free(class, lexed, &items, out);
    r9_hot_alloc(class, lexed, &items, out);
    r10_refresh_contract(class, lexed, &items, out);
}
