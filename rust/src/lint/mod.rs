//! `moelint` — a dependency-free, source-level determinism & hot-path lint.
//!
//! Every guarantee this repo pins dynamically (lockstep ≡ calendar replay,
//! pooled ≡ serial at any thread count, zero-allocation warmed windows) is
//! only as strong as the differential tests that happen to cover the code.
//! `moelint` makes the underlying properties *checked properties of the
//! source*: no entropy-seeded hash containers on decision paths (R1), no
//! wall-clock reads outside benches (R2), no parallelism outside the
//! deterministic pool (R3), no `unsafe` outside the two Miri-audited files
//! (R5), no stray printing from library modules (R6), no hint-named raw
//! `f64` time/byte params or fields in the sim/serving modules (R7 — the
//! `util::units` newtypes carry the unit in the type; this subsumed and
//! retired the line-scoped R4 float-cast heuristic), no
//! `unwrap`/`expect`/`panic!` on serving paths (R8), no allocation inside
//! `// moelint: hot` windows (R9 — the static complement of
//! `tests/alloc_guard.rs`), and no bound-mutating replica call without a
//! calendar `refresh` in `server/router.rs` (R10).
//!
//! * Rule engine: [`rules`] (catalogue in [`rules::RULES`]).
//! * Item structure for the flow-aware rules R7–R10: [`items`].
//! * Tokenizer: [`lex`] (comments, strings, lifetimes, numerics, `::`).
//! * Suppression: `// moelint: allow(<rule>, <reason>)` on the offending
//!   line, or on its own line directly above. The reason is **mandatory**;
//!   a reasonless or unknown-rule pragma is itself a finding (`pragma`),
//!   and `pragma` findings cannot be suppressed. Total suppression debt is
//!   capped by `scripts/lint_budget.json` ([`check_budget`]).
//! * Binary: `cargo run --bin moelint [--json] [--stats] [ROOT]` — exit 0
//!   clean, 1 findings/budget violation, 2 usage/IO error.
//!
//! The self-check test at the bottom runs the linter over the whole crate,
//! so `cargo test` fails the moment a rule regresses — the same wall CI
//! enforces via the `lint` job.

pub mod items;
pub mod lex;
pub mod rules;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use lex::lex;
use rules::{check_all, resolve_rule, FileClass, RULES};

/// Directories (relative to the repo root) the linter walks.
pub const LINT_ROOTS: [&str; 3] = ["rust/src", "rust/benches", "rust/tests"];

/// Repo-relative path of the pragma budget (`--stats` + CI enforcement).
pub const BUDGET_PATH: &str = "scripts/lint_budget.json";

/// Per-rule finding and suppression tallies for one lint run
/// (`moelint --stats`, and the budget ratchet's input).
#[derive(Debug, Clone)]
pub struct LintStats {
    /// Parallel to [`rules::RULES`]: `(rule name, emitted findings,
    /// valid pragmas seen)`. Findings are counted *post*-suppression;
    /// pragmas are counted whether or not they suppressed anything, so
    /// dead suppressions still weigh against the budget.
    pub per_rule: Vec<(&'static str, u32, u32)>,
}

impl Default for LintStats {
    fn default() -> Self {
        LintStats {
            per_rule: RULES.iter().map(|r| (r.name, 0, 0)).collect(),
        }
    }
}

impl LintStats {
    fn bump_finding(&mut self, rule: &str) {
        if let Some(row) = self.per_rule.iter_mut().find(|(n, _, _)| *n == rule) {
            row.1 += 1;
        }
    }

    fn bump_pragma(&mut self, rule: &str) {
        if let Some(row) = self.per_rule.iter_mut().find(|(n, _, _)| *n == rule) {
            row.2 += 1;
        }
    }

    pub fn findings_for(&self, rule: &str) -> u32 {
        self.per_rule.iter().find(|(n, _, _)| *n == rule).map_or(0, |r| r.1)
    }

    pub fn pragmas_for(&self, rule: &str) -> u32 {
        self.per_rule.iter().find(|(n, _, _)| *n == rule).map_or(0, |r| r.2)
    }

    pub fn total_findings(&self) -> u32 {
        self.per_rule.iter().map(|r| r.1).sum()
    }

    pub fn total_pragmas(&self) -> u32 {
        self.per_rule.iter().map(|r| r.2).sum()
    }

    /// One JSON object (the `--json --stats` artifact row).
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self
            .per_rule
            .iter()
            .map(|(name, f, p)| format!(r#""{name}":{{"findings":{f},"pragmas":{p}}}"#))
            .collect();
        format!(
            r#"{{"stats":{{{}}},"total_findings":{},"total_pragmas":{}}}"#,
            rows.join(","),
            self.total_findings(),
            self.total_pragmas()
        )
    }
}

/// Parse `scripts/lint_budget.json` — a flat `{"rule": max_pragmas}`
/// object (hand-rolled: the budget file is the only JSON moelint reads,
/// and the binary must stay dependency-free).
pub fn parse_budget(src: &str) -> Option<Vec<(String, u32)>> {
    let inner = src.trim().strip_prefix('{')?.strip_suffix('}')?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (k, v) = part.split_once(':')?;
        let key = k.trim().strip_prefix('"')?.strip_suffix('"')?.to_string();
        let val: u32 = v.trim().parse().ok()?;
        out.push((key, val));
    }
    Some(out)
}

/// Budget violations: any rule whose pragma count exceeds its budgeted
/// cap (rules absent from the budget file are capped at zero). The
/// ratchet direction is deliberate — suppression debt can shrink without
/// touching the budget file, but growing it means editing a reviewed,
/// checked-in number.
pub fn check_budget(stats: &LintStats, budget: &[(String, u32)]) -> Vec<String> {
    let mut out = Vec::new();
    for &(name, _, pragmas) in &stats.per_rule {
        let cap = budget.iter().find(|(k, _)| k == name).map_or(0, |(_, v)| *v);
        if pragmas > cap {
            out.push(format!(
                "rule `{name}`: {pragmas} pragma(s) exceed the checked-in budget of {cap} \
                 ({BUDGET_PATH}) — pay down suppression debt instead of growing it"
            ));
        }
    }
    out
}

/// One lint finding, addressed by repo-relative path and 1-based position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub path: String,
    pub line: u32,
    pub col: u32,
    /// Canonical rule name (`det-map`, `wall-clock`, ..., or `pragma`).
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: moelint({}): {}",
            self.path, self.line, self.col, self.rule, self.msg
        )
    }
}

impl Finding {
    /// One machine-readable JSON object (newline-delimited stream format).
    pub fn to_json(&self) -> String {
        format!(
            r#"{{"path":"{}","line":{},"col":{},"rule":"{}","msg":"{}"}}"#,
            json_escape(&self.path),
            self.line,
            self.col,
            self.rule,
            json_escape(&self.msg)
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A parsed `moelint:` pragma comment: either a valid suppression or a
/// `pragma`-rule finding message.
fn parse_pragma(text: &str) -> Option<Result<&'static str, String>> {
    if items::is_hot_comment(text) {
        return None; // R9's annotation, not a suppression — items.rs owns it
    }
    let rest = text.trim().strip_prefix("moelint:")?.trim();
    let Some(inner) = rest.strip_prefix("allow(").and_then(|r| r.trim_end().strip_suffix(')'))
    else {
        return Some(Err(format!(
            "malformed pragma `{}`: expected `moelint: allow(<rule>, <reason>)`",
            rest
        )));
    };
    let (rule_arg, reason) = match inner.split_once(',') {
        Some((r, why)) => (r, why.trim()),
        None => (inner, ""),
    };
    let Some(rule) = resolve_rule(rule_arg) else {
        return Some(Err(format!(
            "pragma names unknown rule `{}` (see rules::RULES)",
            rule_arg.trim()
        )));
    };
    if reason.is_empty() {
        return Some(Err(format!(
            "pragma for `{rule}` has no reason: suppressions must say why (`allow({rule}, \
             <reason>)`)"
        )));
    }
    Some(Ok(rule))
}

/// Lint one file's source. `rel_path` is the repo-relative path with
/// forward slashes (it determines rule scope — see [`FileClass`]).
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Finding> {
    lint_source_with_stats(rel_path, src, &mut LintStats::default())
}

/// [`lint_source`] that also tallies per-rule findings and pragmas into
/// `stats` (the `--stats`/budget surface).
pub fn lint_source_with_stats(rel_path: &str, src: &str, stats: &mut LintStats) -> Vec<Finding> {
    let class = FileClass::classify(rel_path);
    let lexed = lex(src);

    let mut out = Vec::new();
    let mut allow: Vec<(u32, &'static str)> = Vec::new();
    for c in &lexed.comments {
        match parse_pragma(&c.text) {
            None => {}
            Some(Ok(rule)) => {
                stats.bump_pragma(rule);
                allow.push((c.line, rule));
                if !c.trailing {
                    // standalone pragma: applies to the next code line
                    if let Some(t) = lexed.tokens.iter().find(|t| t.line > c.line) {
                        allow.push((t.line, rule));
                    }
                }
            }
            Some(Err(msg)) => out.push(Finding {
                path: class.rel.clone(),
                line: c.line,
                col: 1,
                rule: "pragma",
                msg,
            }),
        }
    }

    let mut raw = Vec::new();
    check_all(&class, &lexed, &mut raw);
    out.extend(
        raw.into_iter()
            .filter(|f| !allow.iter().any(|&(l, r)| l == f.line && r == f.rule)),
    );
    out.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    for f in &out {
        stats.bump_finding(f.rule);
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the whole repo under `root` (the directory containing `rust/`),
/// walking [`LINT_ROOTS`] in deterministic (sorted) order.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    lint_tree_with_stats(root).map(|(findings, _)| findings)
}

/// [`lint_tree`] that also returns the per-rule tallies.
pub fn lint_tree_with_stats(root: &Path) -> io::Result<(Vec<Finding>, LintStats)> {
    let mut files = Vec::new();
    for sub in LINT_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    let mut out = Vec::new();
    let mut stats = LintStats::default();
    for f in &files {
        let src = fs::read_to_string(f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        out.extend(lint_source_with_stats(&rel, &src, &mut stats));
    }
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ------------------------------------------------------------ fixtures

    #[test]
    fn r1_trips_in_sim_modules_only() {
        let fix = "use std::collections::{HashMap, HashSet};\n\
                   fn f() { let _m: HashMap<u32, u32> = HashMap::new(); }\n";
        let hits = lint_source("rust/src/cache/fixture.rs", fix);
        assert!(hits.iter().all(|f| f.rule == "det-map"), "{hits:?}");
        assert_eq!(hits.len(), 4, "import x2 + type + ctor: {hits:?}");
        // out of scope: non-sim module, tests, benches
        assert!(lint_source("rust/src/metrics/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/tests/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/benches/fixture.rs", fix).is_empty());
    }

    #[test]
    fn r1_catches_every_sim_module() {
        let fix = "fn f() { let _s = std::collections::HashSet::<u32>::new(); }\n";
        for m in rules::SIM_MODULES {
            let hits = lint_source(&format!("rust/src/{m}/fixture.rs"), fix);
            assert_eq!(rules_of(&hits), vec!["det-map"], "module {m}");
        }
    }

    #[test]
    fn r2_trips_on_wall_clock_outside_benches() {
        let fix = "fn f() -> std::time::Instant { std::time::Instant::now() }\n\
                   fn g() { let _t = std::time::SystemTime::now(); }\n";
        let hits = lint_source("rust/src/server/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["wall-clock", "wall-clock"]);
        assert_eq!((hits[0].line, hits[1].line), (1, 2));
        assert!(lint_source("rust/benches/fixture.rs", fix).is_empty());
    }

    #[test]
    fn r3_trips_on_threads_outside_the_pool() {
        let fix = "fn f() { std::thread::spawn(|| {}).join().unwrap(); }\n";
        assert_eq!(rules_of(&lint_source("rust/src/trace/fixture.rs", fix)), vec!["thread"]);
        assert_eq!(
            rules_of(&lint_source("rust/src/whatever.rs", "use rayon::prelude::*;\n")),
            vec!["thread"]
        );
        assert!(lint_source("rust/src/util/pool.rs", fix).is_empty());
    }

    #[test]
    fn r7_trips_on_hinted_raw_f64_params_and_fields() {
        let fix = "pub struct S { pub stall_time: f64, pub frac: f64 }\n\
                   pub fn f(deadline: f64) -> f64 { deadline }\n\
                   pub enum E { Lands { delay: f64, retries: u32 } }\n";
        let hits = lint_source("rust/src/memory/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["raw-units", "raw-units", "raw-units"], "{hits:?}");
        assert_eq!((hits[0].line, hits[1].line, hits[2].line), (1, 2, 3));
        // out of units scope: engine module, tests dir, benches
        assert!(lint_source("rust/src/engine/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/tests/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/benches/fixture.rs", fix).is_empty());
    }

    #[test]
    fn r7_ignores_containers_locals_returns_and_test_scope() {
        // Vec<f64> buffers, Option<f64> knobs, fn-local lets, return
        // types and neutral-named boundary params are all out of shape
        let clean = "pub struct S { pub ttft_val: Vec<f64>, pub slo: Option<f64> }\n\
                     pub fn new(window_s: f64) -> f64 { let stall_s: f64 = window_s; stall_s }\n\
                     pub fn slots(slot_share: usize) -> usize { slot_share }\n";
        assert!(lint_source("rust/src/server/fixture.rs", clean).is_empty());
        // #[cfg(test)] scope is exempt (raw floats fine in test helpers)
        let test_scoped = "#[cfg(test)]\nmod tests {\n  pub struct T { pub makespan: f64 }\n\
                           fn f(latency: f64) -> f64 { latency }\n}\n";
        assert!(lint_source("rust/src/memory/fixture.rs", test_scoped).is_empty());
        // fn-local structs are not API surface
        let local = "pub fn f() { struct L { wait: f64 } let _ = L { wait: 0.0 }; }\n";
        assert!(lint_source("rust/src/cache/fixture.rs", local).is_empty());
    }

    #[test]
    fn r7_catches_every_units_module_and_respects_pragmas() {
        let fix = "pub fn f(elapsed: f64) -> f64 { elapsed }\n";
        for m in rules::UNITS_MODULES {
            let hits = lint_source(&format!("rust/src/{m}/fixture.rs"), fix);
            assert_eq!(rules_of(&hits), vec!["raw-units"], "module {m}");
        }
        let pragmad = "pub fn f(elapsed: f64) -> f64 { elapsed } \
                       // moelint: allow(raw-units, migration staging)\n";
        assert!(lint_source("rust/src/memory/fixture.rs", pragmad).is_empty());
    }

    #[test]
    fn r8_trips_on_serving_path_panics() {
        let fix = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n\
                   fn g(x: Option<u32>) -> u32 { x.expect(\"y\") }\n\
                   fn h() { panic!(\"boom\") }\n\
                   fn i() { unreachable!() }\n";
        let hits = lint_source("rust/src/server/fixture.rs", fix);
        assert_eq!(
            rules_of(&hits),
            vec!["panic-free", "panic-free", "panic-free", "panic-free"],
            "{hits:?}"
        );
        // out of scope: engine module (not a serving-path module), tests
        assert!(lint_source("rust/src/engine/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/tests/fixture.rs", fix).is_empty());
    }

    #[test]
    fn r8_allows_fallible_forms_asserts_and_test_scope() {
        let ok = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n\
                  fn g(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 7) }\n\
                  fn h(t: bool) { assert!(t, \"invariant\"); debug_assert!(t); }\n";
        assert!(lint_source("rust/src/memory/fixture.rs", ok).is_empty());
        let test_scoped = "#[cfg(test)]\nmod tests {\n  #[test]\n  fn t() { None::<u32>.unwrap(); }\n}\n";
        assert!(lint_source("rust/src/faults/fixture.rs", test_scoped).is_empty());
        let pragmad = "fn f(x: Option<u32>) -> u32 {\n    \
                       x.unwrap() // moelint: allow(panic-free, structurally Some: checked above)\n}\n";
        assert!(lint_source("rust/src/cache/fixture.rs", pragmad).is_empty());
    }

    #[test]
    fn r9_trips_on_allocation_inside_hot_windows() {
        let fix = "// moelint: hot\n\
                   #[inline]\n\
                   pub fn window(out: &mut Vec<u32>) {\n\
                       let v: Vec<u32> = Vec::new();\n\
                       let s = format!(\"x\");\n\
                       let w = vec![1u32];\n\
                       let b = Box::new(1u32);\n\
                       let t = s.to_string();\n\
                       let c: Vec<u32> = v.iter().copied().collect();\n\
                       out.extend(w.iter().chain(c.iter())); let _ = (b, t);\n\
                   }\n";
        let hits = lint_source("rust/src/engine/fixture.rs", fix);
        assert_eq!(hits.len(), 6, "{hits:?}");
        assert!(hits.iter().all(|f| f.rule == "hot-alloc"));
        // the same body without the annotation is out of scope
        let cold = fix.strip_prefix("// moelint: hot\n").unwrap();
        assert!(lint_source("rust/src/engine/fixture.rs", cold).is_empty());
    }

    #[test]
    fn r9_reports_stray_hot_annotations() {
        // annotation anchored to a non-fn item is stray, not silent
        let stray = "// moelint: hot\npub struct S { x: u32 }\nfn later() { vec![1]; }\n";
        let hits = lint_source("rust/src/engine/fixture.rs", stray);
        assert_eq!(rules_of(&hits), vec!["hot-alloc"], "{hits:?}");
        assert_eq!(hits[0].line, 1);
        // a trailing annotation at EOF is stray too
        let eof = "fn only() {}\n// moelint: hot\n";
        assert_eq!(rules_of(&lint_source("rust/src/engine/fixture.rs", eof)), vec!["hot-alloc"]);
    }

    #[test]
    fn r9_pragma_interaction() {
        let fix = "// moelint: hot\n\
                   fn window() {\n\
                       let v = vec![1u32]; // moelint: allow(hot-alloc, one-time warmup fill)\n\
                       let _ = v;\n\
                   }\n";
        assert!(lint_source("rust/src/engine/fixture.rs", fix).is_empty());
    }

    #[test]
    fn r10_trips_on_unrefreshed_replica_mutations() {
        let bad = "impl R {\n\
                   fn tick_all(&mut self) { for k in 0..2 { self.replicas[k].tick(); } }\n\
                   fn hand_off(&mut self, w: W) { self.replicas[w.replica].fail_over(0); }\n\
                   }\n";
        let hits = lint_source("rust/src/server/router.rs", bad);
        assert_eq!(rules_of(&hits), vec!["refresh-contract", "refresh-contract"], "{hits:?}");
        // same shapes with a refresh in the same fn are the contract held
        let good = "impl R {\n\
                    fn tick_all(&mut self) {\n\
                        for k in 0..2 { self.replicas[k].tick(); self.refresh(k); }\n\
                    }\n\
                    }\n";
        assert!(lint_source("rust/src/server/router.rs", good).is_empty());
        // non-mutating replica methods don't trip
        let peek = "impl R { fn load(&self) -> f64 { self.replicas[0].now() } }\n";
        assert!(lint_source("rust/src/server/router.rs", peek).is_empty());
        // scope is router.rs only
        assert!(lint_source("rust/src/server/mod.rs", bad).is_empty());
        // the lockstep reference suppresses with a reason
        let pragmad = "impl R { fn lockstep(&mut self) {\n\
                       self.replicas[0].tick(); // moelint: allow(refresh-contract, lockstep reference invalidates wholesale)\n\
                       } }\n";
        assert!(lint_source("rust/src/server/router.rs", pragmad).is_empty());
    }

    #[test]
    fn r5_trips_on_unsafe_outside_audited_files() {
        let fix = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        assert_eq!(rules_of(&lint_source("rust/src/engine/fixture.rs", fix)), vec!["unsafe"]);
        assert!(lint_source("rust/src/util/alloc.rs", fix).is_empty());
        assert!(lint_source("rust/src/util/pool.rs", fix).is_empty());
    }

    #[test]
    fn r6_trips_on_library_prints() {
        let fix = "fn f() { println!(\"x\"); eprintln!(\"y\"); dbg!(1); }\n";
        let hits = lint_source("rust/src/prefetch/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["print", "print", "print"]);
        assert!(lint_source("rust/src/main.rs", fix).is_empty());
        assert!(lint_source("rust/src/bin/tool.rs", fix).is_empty());
        assert!(lint_source("rust/tests/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/benches/fixture.rs", fix).is_empty());
    }

    // ------------------------------------------------------------- pragmas

    #[test]
    fn trailing_pragma_with_reason_suppresses() {
        let fix = "fn f() { let _t = std::time::Instant::now(); } \
                   // moelint: allow(wall-clock, fixture timing helper)\n";
        assert!(lint_source("rust/src/server/fixture.rs", fix).is_empty());
    }

    #[test]
    fn standalone_pragma_covers_the_next_code_line() {
        let fix = "// moelint: allow(det-map, fixture needs a std map)\n\
                   fn f() { let _m = std::collections::HashMap::<u8, u8>::new(); }\n";
        assert!(lint_source("rust/src/cache/fixture.rs", fix).is_empty());
        // ...but not lines beyond it
        let too_far = "// moelint: allow(det-map, fixture needs a std map)\n\
                       fn ok() {}\n\
                       fn f() { let _m = std::collections::HashMap::<u8, u8>::new(); }\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/cache/fixture.rs", too_far)),
            vec!["det-map"]
        );
    }

    #[test]
    fn pragma_accepts_rule_ids() {
        let fix = "fn f() { let _t = std::time::Instant::now(); } \
                   // moelint: allow(R2, id form is allowed)\n";
        assert!(lint_source("rust/src/server/fixture.rs", fix).is_empty());
    }

    #[test]
    fn reasonless_pragma_is_itself_a_finding_and_suppresses_nothing() {
        let fix = "// moelint: allow(wall-clock)\n\
                   fn f() { let _t = std::time::Instant::now(); }\n";
        let hits = lint_source("rust/src/server/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["pragma", "wall-clock"], "{hits:?}");
    }

    #[test]
    fn unknown_rule_and_malformed_pragmas_are_findings() {
        let unknown = "// moelint: allow(no-such-rule, why)\nfn f() {}\n";
        assert_eq!(rules_of(&lint_source("rust/src/x.rs", unknown)), vec!["pragma"]);
        let malformed = "// moelint: deny(everything)\nfn f() {}\n";
        assert_eq!(rules_of(&lint_source("rust/src/x.rs", malformed)), vec!["pragma"]);
        // `pragma` itself is not a suppressible target
        let meta = "// moelint: allow(pragma, nice try)\nfn f() {}\n";
        assert_eq!(rules_of(&lint_source("rust/src/x.rs", meta)), vec!["pragma"]);
    }

    #[test]
    fn pragma_only_suppresses_its_named_rule() {
        let fix = "fn f() { let _t = std::time::Instant::now(); println!(\"x\"); } \
                   // moelint: allow(wall-clock, only the clock is justified)\n";
        let hits = lint_source("rust/src/server/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["print"]);
    }

    // ------------------------------------------------------------- output

    #[test]
    fn display_and_json_are_machine_readable() {
        let f = Finding {
            path: "rust/src/cache/mod.rs".into(),
            line: 3,
            col: 7,
            rule: "det-map",
            msg: "a \"quoted\" message".into(),
        };
        assert_eq!(
            f.to_string(),
            "rust/src/cache/mod.rs:3:7: moelint(det-map): a \"quoted\" message"
        );
        assert_eq!(
            f.to_json(),
            r#"{"path":"rust/src/cache/mod.rs","line":3,"col":7,"rule":"det-map","msg":"a \"quoted\" message"}"#
        );
    }

    // --------------------------------------------------------------- stats

    #[test]
    fn stats_tally_findings_and_pragmas_per_rule() {
        let fix = "fn f() { let _t = std::time::Instant::now(); }\n\
                   fn g() { let _u = std::time::Instant::now(); } \
                   // moelint: allow(wall-clock, host timing fixture)\n\
                   fn h() { let _m = std::collections::HashMap::<u8, u8>::new(); }\n";
        let mut stats = LintStats::default();
        let hits = lint_source_with_stats("rust/src/server/fixture.rs", fix, &mut stats);
        assert_eq!(hits.len(), 2, "{hits:?}"); // unsuppressed clock + det-map
        assert_eq!(stats.findings_for("wall-clock"), 1);
        assert_eq!(stats.pragmas_for("wall-clock"), 1);
        assert_eq!(stats.findings_for("det-map"), 1);
        assert_eq!(stats.pragmas_for("det-map"), 0);
        assert_eq!(stats.total_findings(), 2);
        assert_eq!(stats.total_pragmas(), 1);
        // dead suppressions still count against the budget
        let dead = "// moelint: allow(unsafe, nothing here is unsafe)\nfn f() {}\n";
        let mut stats = LintStats::default();
        assert!(lint_source_with_stats("rust/src/x.rs", dead, &mut stats).is_empty());
        assert_eq!(stats.pragmas_for("unsafe"), 1);
        // the stats JSON row names every rule
        let json = stats.to_json();
        for r in RULES {
            assert!(json.contains(&format!("\"{}\"", r.name)), "{json}");
        }
    }

    #[test]
    fn budget_parses_and_ratchets() {
        let src = "{\n  \"wall-clock\": 2,\n  \"print\": 4\n}\n";
        let budget = parse_budget(src).expect("parse");
        assert_eq!(budget, vec![("wall-clock".to_string(), 2), ("print".to_string(), 4)]);
        let mut stats = LintStats::default();
        stats.bump_pragma("wall-clock");
        stats.bump_pragma("wall-clock");
        assert!(check_budget(&stats, &budget).is_empty());
        stats.bump_pragma("wall-clock");
        let violations = check_budget(&stats, &budget);
        assert_eq!(violations.len(), 1, "{violations:?}");
        assert!(violations[0].contains("wall-clock"));
        // rules absent from the budget are capped at zero
        stats.bump_pragma("det-map");
        assert_eq!(check_budget(&stats, &budget).len(), 2);
        // malformed budgets are rejected, not guessed at
        assert!(parse_budget("not json").is_none());
        assert!(parse_budget("{\"x\": -1}").is_none());
    }

    // ---------------------------------------------------------- self-check

    /// The ratchet: the crate must lint clean. Every suppression in the
    /// tree carries a reason (reasonless pragmas surface here as `pragma`
    /// findings — this test is the satellite's honesty check).
    #[test]
    fn crate_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = lint_tree(root).expect("lint walk");
        assert!(
            findings.is_empty(),
            "moelint found {} issue(s):\n{}",
            findings.len(),
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    /// The debt ceiling: total pragmas per rule must stay within the
    /// checked-in budget. Deleting a pragma never breaks this; adding one
    /// means editing `scripts/lint_budget.json` in the same reviewed
    /// change.
    #[test]
    fn pragma_debt_within_budget() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let (_, stats) = lint_tree_with_stats(root).expect("lint walk");
        let src = std::fs::read_to_string(root.join(BUDGET_PATH)).expect("budget file");
        let budget = parse_budget(&src).expect("budget parses");
        let violations = check_budget(&stats, &budget);
        assert!(violations.is_empty(), "{}", violations.join("\n"));
    }
}
