//! `moelint` — a dependency-free, source-level determinism & hot-path lint.
//!
//! Every guarantee this repo pins dynamically (lockstep ≡ calendar replay,
//! pooled ≡ serial at any thread count, zero-allocation warmed windows) is
//! only as strong as the differential tests that happen to cover the code.
//! `moelint` makes the underlying properties *checked properties of the
//! source*: no entropy-seeded hash containers on decision paths (R1), no
//! wall-clock reads outside benches (R2), no parallelism outside the
//! deterministic pool (R3), no silent float→int truncation of sim-time or
//! byte quantities (R4), no `unsafe` outside the two Miri-audited files
//! (R5), and no stray printing from library modules (R6).
//!
//! * Rule engine: [`rules`] (catalogue in [`rules::RULES`]).
//! * Tokenizer: [`lex`] (comments, strings, lifetimes, numerics, `::`).
//! * Suppression: `// moelint: allow(<rule>, <reason>)` on the offending
//!   line, or on its own line directly above. The reason is **mandatory**;
//!   a reasonless or unknown-rule pragma is itself a finding (`pragma`),
//!   and `pragma` findings cannot be suppressed.
//! * Binary: `cargo run --bin moelint [--json] [ROOT]` — exit 0 clean,
//!   1 findings, 2 usage/IO error.
//!
//! The self-check test at the bottom runs the linter over the whole crate,
//! so `cargo test` fails the moment a rule regresses — the same wall CI
//! enforces via the `lint` job.

pub mod lex;
pub mod rules;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use lex::lex;
use rules::{check_all, resolve_rule, FileClass};

/// Directories (relative to the repo root) the linter walks.
pub const LINT_ROOTS: [&str; 3] = ["rust/src", "rust/benches", "rust/tests"];

/// One lint finding, addressed by repo-relative path and 1-based position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub path: String,
    pub line: u32,
    pub col: u32,
    /// Canonical rule name (`det-map`, `wall-clock`, ..., or `pragma`).
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: moelint({}): {}",
            self.path, self.line, self.col, self.rule, self.msg
        )
    }
}

impl Finding {
    /// One machine-readable JSON object (newline-delimited stream format).
    pub fn to_json(&self) -> String {
        format!(
            r#"{{"path":"{}","line":{},"col":{},"rule":"{}","msg":"{}"}}"#,
            json_escape(&self.path),
            self.line,
            self.col,
            self.rule,
            json_escape(&self.msg)
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A parsed `moelint:` pragma comment: either a valid suppression or a
/// `pragma`-rule finding message.
fn parse_pragma(text: &str) -> Option<Result<&'static str, String>> {
    let rest = text.trim().strip_prefix("moelint:")?.trim();
    let Some(inner) = rest.strip_prefix("allow(").and_then(|r| r.trim_end().strip_suffix(')'))
    else {
        return Some(Err(format!(
            "malformed pragma `{}`: expected `moelint: allow(<rule>, <reason>)`",
            rest
        )));
    };
    let (rule_arg, reason) = match inner.split_once(',') {
        Some((r, why)) => (r, why.trim()),
        None => (inner, ""),
    };
    let Some(rule) = resolve_rule(rule_arg) else {
        return Some(Err(format!(
            "pragma names unknown rule `{}` (see rules::RULES)",
            rule_arg.trim()
        )));
    };
    if reason.is_empty() {
        return Some(Err(format!(
            "pragma for `{rule}` has no reason: suppressions must say why (`allow({rule}, \
             <reason>)`)"
        )));
    }
    Some(Ok(rule))
}

/// Lint one file's source. `rel_path` is the repo-relative path with
/// forward slashes (it determines rule scope — see [`FileClass`]).
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Finding> {
    let class = FileClass::classify(rel_path);
    let lexed = lex(src);

    let mut out = Vec::new();
    let mut allow: Vec<(u32, &'static str)> = Vec::new();
    for c in &lexed.comments {
        match parse_pragma(&c.text) {
            None => {}
            Some(Ok(rule)) => {
                allow.push((c.line, rule));
                if !c.trailing {
                    // standalone pragma: applies to the next code line
                    if let Some(t) = lexed.tokens.iter().find(|t| t.line > c.line) {
                        allow.push((t.line, rule));
                    }
                }
            }
            Some(Err(msg)) => out.push(Finding {
                path: class.rel.clone(),
                line: c.line,
                col: 1,
                rule: "pragma",
                msg,
            }),
        }
    }

    let mut raw = Vec::new();
    check_all(&class, &lexed, &mut raw);
    out.extend(
        raw.into_iter()
            .filter(|f| !allow.iter().any(|&(l, r)| l == f.line && r == f.rule)),
    );
    out.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the whole repo under `root` (the directory containing `rust/`),
/// walking [`LINT_ROOTS`] in deterministic (sorted) order.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for sub in LINT_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    let mut out = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        out.extend(lint_source(&rel, &src));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ------------------------------------------------------------ fixtures

    #[test]
    fn r1_trips_in_sim_modules_only() {
        let fix = "use std::collections::{HashMap, HashSet};\n\
                   fn f() { let _m: HashMap<u32, u32> = HashMap::new(); }\n";
        let hits = lint_source("rust/src/cache/fixture.rs", fix);
        assert!(hits.iter().all(|f| f.rule == "det-map"), "{hits:?}");
        assert_eq!(hits.len(), 4, "import x2 + type + ctor: {hits:?}");
        // out of scope: non-sim module, tests, benches
        assert!(lint_source("rust/src/metrics/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/tests/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/benches/fixture.rs", fix).is_empty());
    }

    #[test]
    fn r1_catches_every_sim_module() {
        let fix = "fn f() { let _s = std::collections::HashSet::<u32>::new(); }\n";
        for m in rules::SIM_MODULES {
            let hits = lint_source(&format!("rust/src/{m}/fixture.rs"), fix);
            assert_eq!(rules_of(&hits), vec!["det-map"], "module {m}");
        }
    }

    #[test]
    fn r2_trips_on_wall_clock_outside_benches() {
        let fix = "fn f() -> std::time::Instant { std::time::Instant::now() }\n\
                   fn g() { let _t = std::time::SystemTime::now(); }\n";
        let hits = lint_source("rust/src/server/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["wall-clock", "wall-clock"]);
        assert_eq!((hits[0].line, hits[1].line), (1, 2));
        assert!(lint_source("rust/benches/fixture.rs", fix).is_empty());
    }

    #[test]
    fn r3_trips_on_threads_outside_the_pool() {
        let fix = "fn f() { std::thread::spawn(|| {}).join().unwrap(); }\n";
        assert_eq!(rules_of(&lint_source("rust/src/trace/fixture.rs", fix)), vec!["thread"]);
        assert_eq!(
            rules_of(&lint_source("rust/src/whatever.rs", "use rayon::prelude::*;\n")),
            vec!["thread"]
        );
        assert!(lint_source("rust/src/util/pool.rs", fix).is_empty());
    }

    #[test]
    fn r4_trips_on_quantity_truncation_only() {
        // float evidence + quantity hint on the line -> finding
        let fix = "fn f(elapsed_s: f64) -> u64 { (elapsed_s * 1e3) as u64 }\n";
        assert_eq!(rules_of(&lint_source("rust/src/memory/fixture.rs", fix)), vec!["float-cast"]);
        // no quantity hint -> clean (a percentile rank, say)
        let no_hint = "fn f(frac: f64, n: usize) -> usize { (frac * n as f64) as usize }\n";
        assert!(lint_source("rust/src/metrics/fixture.rs", no_hint).is_empty());
        // quantity hint but no float on the line -> clean (int-to-int)
        let no_float = "fn f(byte_count: u32) -> u64 { byte_count as u64 }\n";
        assert!(lint_source("rust/src/memory/fixture.rs", no_float).is_empty());
        // int-to-float widening is never flagged
        let widen = "fn f(bytes: u64) -> f64 { bytes as f64 }\n";
        assert!(lint_source("rust/src/memory/fixture.rs", widen).is_empty());
    }

    #[test]
    fn r5_trips_on_unsafe_outside_audited_files() {
        let fix = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        assert_eq!(rules_of(&lint_source("rust/src/engine/fixture.rs", fix)), vec!["unsafe"]);
        assert!(lint_source("rust/src/util/alloc.rs", fix).is_empty());
        assert!(lint_source("rust/src/util/pool.rs", fix).is_empty());
    }

    #[test]
    fn r6_trips_on_library_prints() {
        let fix = "fn f() { println!(\"x\"); eprintln!(\"y\"); dbg!(1); }\n";
        let hits = lint_source("rust/src/prefetch/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["print", "print", "print"]);
        assert!(lint_source("rust/src/main.rs", fix).is_empty());
        assert!(lint_source("rust/src/bin/tool.rs", fix).is_empty());
        assert!(lint_source("rust/tests/fixture.rs", fix).is_empty());
        assert!(lint_source("rust/benches/fixture.rs", fix).is_empty());
    }

    // ------------------------------------------------------------- pragmas

    #[test]
    fn trailing_pragma_with_reason_suppresses() {
        let fix = "fn f() { let _t = std::time::Instant::now(); } \
                   // moelint: allow(wall-clock, fixture timing helper)\n";
        assert!(lint_source("rust/src/server/fixture.rs", fix).is_empty());
    }

    #[test]
    fn standalone_pragma_covers_the_next_code_line() {
        let fix = "// moelint: allow(det-map, fixture needs a std map)\n\
                   fn f() { let _m = std::collections::HashMap::<u8, u8>::new(); }\n";
        assert!(lint_source("rust/src/cache/fixture.rs", fix).is_empty());
        // ...but not lines beyond it
        let too_far = "// moelint: allow(det-map, fixture needs a std map)\n\
                       fn ok() {}\n\
                       fn f() { let _m = std::collections::HashMap::<u8, u8>::new(); }\n";
        assert_eq!(
            rules_of(&lint_source("rust/src/cache/fixture.rs", too_far)),
            vec!["det-map"]
        );
    }

    #[test]
    fn pragma_accepts_rule_ids() {
        let fix = "fn f() { let _t = std::time::Instant::now(); } \
                   // moelint: allow(R2, id form is allowed)\n";
        assert!(lint_source("rust/src/server/fixture.rs", fix).is_empty());
    }

    #[test]
    fn reasonless_pragma_is_itself_a_finding_and_suppresses_nothing() {
        let fix = "// moelint: allow(wall-clock)\n\
                   fn f() { let _t = std::time::Instant::now(); }\n";
        let hits = lint_source("rust/src/server/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["pragma", "wall-clock"], "{hits:?}");
    }

    #[test]
    fn unknown_rule_and_malformed_pragmas_are_findings() {
        let unknown = "// moelint: allow(no-such-rule, why)\nfn f() {}\n";
        assert_eq!(rules_of(&lint_source("rust/src/x.rs", unknown)), vec!["pragma"]);
        let malformed = "// moelint: deny(everything)\nfn f() {}\n";
        assert_eq!(rules_of(&lint_source("rust/src/x.rs", malformed)), vec!["pragma"]);
        // `pragma` itself is not a suppressible target
        let meta = "// moelint: allow(pragma, nice try)\nfn f() {}\n";
        assert_eq!(rules_of(&lint_source("rust/src/x.rs", meta)), vec!["pragma"]);
    }

    #[test]
    fn pragma_only_suppresses_its_named_rule() {
        let fix = "fn f() { let _t = std::time::Instant::now(); println!(\"x\"); } \
                   // moelint: allow(wall-clock, only the clock is justified)\n";
        let hits = lint_source("rust/src/server/fixture.rs", fix);
        assert_eq!(rules_of(&hits), vec!["print"]);
    }

    // ------------------------------------------------------------- output

    #[test]
    fn display_and_json_are_machine_readable() {
        let f = Finding {
            path: "rust/src/cache/mod.rs".into(),
            line: 3,
            col: 7,
            rule: "det-map",
            msg: "a \"quoted\" message".into(),
        };
        assert_eq!(
            f.to_string(),
            "rust/src/cache/mod.rs:3:7: moelint(det-map): a \"quoted\" message"
        );
        assert_eq!(
            f.to_json(),
            r#"{"path":"rust/src/cache/mod.rs","line":3,"col":7,"rule":"det-map","msg":"a \"quoted\" message"}"#
        );
    }

    // ---------------------------------------------------------- self-check

    /// The ratchet: the crate must lint clean. Every suppression in the
    /// tree carries a reason (reasonless pragmas surface here as `pragma`
    /// findings — this test is the satellite's honesty check).
    #[test]
    fn crate_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = lint_tree(root).expect("lint walk");
        assert!(
            findings.is_empty(),
            "moelint found {} issue(s):\n{}",
            findings.len(),
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
