//! Deterministic fault-injection plans (robustness substrate).
//!
//! A [`FaultPlan`] is a *schedule* of adverse events, not a live random
//! process: transient transfer failures are Bernoulli draws from dedicated
//! [`Rng::for_stream`] streams keyed off the plan seed (so the fault
//! timeline is a pure function of the plan, independent of how many
//! transfers other links perform), bandwidth brownouts are time-windowed
//! multipliers on a [`crate::memory::Link`]'s effective bandwidth, and
//! replica crashes are `[crash, recover)` windows consumed by the router.
//!
//! The cardinal contract, pinned across the test suite: an **empty plan is
//! free**. `MemorySim` holds `Option<Box<FaultState>>` = `None` unless the
//! plan actually perturbs links, every hot-path hook checks that option
//! before touching a float, and the zero-fault replay is bitwise identical
//! to a build without any plan installed.
//!
//! Failure semantics (all in simulated time):
//! * a failed transfer attempt still occupies its link for the full
//!   service time, then waits a capped exponential backoff before retrying
//!   ([`RetryPolicy`], [`backoff`]);
//! * a *prefetch* that exhausts its retries is dropped — the expert simply
//!   stays where it was and a later demand fetches it on the critical path
//!   (degraded, never wedged);
//! * a *demand* fetch that exhausts its retries counts a `demand_failures`
//!   stat and is then force-landed with one extra attempt, so the engine's
//!   event loop always terminates (a real system would fail the request;
//!   the simulator charges the time and keeps the replay total).

use crate::util::units::SimTime;
use crate::util::Rng;

/// Stream id for the SSD→DRAM link's fault draws.
const STREAM_SSD: u64 = 0xFA01;
/// Base stream id for the DRAM→GPU links' fault draws (link `g` uses
/// `STREAM_GPU_BASE + g`).
const STREAM_GPU_BASE: u64 = 0xFA10;

/// Which transfer link a fault event applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultLink {
    SsdToDram,
    DramToGpu,
}

/// A time-windowed bandwidth degradation: while `start <= t < end`, the
/// link's effective bandwidth is multiplied by `factor` (in `(0, 1]`).
/// Overlapping windows on the same link compound multiplicatively.
#[derive(Debug, Clone)]
pub struct Brownout {
    pub link: FaultLink,
    pub start: SimTime,
    pub end: SimTime,
    pub factor: f64,
}

/// A replica crash window: the replica is dead for `[crash, recover)`.
/// `recover = SimTime::INFINITY` means it never comes back.
#[derive(Debug, Clone)]
pub struct CrashWindow {
    pub replica: usize,
    pub crash: SimTime,
    pub recover: SimTime,
}

impl CrashWindow {
    /// Is the replica down at simulated time `t`?
    pub fn down_at(&self, t: SimTime) -> bool {
        t >= self.crash && t < self.recover
    }

    /// Has the crash edge been reached by the replica's clock (`t >=
    /// crash`)? This is the router's firing predicate, split out so the
    /// event calendar and the lockstep reference loop share it verbatim:
    /// a batched replica runs only until its clock crosses its earliest
    /// unfired crash instant, so the window fires at exactly the
    /// iteration boundary the per-tick polling loop fired it at.
    pub fn fires_by(&self, t: SimTime) -> bool {
        t >= self.crash
    }
}

/// Capped exponential backoff schedule for failed transfers.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Delay before the first retry (simulated).
    pub base_delay: SimTime,
    /// Ceiling on any single backoff delay.
    pub max_delay: SimTime,
    /// Retries granted after the initial attempt; attempt count is
    /// therefore `max_retries + 1`.
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            base_delay: SimTime::from_f64(0.5e-3),
            max_delay: SimTime::from_f64(8e-3),
            max_retries: 4,
        }
    }
}

/// The backoff before retry `attempt` (0-based): `base_delay * 2^attempt`,
/// capped at `max_delay`. Pure — the property tests pin determinism and
/// the cap on this function plus [`draw_transfer`].
pub fn backoff(retry: &RetryPolicy, attempt: u32) -> SimTime {
    let exp = attempt.min(52); // avoid 2^big overflowing the f64 exponent
    (retry.base_delay * (1u64 << exp) as f64).min(retry.max_delay)
}

/// A deterministic schedule of faults.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Seed for the per-link fault streams (independent of every other
    /// stream in the replay).
    pub seed: u64,
    /// Per-attempt failure probability on the SSD→DRAM link, in `[0, 1)`.
    pub ssd_failure_p: f64,
    /// Per-attempt failure probability on each DRAM→GPU link, in `[0, 1)`.
    pub gpu_failure_p: f64,
    /// Retry/backoff schedule shared by both links.
    pub retry: RetryPolicy,
    /// Bandwidth brownout windows.
    pub brownouts: Vec<Brownout>,
    /// Replica crash windows (router-level; ignored by `MemorySim`).
    pub crashes: Vec<CrashWindow>,
}

impl FaultPlan {
    /// An empty plan with the given stream seed (still "empty": no
    /// failures, no brownouts, no crashes — installing it is a no-op).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            ..FaultPlan::default()
        }
    }

    /// True when the plan schedules nothing at all.
    pub fn is_empty(&self) -> bool {
        !self.affects_links() && self.crashes.is_empty()
    }

    /// True when the plan perturbs transfer links (failures or brownouts).
    /// `MemorySim` only installs fault state when this holds, so an
    /// empty/crash-only plan leaves the memory hot path untouched.
    pub fn affects_links(&self) -> bool {
        self.ssd_failure_p > 0.0 || self.gpu_failure_p > 0.0 || !self.brownouts.is_empty()
    }

    /// Compounded brownout bandwidth multiplier for `link` at time `t`
    /// (1.0 outside every window).
    pub fn brownout_factor(&self, link: FaultLink, t: SimTime) -> f64 {
        let mut f = 1.0;
        for b in &self.brownouts {
            if b.link == link && t >= b.start && t < b.end {
                f *= b.factor;
            }
        }
        f
    }
}

/// Outcome of drawing the fault events for one transfer: either it lands
/// after `delay` total link-occupancy + backoff time, or it permanently
/// fails having burned `delay` anyway.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TransferOutcome {
    Lands { delay: SimTime, retries: u32 },
    Failed { delay: SimTime, retries: u32 },
}

impl TransferOutcome {
    pub fn retries(&self) -> u32 {
        match *self {
            TransferOutcome::Lands { retries, .. } => retries,
            TransferOutcome::Failed { retries, .. } => retries,
        }
    }

    pub fn delay(&self) -> SimTime {
        match *self {
            TransferOutcome::Lands { delay, .. } => delay,
            TransferOutcome::Failed { delay, .. } => delay,
        }
    }
}

/// Draw the full attempt sequence for one transfer whose single-attempt
/// service time is `dt`, failing each attempt with probability `p`. A
/// failed attempt occupies the link for the full `dt` (the wire went dead
/// mid-copy, not before it), then waits `backoff(retry, k)` before attempt
/// `k + 1`. After `max_retries` retries the transfer is `Failed` — the
/// caller decides whether that means *drop* (prefetch) or *force-land with
/// a counted failure* (demand).
pub fn draw_transfer(rng: &mut Rng, p: f64, retry: &RetryPolicy, dt: SimTime) -> TransferOutcome {
    debug_assert!((0.0..1.0).contains(&p), "failure probability {p} not in [0,1)");
    let mut delay = SimTime::ZERO;
    let mut retries = 0u32;
    loop {
        if rng.f64() >= p {
            return TransferOutcome::Lands {
                delay: delay + dt,
                retries,
            };
        }
        delay += dt; // the failed attempt still burned its service time
        if retries >= retry.max_retries {
            return TransferOutcome::Failed { delay, retries };
        }
        delay += backoff(retry, retries);
        retries += 1;
    }
}

/// Live fault-draw state owned by one `MemorySim`: the plan plus one
/// dedicated RNG stream per link. Boxed behind an `Option` so the
/// fault-free hot path carries a single pointer-null check.
#[derive(Debug, Clone)]
pub struct FaultState {
    pub plan: FaultPlan,
    pub rng_ssd: Rng,
    pub rng_gpu: Vec<Rng>,
}

impl FaultState {
    pub fn new(plan: FaultPlan, n_gpus: usize) -> FaultState {
        let rng_ssd = Rng::for_stream(plan.seed, STREAM_SSD);
        let rng_gpu = (0..n_gpus)
            .map(|g| Rng::for_stream(plan.seed, STREAM_GPU_BASE + g as u64))
            .collect();
        FaultState {
            plan,
            rng_ssd,
            rng_gpu,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall_res;

    fn st(secs: f64) -> SimTime {
        SimTime::from_f64(secs)
    }

    #[test]
    fn crash_window_edges_are_half_open() {
        let w = CrashWindow {
            replica: 0,
            crash: st(1.0),
            recover: st(2.0),
        };
        assert!(!w.down_at(st(0.999)) && w.down_at(st(1.0)) && w.down_at(st(1.999)));
        assert!(!w.down_at(st(2.0)), "recover instant is exclusive of downtime");
        // the firing predicate is the crash edge alone: a clock that idles
        // past recover still fires the window if it ever crossed crash
        assert!(!w.fires_by(st(0.999)));
        assert!(w.fires_by(st(1.0)) && w.fires_by(st(5.0)));
    }

    #[test]
    fn empty_plan_is_empty_and_linkless() {
        let p = FaultPlan::new(7);
        assert!(p.is_empty());
        assert!(!p.affects_links());
        assert_eq!(p.brownout_factor(FaultLink::SsdToDram, st(3.0)), 1.0);
    }

    #[test]
    fn crash_only_plan_leaves_links_alone() {
        let mut p = FaultPlan::new(7);
        p.crashes.push(CrashWindow {
            replica: 1,
            crash: st(2.0),
            recover: st(5.0),
        });
        assert!(!p.is_empty());
        assert!(!p.affects_links());
        assert!(p.crashes[0].down_at(st(2.0)));
        assert!(p.crashes[0].down_at(st(4.999)));
        assert!(!p.crashes[0].down_at(st(5.0)));
        assert!(!p.crashes[0].down_at(st(1.0)));
    }

    #[test]
    fn permanent_crash_never_recovers() {
        let w = CrashWindow {
            replica: 0,
            crash: st(1.0),
            recover: SimTime::INFINITY,
        };
        assert!(w.down_at(st(1e12)));
    }

    #[test]
    fn backoff_doubles_then_caps() {
        let r = RetryPolicy {
            base_delay: st(1e-3),
            max_delay: st(5e-3),
            max_retries: 10,
        };
        assert_eq!(backoff(&r, 0), 1e-3);
        assert_eq!(backoff(&r, 1), 2e-3);
        assert_eq!(backoff(&r, 2), 4e-3);
        assert_eq!(backoff(&r, 3), 5e-3); // 8e-3 capped
        assert_eq!(backoff(&r, 60), 5e-3); // huge attempt index stays finite
    }

    #[test]
    fn brownout_windows_compound() {
        let mut p = FaultPlan::new(1);
        p.brownouts.push(Brownout {
            link: FaultLink::DramToGpu,
            start: st(1.0),
            end: st(3.0),
            factor: 0.5,
        });
        p.brownouts.push(Brownout {
            link: FaultLink::DramToGpu,
            start: st(2.0),
            end: st(4.0),
            factor: 0.5,
        });
        assert_eq!(p.brownout_factor(FaultLink::DramToGpu, st(0.5)), 1.0);
        assert_eq!(p.brownout_factor(FaultLink::DramToGpu, st(1.5)), 0.5);
        assert_eq!(p.brownout_factor(FaultLink::DramToGpu, st(2.5)), 0.25);
        // other link untouched
        assert_eq!(p.brownout_factor(FaultLink::SsdToDram, st(2.5)), 1.0);
    }

    #[test]
    fn zero_probability_never_draws() {
        // p = 0 lands immediately without consuming a single RNG draw's
        // worth of divergence... it does draw once (the success check), but
        // MemorySim never even calls in when the plan is inactive; this
        // pins the pure function's behaviour at p = 0.
        let r = RetryPolicy::default();
        let mut rng = Rng::new(3);
        match draw_transfer(&mut rng, 0.0, &r, st(0.01)) {
            TransferOutcome::Lands { delay, retries } => {
                assert_eq!(delay, 0.01);
                assert_eq!(retries, 0);
            }
            other => panic!("expected Lands, got {other:?}"),
        }
    }

    #[test]
    fn draws_are_deterministic_for_a_fixed_stream() {
        let r = RetryPolicy::default();
        let mut a = Rng::for_stream(42, STREAM_SSD);
        let mut b = Rng::for_stream(42, STREAM_SSD);
        for _ in 0..200 {
            assert_eq!(
                draw_transfer(&mut a, 0.3, &r, st(0.01)),
                draw_transfer(&mut b, 0.3, &r, st(0.01))
            );
        }
    }

    #[test]
    fn retry_delays_are_deterministic_capped_and_bounded() {
        // Satellite property test: for arbitrary policies and failure
        // probabilities, (1) the outcome is a pure function of the stream,
        // (2) no single backoff exceeds max_delay, (3) total retries never
        // exceed max_retries, and (4) the accumulated delay is exactly
        // attempts * dt + the deterministic backoff prefix sum.
        forall_res(
            0xFA11,
            300,
            |rng| {
                let p = 0.05 + 0.9 * rng.f64(); // [0.05, 0.95)
                let retry = RetryPolicy {
                    base_delay: st(1e-4 * (1.0 + rng.f64())),
                    max_delay: st(1e-3 * (1.0 + 9.0 * rng.f64())),
                    max_retries: rng.below(8) as u32,
                };
                let dt = 1e-3 * (1.0 + rng.f64());
                let seed = rng.next_u64();
                (p, retry, dt, seed)
            },
            |(p, retry, dt, seed)| {
                let mut r1 = Rng::new(*seed);
                let mut r2 = Rng::new(*seed);
                let o1 = draw_transfer(&mut r1, *p, retry, st(*dt));
                let o2 = draw_transfer(&mut r2, *p, retry, st(*dt));
                if o1 != o2 {
                    return Err(format!("non-deterministic: {o1:?} vs {o2:?}"));
                }
                if o1.retries() > retry.max_retries {
                    return Err(format!(
                        "retries {} exceed max {}",
                        o1.retries(),
                        retry.max_retries
                    ));
                }
                for k in 0..=retry.max_retries {
                    let b = backoff(retry, k);
                    if b.to_f64() > retry.max_delay.to_f64() + 1e-15 {
                        return Err(format!("backoff({k}) = {b} exceeds cap {}", retry.max_delay));
                    }
                }
                // reconstruct the expected delay from the outcome shape
                let retries = o1.retries();
                let backoffs: f64 = (0..retries).map(|k| backoff(retry, k).to_f64()).sum();
                let want = match o1 {
                    TransferOutcome::Lands { .. } => (retries + 1) as f64 * dt + backoffs,
                    TransferOutcome::Failed { .. } => (retries + 1) as f64 * dt + backoffs,
                };
                if (o1.delay().to_f64() - want).abs() > 1e-12 {
                    return Err(format!("delay {} != reconstructed {want}", o1.delay()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn certain_failure_terminates_at_max_retries() {
        // p -> 1 must not stall: the attempt loop is bounded by max_retries.
        let r = RetryPolicy {
            base_delay: st(1e-3),
            max_delay: st(4e-3),
            max_retries: 3,
        };
        let mut rng = Rng::new(9);
        match draw_transfer(&mut rng, 0.999_999, &r, st(0.01)) {
            TransferOutcome::Failed { delay, retries } => {
                assert_eq!(retries, 3);
                let backoffs: f64 = (0..3).map(|k| backoff(&r, k).to_f64()).sum();
                assert!((delay.to_f64() - (4.0 * 0.01 + backoffs)).abs() < 1e-12);
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn typed_backoff_is_bitwise_the_raw_expression() {
        // the units migration contract: SimTime's operators replay
        // `base * 2^k as f64, min cap` — identical ops, identical order
        for &(base, cap) in &[(0.5e-3, 8e-3), (1e-4, 1e-3), (3.7e-5, 2.9e-2), (1e-2, 1e-2)] {
            let r = RetryPolicy {
                base_delay: st(base),
                max_delay: st(cap),
                max_retries: 8,
            };
            for k in 0..60u32 {
                let exp = k.min(52);
                let raw = (base * (1u64 << exp) as f64).min(cap);
                assert_eq!(
                    backoff(&r, k).to_bits(),
                    raw.to_bits(),
                    "base {base} cap {cap} attempt {k}"
                );
            }
        }
    }

    #[test]
    fn fault_state_streams_are_per_link_independent() {
        let plan = FaultPlan {
            seed: 11,
            ssd_failure_p: 0.5,
            ..FaultPlan::default()
        };
        let mut s1 = FaultState::new(plan.clone(), 2);
        let s2 = FaultState::new(plan, 2);
        // draining one link's stream must not move any other stream
        for _ in 0..64 {
            s1.rng_ssd.next_u64();
        }
        assert_eq!(s1.rng_gpu[0].clone().next_u64(), s2.rng_gpu[0].clone().next_u64());
        assert_eq!(s1.rng_gpu[1].clone().next_u64(), s2.rng_gpu[1].clone().next_u64());
    }
}
