//! Multi-replica request routing (the ROADMAP "multi-replica routing"
//! item, eMoE-style).
//!
//! A [`Router`] owns N engine replicas, each wrapped in its own
//! [`ContinuousScheduler`], and dispatches one arrival-ordered request
//! stream across them with a pluggable [`RoutingPolicy`]. The interesting
//! policy is **task affinity**: each replica's EAMC is scored against the
//! request's task signature (its prefill-iteration routing trace — the
//! simulator's stand-in for eMoE's task-level profiling) through the
//! incremental `trace::matcher` machinery, and the request lands on the
//! replica whose collection already represents its task best, lightly
//! penalized by load. Same-task sequences therefore pile onto the same
//! replica, which is exactly what preserves the activation locality the
//! expert cache and prefetcher exploit — the per-replica EAMCs then keep
//! specializing through the §4.3 online feedback loop.
//!
//! ## Determinism
//!
//! Each replica is an independent virtual timeline. The router's event
//! loop interleaves two actions: *dispatch* the next pending arrival once
//! every busy replica's [`ContinuousScheduler::next_event_bound`] has
//! reached it (replica states at the arrival instant are then final — no
//! later-simulated event can precede it), and otherwise *step* the replica
//! with the earliest bound by one quantum. The replay is a pure function
//! of the request stream and the replica set. With **one replica and
//! round-robin** the dispatch gate provably never changes admission
//! instants, so the replay is bitwise identical to a bare
//! [`ContinuousScheduler`] (pinned in `rust/tests/scheduler.rs`).

use std::collections::VecDeque;

use crate::engine::{prefill_chunk_tokens, SimEngine};
use crate::faults::{CrashWindow, FaultPlan};
use crate::server::{
    expected_iterations, AdmissionPolicy, Batcher, ContinuousScheduler, Scheduler, ServeReport,
};
use crate::trace::{EamcMatcher, MatcherIndex};
use crate::workload::{Request, SequenceActivation};

/// Per-replica fault-stream seed stride: replica `k` draws its link faults
/// from `plan.seed + k * 0x5EED`, so replicas fail independently yet the
/// whole timeline stays a pure function of the plan seed.
const REPLICA_FAULT_SEED_STRIDE: u64 = 0x5EED;

/// How the router picks a replica for each arriving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Cycle through replicas in submission order.
    #[default]
    RoundRobin,
    /// Fewest dispatched-but-unfinished requests (ties to lowest index).
    LeastLoaded,
    /// Minimal `EAMC distance + load penalty`: the request goes to the
    /// replica whose expert-activation collection best matches its prefill
    /// routing signature (ties to lowest index).
    TaskAffinity,
}

impl RoutingPolicy {
    pub fn by_name(s: &str) -> Option<RoutingPolicy> {
        match s {
            "round-robin" => Some(RoutingPolicy::RoundRobin),
            "least-loaded" => Some(RoutingPolicy::LeastLoaded),
            "task-affinity" => Some(RoutingPolicy::TaskAffinity),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::TaskAffinity => "task-affinity",
        }
    }
}

/// Weight of the occupancy term in the task-affinity score: distance is in
/// `[0, 1]`-ish Eq. 1 units, load is normalized by `max_batch`, so 0.25
/// breaks affinity ties toward idle replicas without overriding a clear
/// task match.
const AFFINITY_LOAD_WEIGHT: f64 = 0.25;

/// A task-affinity multi-replica request router. See the module docs.
pub struct Router<'r> {
    replicas: Vec<ContinuousScheduler<'r>>,
    policy: RoutingPolicy,
    max_batch: usize,
    /// Per-iteration prefill token budget applied to every replica
    /// (`u32::MAX` = plain continuous). Affinity scoring uses the same
    /// value: under chunked prefill only the first chunk of a prompt has
    /// routed by dispatch time, so the scorer sees that chunk's share of
    /// the signature instead of the full (not-yet-observable) prefill EAM.
    prefill_chunk: u32,
    rr_next: usize,
    /// Submitted, not yet dispatched (arrival order).
    pending: VecDeque<&'r Request>,
    /// Per-replica matcher scratch for affinity scoring (reused; scoring a
    /// request is allocation-free once warmed).
    scorers: Vec<EamcMatcher>,
    total_requests: usize,
    total_tokens: usize,
    /// Replica crash/recover windows from the fault plan (empty = the
    /// historical immortal-replica replay, bitwise-preserved: every fault
    /// hook below early-outs on `is_empty`).
    fault_windows: Vec<CrashWindow>,
    /// Whether each window's crash has fired (captured + re-dispatched).
    fired: Vec<bool>,
}

impl<'r> Router<'r> {
    /// Wrap `engines` (one per replica) in per-replica continuous
    /// schedulers sharing one batching/admission policy.
    pub fn new(
        engines: Vec<SimEngine>,
        batcher: Batcher,
        policy: RoutingPolicy,
        admission: AdmissionPolicy,
    ) -> Router<'r> {
        assert!(!engines.is_empty(), "router needs at least one replica");
        let n = engines.len();
        Router {
            replicas: engines
                .into_iter()
                .map(|e| ContinuousScheduler::new(e, batcher, admission))
                .collect(),
            policy,
            max_batch: batcher.max_batch,
            prefill_chunk: u32::MAX,
            rr_next: 0,
            pending: VecDeque::new(),
            scorers: (0..n).map(|_| EamcMatcher::new()).collect(),
            total_requests: 0,
            total_tokens: 0,
            fault_windows: Vec::new(),
            fired: Vec::new(),
        }
    }

    /// Install a fault plan across the replica set: the link-fault portion
    /// (failure probabilities, retry policy, brownouts) lands on every
    /// replica's engine under a per-replica derived seed
    /// ([`REPLICA_FAULT_SEED_STRIDE`]), and the crash/recover windows are
    /// kept by the router itself — a window fires at the first iteration
    /// boundary its replica's clock reaches, capturing in-flight sequences
    /// as warm [`crate::engine::PreemptedSeq`]s and re-dispatching them
    /// (and all waiting work) to survivors; the replica rejoins the
    /// dispatch set once its recover instant passes. An empty plan leaves
    /// the replay bitwise untouched.
    pub fn with_fault_plan(mut self, plan: &FaultPlan) -> Router<'r> {
        if plan.affects_links() {
            for (k, rep) in self.replicas.iter_mut().enumerate() {
                let mut p = plan.clone();
                p.seed = plan.seed.wrapping_add(k as u64 * REPLICA_FAULT_SEED_STRIDE);
                p.crashes.clear();
                rep.engine_mut().set_fault_plan(&p);
            }
        }
        self.fault_windows = plan.crashes.clone();
        self.fired = vec![false; self.fault_windows.len()];
        self
    }

    /// Enable SLO deadline shedding on every replica (see
    /// [`ContinuousScheduler::set_shedding`]).
    pub fn set_shedding(&mut self, on: bool) {
        for rep in &mut self.replicas {
            rep.set_shedding(on);
        }
    }

    /// Run every replica under chunked prefill with this per-iteration
    /// token budget (>= 1; `u32::MAX` = unlimited — the plain continuous
    /// router, bitwise-preserved). Task-affinity scoring switches to the
    /// first-chunk share of the prompt signature accordingly.
    pub fn with_prefill_chunk(mut self, chunk: u32) -> Router<'r> {
        assert!(chunk >= 1, "prefill_chunk must be >= 1 (u32::MAX = unlimited)");
        self.prefill_chunk = chunk;
        for rep in &mut self.replicas {
            rep.set_prefill_chunk(chunk);
        }
        self
    }

    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Read access to the per-replica schedulers (post-run stats).
    pub fn replicas(&self) -> &[ContinuousScheduler<'r>] {
        &self.replicas
    }

    /// Does window `w` make replica `k` undispatchable at instant `t`?
    /// Down at the dispatch instant itself, or — while the replica is
    /// still busy, so its clock is live — down at its current boundary (a
    /// fired crash whose recover instant the clock hasn't reached). An
    /// idle replica's frozen clock is deliberately ignored: a new submit
    /// idle-hops it to the arrival instant, past the window.
    fn window_blocks(&self, w: &CrashWindow, k: usize, t: f64) -> bool {
        w.replica == k
            && (w.down_at(t) || (self.replicas[k].has_work() && w.down_at(self.replicas[k].now())))
    }

    /// Is replica `k` inside any crash window at dispatch instant `t`?
    /// O(0) with no fault plan.
    fn replica_down(&self, k: usize, t: f64) -> bool {
        self.fault_windows
            .iter()
            .any(|w| self.window_blocks(w, k, t))
    }

    /// Pick the replica for `req` (dispatched at instant `t`) under the
    /// configured policy, skipping crashed replicas. With no fault plan
    /// the down-filter is free and the historical pick is bitwise
    /// unchanged.
    fn pick_replica(&mut self, req: &Request, t: f64) -> usize {
        let n = self.replicas.len();
        if !self.fault_windows.is_empty() && (0..n).all(|k| self.replica_down(k, t)) {
            // total blackout: park the request on the replica that
            // recovers soonest — it waits in that backlog instead of
            // deadlocking the dispatch gate
            let mut best = 0;
            let mut best_rec = f64::INFINITY;
            for k in 0..n {
                let mut rec = 0.0f64;
                for wi in 0..self.fault_windows.len() {
                    let w = self.fault_windows[wi].clone();
                    if self.window_blocks(&w, k, t) {
                        rec = rec.max(w.recover);
                    }
                }
                if rec < best_rec {
                    best_rec = rec;
                    best = k;
                }
            }
            return best;
        }
        match self.policy {
            RoutingPolicy::RoundRobin => loop {
                let k = self.rr_next % n;
                self.rr_next += 1;
                if !self.replica_down(k, t) {
                    return k;
                }
            },
            RoutingPolicy::LeastLoaded => {
                let mut best = usize::MAX;
                for k in 0..n {
                    if self.replica_down(k, t) {
                        continue;
                    }
                    if best == usize::MAX || self.replicas[k].load() < self.replicas[best].load() {
                        best = k;
                    }
                }
                best
            }
            RoutingPolicy::TaskAffinity => {
                let mut best = usize::MAX;
                let mut best_score = f64::INFINITY;
                for k in 0..n {
                    if self.replica_down(k, t) {
                        continue;
                    }
                    let eamc = self.replicas[k].engine().eamc();
                    let scorer = &mut self.scorers[k];
                    scorer.attach(eamc);
                    let index = eamc.index();
                    // task signature = the prefill routing the dispatcher
                    // can actually observe: the whole prompt normally, the
                    // first chunk's share under chunked prefill
                    record_prefill_signature(scorer, index, &req.seq, self.prefill_chunk);
                    // an empty EAMC (non-activation-aware bundles) scores
                    // neutrally; the load term then decides
                    let dist = scorer.nearest().map_or(0.0, |(_, d)| d);
                    let load = self.replicas[k].load() as f64 / self.max_batch as f64;
                    let score = dist + AFFINITY_LOAD_WEIGHT * load;
                    if best == usize::MAX || score < best_score {
                        best_score = score;
                        best = k;
                    }
                }
                best
            }
        }
    }

    /// Fire every crash window whose replica's clock has reached its crash
    /// instant: the replica's unfinished work — in-flight sequences as
    /// warm [`crate::engine::PreemptedSeq`] state, waiting/undispatched
    /// requests bare — is captured via
    /// [`ContinuousScheduler::fail_over`] and immediately re-dispatched to
    /// the surviving replicas under the routing policy (warm failover:
    /// `admit_resumed` on the survivor continues each sequence with
    /// identical per-token expert demands). A replica that idles past its
    /// whole window never fires it — there was nothing to lose — and the
    /// window degrades to pure dispatch filtering.
    fn fire_due_crashes(&mut self) {
        if self.fault_windows.is_empty() {
            return;
        }
        for wi in 0..self.fault_windows.len() {
            if self.fired[wi] {
                continue;
            }
            let w = self.fault_windows[wi].clone();
            if self.replicas[w.replica].now() < w.crash {
                continue;
            }
            self.fired[wi] = true;
            let handoff_t = self.replicas[w.replica].now();
            let mut captured = Vec::new();
            self.replicas[w.replica].fail_over(&mut captured);
            for (req, saved) in captured {
                let dst = self.pick_replica(req, handoff_t);
                self.replicas[dst].submit_failover(req, saved, handoff_t);
            }
        }
    }

    /// Queue one request (arrival order asserted) without re-sizing
    /// replica buffers; callers re-size via [`Router::presize_replicas`].
    fn enqueue(&mut self, req: &'r Request) {
        debug_assert!(
            self.pending.back().map_or(true, |p| p.arrival <= req.arrival),
            "requests must be submitted in arrival order"
        );
        self.total_requests += 1;
        // executed-iteration budget for replica pre-sizing (shared-budget
        // leftovers can split prompts past ceil(prompt/chunk) — see
        // `server::expected_iterations`)
        self.total_tokens += expected_iterations(&req.seq, self.prefill_chunk);
        self.pending.push_back(req);
    }

    /// Any replica may end up with the whole stream; pre-sizing after
    /// submission keeps dispatch-time replica pushes allocation-free
    /// mid-replay.
    fn presize_replicas(&mut self) {
        for rep in &mut self.replicas {
            rep.reserve_for(self.total_requests, self.total_tokens);
        }
    }

    /// Earliest next-event bound across replicas that still have work.
    fn frontier(&self) -> Option<f64> {
        let mut m: Option<f64> = None;
        for rep in &self.replicas {
            if let Some(t) = rep.next_event_bound() {
                m = Some(match m {
                    Some(x) => x.min(t),
                    None => t,
                });
            }
        }
        m
    }
}

/// Record the *observable* prefill signature of `seq` into an affinity
/// scorer: the proportional first-`chunk`-token share of every prefill row
/// cell (with `chunk = u32::MAX`, exactly the full prefill EAM — the
/// historical scorer input, bitwise-preserved). The truncated-cosine
/// distance is scale-invariant per row and [`EamcMatcher::nearest`]
/// normalizes by traced rows only, so a partial signature scores
/// meaningfully rather than degrading toward load-only dispatch. If the
/// chunk is so small that every proportional share rounds to zero (flat
/// routing over a tiny chunk), fall back to each layer's modal expert so
/// the scorer still sees a task signature.
fn record_prefill_signature(
    scorer: &mut EamcMatcher,
    index: &MatcherIndex,
    seq: &SequenceActivation,
    chunk: u32,
) {
    let prompt = seq.prompt_len as u32;
    if prompt == 0 {
        return; // nothing observable; the load term decides
    }
    let k = chunk.min(prompt);
    let mut any = false;
    for (l, row) in seq.routes[0].iter().enumerate() {
        for &(e, c) in row {
            let ck = prefill_chunk_tokens(c, 0, k, prompt);
            if ck > 0 {
                scorer.record(index, l, e as usize, ck);
                any = true;
            }
        }
    }
    if any {
        return;
    }
    for (l, row) in seq.routes[0].iter().enumerate() {
        // ties break to the later (higher-id) expert — deterministic
        if let Some(&(e, _)) = row.iter().max_by(|a, b| a.1.cmp(&b.1)) {
            scorer.record(index, l, e as usize, 1);
        }
    }
}

impl<'r> Scheduler<'r> for Router<'r> {
    fn submit(&mut self, req: &'r Request) {
        self.enqueue(req);
        self.presize_replicas();
    }

    /// One replica pre-sizing pass for the whole slice instead of one per
    /// request (`submit` would probe every replica buffer M×R times).
    fn submit_all(&mut self, reqs: &'r [Request]) {
        for req in reqs {
            self.enqueue(req);
        }
        self.presize_replicas();
    }

    /// One router event: dispatch the next due arrival, or advance the
    /// earliest-bounded replica by one scheduling quantum.
    fn tick(&mut self) -> bool {
        self.fire_due_crashes();
        if let Some(&req) = self.pending.front() {
            // safe to route once no busy replica can produce an earlier
            // event (idle replicas don't change state on their own)
            let due = self.frontier().map_or(true, |f| req.arrival <= f);
            if due {
                self.pending.pop_front();
                let k = self.pick_replica(req, req.arrival);
                self.replicas[k].submit(req);
                return true;
            }
        }
        // step the replica with the earliest next event
        let mut best: Option<(f64, usize)> = None;
        for (k, rep) in self.replicas.iter().enumerate() {
            if let Some(t) = rep.next_event_bound() {
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, k));
                }
            }
        }
        match best {
            Some((_, k)) => {
                let stepped = self.replicas[k].tick();
                debug_assert!(stepped, "a replica with work must make progress");
                true
            }
            None => false,
        }
    }

    fn drain(&mut self) -> ServeReport {
        while self.tick() {}
        let mut out = ServeReport::default();
        for rep in &mut self.replicas {
            let r = rep.drain();
            out.merge(&r);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheKind;
    use crate::engine::{ComputeModel, EngineConfig};
    use crate::memory::{Link, Tier, TierConfig};
    use crate::model::ModelSpec;
    use crate::trace::Eamc;
    use crate::util::Rng;
    use crate::workload::{ArrivalProcess, DatasetPreset, Workload};

    fn mk_engine(seed: u64, gpu: usize) -> (ModelSpec, SimEngine) {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), seed);
        let ds = w.gen_eam_dataset(40);
        let eamc = Eamc::construct(10, &ds, seed);
        let tier = TierConfig {
            gpu_capacity: gpu,
            dram_capacity: 200,
            backing: Tier::Ssd,
            ssd_to_dram: Link::new(6.0, 50e-6),
            dram_to_gpu: Link::new(32.0, 10e-6),
            n_gpus: 1,
            demand_extra_latency: 0.0,
            demand_bw_factor: 1.0,
            cache_kind: CacheKind::Activation,
            oracle_trace: Vec::new(),
            activation_terms: (true, true),
            prefetch_gpu_budget: 0.5,
        };
        let eng = SimEngine::new(
            spec.clone(),
            tier,
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        (spec, eng)
    }

    fn mk_requests(n: usize, rps: f64, seed: u64) -> Vec<Request> {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), seed ^ 0x77);
        let mut rng = Rng::new(seed ^ 0xabc);
        let proc = ArrivalProcess::Poisson { rps };
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                t += proc.next_gap(&mut rng);
                Request::new(i as u64, t, w.gen_sequence())
            })
            .collect()
    }

    #[test]
    fn routing_policy_names_roundtrip() {
        for p in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::TaskAffinity,
        ] {
            assert_eq!(RoutingPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(RoutingPolicy::by_name("random"), None);
        assert_eq!(RoutingPolicy::default(), RoutingPolicy::RoundRobin);
    }

    #[test]
    fn router_serves_everything_across_replicas() {
        for policy in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::TaskAffinity,
        ] {
            let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
            let reqs = mk_requests(16, 8.0, 3);
            let mut router = Router::new(engines, Batcher::new(4, 0.1), policy, AdmissionPolicy::Fifo);
            router.submit_all(&reqs);
            let report = router.drain();
            assert_eq!(report.requests, 16, "{policy:?} must serve every request");
            assert_eq!(report.request_latency.len(), 16);
            assert_eq!(report.ttft.len(), 16);
            assert!(report.makespan > 0.0);
            assert!(report.token_throughput() > 0.0);
            // work actually spread across replicas under round-robin
            if policy == RoutingPolicy::RoundRobin {
                for rep in router.replicas() {
                    assert_eq!(rep.load(), 0, "all dispatched work finished");
                    assert!(rep.engine().now() > 0.0);
                }
            }
        }
    }

    #[test]
    fn round_robin_splits_evenly() {
        let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
        let reqs = mk_requests(10, 4.0, 5);
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::RoundRobin,
            AdmissionPolicy::Fifo,
        );
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 10);
        let per_replica: Vec<usize> = router
            .replicas()
            .iter()
            .map(|r| r.request_stats().len())
            .collect();
        assert_eq!(per_replica, vec![5, 5], "round-robin splits evenly");
    }

    #[test]
    fn task_affinity_routes_same_task_to_its_replica() {
        // two replicas whose EAMCs cover *disjoint task ranges* of the same
        // workload (same seed => identical task profiles): every sequence
        // of a task must land on the replica whose collection knows it
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let preset = DatasetPreset::by_name("translation").unwrap();
        let mk_replica = |tasks: std::ops::Range<usize>| -> SimEngine {
            let w = Workload::new(&spec, preset.clone(), 9);
            let mut rng = Rng::new(0xD15C ^ tasks.start as u64);
            let ds: Vec<crate::trace::Eam> = tasks
                .flat_map(|t| {
                    (0..6)
                        .map(|_| {
                            w.gen_sequence_for_task_with(t, &mut rng)
                                .to_eam(spec.n_layers, spec.experts_per_layer)
                        })
                        .collect::<Vec<_>>()
                })
                .collect();
            let eamc = Eamc::construct(8, &ds, 4);
            let tier = TierConfig {
                gpu_capacity: 64,
                dram_capacity: 200,
                backing: Tier::Ssd,
                ssd_to_dram: Link::new(6.0, 50e-6),
                dram_to_gpu: Link::new(32.0, 10e-6),
                n_gpus: 1,
                demand_extra_latency: 0.0,
                demand_bw_factor: 1.0,
                cache_kind: CacheKind::Activation,
                oracle_trace: Vec::new(),
                activation_terms: (true, true),
                prefetch_gpu_budget: 0.5,
            };
            SimEngine::new(
                spec.clone(),
                tier,
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            )
        };
        let engines = vec![mk_replica(0..4), mk_replica(4..8)];
        let mut w = Workload::new(&spec, preset.clone(), 9);
        // sparse arrivals so load never influences the affinity score;
        // task 6 lives only in replica 1's collection
        let reqs: Vec<Request> = (0..5)
            .map(|i| Request::new(i as u64, i as f64 * 40.0, w.gen_sequence_for_task(6)))
            .collect();
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::TaskAffinity,
            AdmissionPolicy::Fifo,
        );
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 5);
        let counts: Vec<usize> = router
            .replicas()
            .iter()
            .map(|r| r.request_stats().len())
            .collect();
        assert_eq!(
            counts,
            vec![0, 5],
            "task-6 sequences must stick to the replica whose EAMC covers task 6"
        );
    }

    #[test]
    fn task_affinity_survives_first_chunk_only_signatures() {
        // chunked-prefill composition: with a chunk smaller than every
        // prompt, the affinity scorer only sees the first chunk's share of
        // the signature — task routing must still separate the tasks
        // instead of silently degrading to load-only dispatch
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let preset = DatasetPreset::by_name("translation").unwrap();
        let mk_replica = |tasks: std::ops::Range<usize>| -> SimEngine {
            let w = Workload::new(&spec, preset.clone(), 9);
            let mut rng = Rng::new(0xD15C ^ tasks.start as u64);
            let ds: Vec<crate::trace::Eam> = tasks
                .flat_map(|t| {
                    (0..6)
                        .map(|_| {
                            w.gen_sequence_for_task_with(t, &mut rng)
                                .to_eam(spec.n_layers, spec.experts_per_layer)
                        })
                        .collect::<Vec<_>>()
                })
                .collect();
            let eamc = Eamc::construct(8, &ds, 4);
            let tier = TierConfig {
                gpu_capacity: 64,
                dram_capacity: 200,
                backing: Tier::Ssd,
                ssd_to_dram: Link::new(6.0, 50e-6),
                dram_to_gpu: Link::new(32.0, 10e-6),
                n_gpus: 1,
                demand_extra_latency: 0.0,
                demand_bw_factor: 1.0,
                cache_kind: CacheKind::Activation,
                oracle_trace: Vec::new(),
                activation_terms: (true, true),
                prefetch_gpu_budget: 0.5,
            };
            SimEngine::new(
                spec.clone(),
                tier,
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            )
        };
        let engines = vec![mk_replica(0..4), mk_replica(4..8)];
        let mut w = Workload::new(&spec, preset.clone(), 9);
        let reqs: Vec<Request> = (0..5)
            .map(|i| Request::new(i as u64, i as f64 * 40.0, w.gen_sequence_for_task(6)))
            .collect();
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::TaskAffinity,
            AdmissionPolicy::Fifo,
        )
        .with_prefill_chunk(8); // below the preset's minimum prompt length
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 5);
        let counts: Vec<usize> = router
            .replicas()
            .iter()
            .map(|r| r.request_stats().len())
            .collect();
        assert_eq!(
            counts,
            vec![0, 5],
            "first-chunk signatures must still route task 6 to its replica"
        );
    }

    #[test]
    fn empty_fault_plan_router_replays_bitwise() {
        let run = |plan: Option<FaultPlan>| -> ServeReport {
            let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
            let reqs = mk_requests(16, 8.0, 3);
            let mut router = Router::new(
                engines,
                Batcher::new(4, 0.1),
                RoutingPolicy::RoundRobin,
                AdmissionPolicy::Fifo,
            );
            if let Some(p) = plan {
                router = router.with_fault_plan(&p);
            }
            router.submit_all(&reqs);
            router.drain()
        };
        let base = run(None);
        let empty = run(Some(FaultPlan::new(99)));
        assert_eq!(base.requests, empty.requests);
        assert_eq!(base.tokens, empty.tokens);
        assert_eq!(base.batches, empty.batches);
        assert_eq!(base.makespan.to_bits(), empty.makespan.to_bits());
        assert_eq!(base.demands, empty.demands);
        assert_eq!(base.gpu_hits, empty.gpu_hits);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(base.token_latency.samples()),
            bits(empty.token_latency.samples()),
            "an empty fault plan must not change the router replay"
        );
        assert_eq!(empty.transfer_retries, 0);
        assert_eq!(empty.demand_failures, 0);
    }

    #[test]
    fn replica_crash_fails_over_in_flight_work_to_the_survivor() {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), 0x77 ^ 3);
        // tight arrivals: both replicas are mid-flight when replica 0 dies
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request::new(i as u64, i as f64 * 0.01, w.gen_sequence()))
            .collect();
        let mut plan = FaultPlan::new(5);
        plan.crashes.push(CrashWindow {
            replica: 0,
            crash: 0.02,
            recover: f64::INFINITY, // never comes back
        });
        let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::RoundRobin,
            AdmissionPolicy::Fifo,
        )
        .with_fault_plan(&plan);
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 4, "every request survives the crash");
        assert_eq!(report.request_latency.len(), 4);
        // the survivor ended up owning everything replica 0 lost
        let survivor_stats = router.replicas()[1].request_stats();
        assert!(
            survivor_stats.len() >= 3,
            "failed-over work must re-dispatch to the survivor (got {})",
            survivor_stats.len()
        );
        assert!(
            survivor_stats.iter().any(|s| s.preemptions > 0),
            "at least one sequence must resume from warm captured state"
        );
        assert!(survivor_stats.iter().all(|s| s.finished));
    }

    #[test]
    fn recovered_replica_rejoins_the_dispatch_set() {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), 0x77 ^ 9);
        // burst one: both replicas busy when replica 0 dies; burst two
        // arrives long after recovery and must spread across both again
        let mut reqs: Vec<Request> = (0..4)
            .map(|i| Request::new(i as u64, i as f64 * 0.01, w.gen_sequence()))
            .collect();
        for i in 0..4 {
            reqs.push(Request::new(4 + i as u64, 1000.0 + i as f64 * 0.01, w.gen_sequence()));
        }
        let mut plan = FaultPlan::new(5);
        plan.crashes.push(CrashWindow {
            replica: 0,
            crash: 0.02,
            recover: 500.0,
        });
        let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::RoundRobin,
            AdmissionPolicy::Fifo,
        )
        .with_fault_plan(&plan);
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 8);
        // replica 0 received post-recovery dispatches (round-robin resumes
        // including it once the window has passed)
        let r0 = router.replicas()[0].request_stats();
        assert!(
            r0.iter().any(|s| s.arrival >= 1000.0),
            "a recovered replica must rejoin the dispatch set"
        );
    }

    #[test]
    fn degenerate_chunk_signature_falls_back_to_modal_experts() {
        // a 1-token chunk of a flat prompt rounds every proportional share
        // to zero; the scorer must fall back to modal experts, not record
        // nothing. Construct the degenerate row directly.
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("translation").unwrap(), 3);
        let seq = w.gen_sequence();
        // a prompt row spread so thin every cell share rounds to zero at
        // chunk 1: counts are < prompt for every expert whenever at least
        // two experts split the row — true for generated traces with
        // prompt >= 16 and noise > 0; assert rather than assume
        let spread = seq.routes[0]
            .iter()
            .any(|row| row.len() >= 2 && row.iter().all(|&(_, c)| c < seq.prompt_len as u32));
        assert!(spread, "trace must have a spread prefill row for this test");
        let ds = w.gen_eam_dataset(20);
        let eamc = Eamc::construct(6, &ds, 5);
        let mut scorer = EamcMatcher::new();
        scorer.attach(&eamc);
        record_prefill_signature(&mut scorer, eamc.index(), &seq, 1);
        assert!(
            scorer.traced_rows() > 0,
            "fallback must leave a usable signature in the scorer"
        );
    }
}
