//! Multi-replica request routing (the ROADMAP "multi-replica routing"
//! item, eMoE-style).
//!
//! A [`Router`] owns N engine replicas, each wrapped in its own
//! [`ContinuousScheduler`], and dispatches one arrival-ordered request
//! stream across them with a pluggable [`RoutingPolicy`]. The interesting
//! policy is **task affinity**: each replica's EAMC is scored against the
//! request's task signature (its prefill-iteration routing trace — the
//! simulator's stand-in for eMoE's task-level profiling) through the
//! incremental `trace::matcher` machinery, and the request lands on the
//! replica whose collection already represents its task best, lightly
//! penalized by load. Same-task sequences therefore pile onto the same
//! replica, which is exactly what preserves the activation locality the
//! expert cache and prefetcher exploit — the per-replica EAMCs then keep
//! specializing through the §4.3 online feedback loop.
//!
//! ## The event calendar
//!
//! Each replica is an independent virtual timeline. Historically the
//! router interleaved them with a lockstep polling loop: every tick
//! re-scanned all N [`ContinuousScheduler::next_event_bound`]s (twice —
//! once for the arrival-dispatch gate, once to pick the replica to step),
//! re-checked every crash window, and advanced exactly one scheduling
//! quantum, so simulated cluster time cost O(N · events) host time.
//!
//! [`Router::tick`] now runs a discrete-event calendar instead:
//!
//! * **Memoized bounds in a min-heap.** The calendar is a binary heap of
//!   `(next_event_time, replica_idx)` entries, earliest on top, ties to
//!   the lowest index — exactly the scan's `t < bt` pick order. Bounds
//!   are *stable between mutations* of their scheduler (the contract on
//!   [`ContinuousScheduler::next_event_bound`]), so they are re-read only
//!   when the router itself mutates a replica: dispatch, stepping, or
//!   crash failover. Invalidations are per-replica versioned and lazy —
//!   stale entries are discarded when they surface at the top, O(log N)
//!   per event instead of O(N) per tick.
//! * **Arrivals and crash edges merged into the calendar.** The pending
//!   front is compared against the heap top (not a fresh fleet scan), and
//!   `fire_due_crashes` runs only when a `crash_pending` flag says some
//!   window may actually fire — set when a plan is installed, when a
//!   dispatch or failover hop can move a replica clock, and when a
//!   batched replica crosses its own earliest unfired crash edge.
//! * **Run-to-frontier batching.** The popped replica executes
//!   consecutive internal quanta until its bound crosses the frontier
//!   frozen at pop time (second-earliest calendar entry, pending-arrival
//!   front, earliest unfired crash edge). Only that replica's state can
//!   change while it runs, so the frozen frontier is exact and heap
//!   traffic collapses from O(per quantum) to O(per frontier crossing).
//!
//! The calendar replays the lockstep loop **bitwise** — same dispatch
//! instants, same replica pick at every tie, same crash-firing
//! boundaries — under every scheduler kind and fault plan; the retired
//! loop is kept verbatim as [`Router::tick_lockstep`] and pinned against
//! the calendar in `rust/tests/scheduler.rs` and the `perf_events`
//! bench. The replay is a pure function of the request stream and the
//! replica set. With **one replica and round-robin** the dispatch gate
//! provably never changes admission instants, so the replay is bitwise
//! identical to a bare [`ContinuousScheduler`] (also pinned in
//! `rust/tests/scheduler.rs`).

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::engine::{prefill_chunk_tokens, SimEngine};
use crate::faults::{CrashWindow, FaultPlan};
use crate::server::{
    expected_iterations, AdmissionPolicy, Batcher, ContinuousScheduler, Scheduler, ServeReport,
};
use crate::trace::{EamcMatcher, MatcherIndex};
use crate::util::units::SimTime;
use crate::workload::{Request, SequenceActivation};

/// Per-replica fault-stream seed stride: replica `k` draws its link faults
/// from `plan.seed + k * 0x5EED`, so replicas fail independently yet the
/// whole timeline stays a pure function of the plan seed.
const REPLICA_FAULT_SEED_STRIDE: u64 = 0x5EED;

/// How the router picks a replica for each arriving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Cycle through replicas in submission order.
    #[default]
    RoundRobin,
    /// Fewest dispatched-but-unfinished requests (ties to lowest index).
    LeastLoaded,
    /// Minimal `EAMC distance + load penalty`: the request goes to the
    /// replica whose expert-activation collection best matches its prefill
    /// routing signature (ties to lowest index).
    TaskAffinity,
}

impl RoutingPolicy {
    pub fn by_name(s: &str) -> Option<RoutingPolicy> {
        match s {
            "round-robin" => Some(RoutingPolicy::RoundRobin),
            "least-loaded" => Some(RoutingPolicy::LeastLoaded),
            "task-affinity" => Some(RoutingPolicy::TaskAffinity),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::TaskAffinity => "task-affinity",
        }
    }
}

/// Weight of the occupancy term in the task-affinity score: distance is in
/// `[0, 1]`-ish Eq. 1 units, load is normalized by `max_batch`, so 0.25
/// breaks affinity ties toward idle replicas without overriding a clear
/// task match.
const AFFINITY_LOAD_WEIGHT: f64 = 0.25;

/// One memoized replica bound in the event calendar. The ordering is
/// inverted (earliest `(time, idx)` at the heap top) with time ties broken
/// toward the **lowest** replica index — exactly the retired lockstep
/// scan's strict `t < bt` pick order, so popping the calendar replays the
/// scan's choice bitwise. `version` is *not* part of the ordering: an
/// entry whose version no longer matches its replica's current version is
/// stale and is discarded lazily when it surfaces at the top.
#[derive(Debug, Clone, Copy)]
struct CalEntry {
    time: SimTime,
    idx: u32,
    version: u64,
}

impl Ord for CalEntry {
    fn cmp(&self, other: &CalEntry) -> Ordering {
        // Reversed operands: BinaryHeap is a max-heap and we want the
        // earliest entry on top. total_cmp is a total order over the
        // bounds (never NaN); -0.0 is normalized to +0.0 before pushing
        // so total_cmp's -0.0 < +0.0 distinction cannot reorder a tie the
        // scan's `<` would have left to the index.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

impl PartialOrd for CalEntry {
    fn partial_cmp(&self, other: &CalEntry) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for CalEntry {
    fn eq(&self, other: &CalEntry) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for CalEntry {}

/// A task-affinity multi-replica request router. See the module docs.
pub struct Router<'r> {
    replicas: Vec<ContinuousScheduler<'r>>,
    policy: RoutingPolicy,
    max_batch: usize,
    /// Per-iteration prefill token budget applied to every replica
    /// (`u32::MAX` = plain continuous). Affinity scoring uses the same
    /// value: under chunked prefill only the first chunk of a prompt has
    /// routed by dispatch time, so the scorer sees that chunk's share of
    /// the signature instead of the full (not-yet-observable) prefill EAM.
    prefill_chunk: u32,
    rr_next: usize,
    /// Submitted, not yet dispatched (arrival order).
    pending: VecDeque<&'r Request>,
    /// Per-replica matcher scratch for affinity scoring (reused; scoring a
    /// request is allocation-free once warmed).
    scorers: Vec<EamcMatcher>,
    total_requests: usize,
    total_tokens: usize,
    /// Replica crash/recover windows from the fault plan (empty = the
    /// historical immortal-replica replay, bitwise-preserved: every fault
    /// hook below early-outs on `is_empty`).
    fault_windows: Vec<CrashWindow>,
    /// Whether each window's crash has fired (captured + re-dispatched).
    fired: Vec<bool>,
    /// The event calendar: memoized `next_event_bound`s, earliest on top.
    calendar: BinaryHeap<CalEntry>,
    /// Monotonic per-replica entry version; [`Router::refresh`] bumps it,
    /// so every calendar entry but a replica's newest is stale.
    versions: Vec<u64>,
    /// `total_requests` watermark at each replica's last `reserve_for`
    /// (presize-by-delta: dispatch re-sizes one replica only when new
    /// submissions arrived since its last re-size, so M incremental
    /// submits cost O(M) amortized rather than O(M·N) fleet probes).
    presized: Vec<usize>,
    /// Some unfired crash window may be fireable. Clear implies
    /// `fire_due_crashes` would be a read-only no-op — replica clocks only
    /// move inside replica `tick`/`submit`/failover hops, all of which
    /// re-set this — so the calendar path skips the scan entirely.
    crash_pending: bool,
    /// Memoized bounds may be stale (a lockstep tick stepped replicas
    /// behind the calendar's back); rebuilt on the next calendar tick so
    /// the two loops can be interleaved safely.
    calendar_stale: bool,
}

impl<'r> Router<'r> {
    /// Wrap `engines` (one per replica) in per-replica continuous
    /// schedulers sharing one batching/admission policy.
    pub fn new(
        engines: Vec<SimEngine>,
        batcher: Batcher,
        policy: RoutingPolicy,
        admission: AdmissionPolicy,
    ) -> Router<'r> {
        assert!(!engines.is_empty(), "router needs at least one replica");
        let n = engines.len();
        Router {
            replicas: engines
                .into_iter()
                .map(|e| ContinuousScheduler::new(e, batcher, admission))
                .collect(),
            policy,
            max_batch: batcher.max_batch,
            prefill_chunk: u32::MAX,
            rr_next: 0,
            pending: VecDeque::new(),
            scorers: (0..n).map(|_| EamcMatcher::new()).collect(),
            total_requests: 0,
            total_tokens: 0,
            fault_windows: Vec::new(),
            fired: Vec::new(),
            calendar: BinaryHeap::new(),
            versions: vec![0; n],
            presized: vec![0; n],
            crash_pending: false,
            calendar_stale: false,
        }
    }

    /// Install a fault plan across the replica set: the link-fault portion
    /// (failure probabilities, retry policy, brownouts) lands on every
    /// replica's engine under a per-replica derived seed
    /// ([`REPLICA_FAULT_SEED_STRIDE`]), and the crash/recover windows are
    /// kept by the router itself — a window fires at the first iteration
    /// boundary its replica's clock reaches, capturing in-flight sequences
    /// as warm [`crate::engine::PreemptedSeq`]s and re-dispatching them
    /// (and all waiting work) to survivors; the replica rejoins the
    /// dispatch set once its recover instant passes. An empty plan leaves
    /// the replay bitwise untouched.
    pub fn with_fault_plan(mut self, plan: &FaultPlan) -> Router<'r> {
        if plan.affects_links() {
            for (k, rep) in self.replicas.iter_mut().enumerate() {
                let mut p = plan.clone();
                p.seed = plan.seed.wrapping_add(k as u64 * REPLICA_FAULT_SEED_STRIDE);
                p.crashes.clear();
                rep.engine_mut().set_fault_plan(&p);
            }
        }
        self.fault_windows = plan.crashes.clone();
        self.fired = vec![false; self.fault_windows.len()];
        self.crash_pending = !self.fault_windows.is_empty();
        self
    }

    /// Enable SLO deadline shedding on every replica (see
    /// [`ContinuousScheduler::set_shedding`]).
    pub fn set_shedding(&mut self, on: bool) {
        for rep in &mut self.replicas {
            rep.set_shedding(on);
        }
    }

    /// Run every replica under chunked prefill with this per-iteration
    /// token budget (>= 1; `u32::MAX` = unlimited — the plain continuous
    /// router, bitwise-preserved). Task-affinity scoring switches to the
    /// first-chunk share of the prompt signature accordingly.
    pub fn with_prefill_chunk(mut self, chunk: u32) -> Router<'r> {
        assert!(chunk >= 1, "prefill_chunk must be >= 1 (u32::MAX = unlimited)");
        self.prefill_chunk = chunk;
        for rep in &mut self.replicas {
            rep.set_prefill_chunk(chunk);
        }
        self
    }

    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Read access to the per-replica schedulers (post-run stats).
    pub fn replicas(&self) -> &[ContinuousScheduler<'r>] {
        &self.replicas
    }

    /// Does window `w` make replica `k` undispatchable at instant `t`?
    /// Down at the dispatch instant itself, or — while the replica is
    /// still busy, so its clock is live — down at its current boundary (a
    /// fired crash whose recover instant the clock hasn't reached). An
    /// idle replica's frozen clock is deliberately ignored: a new submit
    /// idle-hops it to the arrival instant, past the window.
    fn window_blocks(&self, w: &CrashWindow, k: usize, t: f64) -> bool {
        w.replica == k
            && (w.down_at(SimTime::from_f64(t))
                || (self.replicas[k].has_work()
                    && w.down_at(SimTime::from_f64(self.replicas[k].now()))))
    }

    /// Is replica `k` inside any crash window at dispatch instant `t`?
    /// O(0) with no fault plan.
    fn replica_down(&self, k: usize, t: f64) -> bool {
        self.fault_windows
            .iter()
            .any(|w| self.window_blocks(w, k, t))
    }

    /// Pick the replica for `req` (dispatched at instant `t`) under the
    /// configured policy, skipping crashed replicas. With no fault plan
    /// the down-filter is free and the historical pick is bitwise
    /// unchanged.
    fn pick_replica(&mut self, req: &Request, t: f64) -> usize {
        let n = self.replicas.len();
        if !self.fault_windows.is_empty() && (0..n).all(|k| self.replica_down(k, t)) {
            // total blackout: park the request on the replica that
            // recovers soonest — it waits in that backlog instead of
            // deadlocking the dispatch gate
            let mut best = 0;
            let mut best_rec = SimTime::INFINITY;
            for k in 0..n {
                let mut rec = SimTime::ZERO;
                for wi in 0..self.fault_windows.len() {
                    let w = self.fault_windows[wi].clone();
                    if self.window_blocks(&w, k, t) {
                        rec = rec.max(w.recover);
                    }
                }
                if rec < best_rec {
                    best_rec = rec;
                    best = k;
                }
            }
            return best;
        }
        match self.policy {
            RoutingPolicy::RoundRobin => loop {
                let k = self.rr_next % n;
                self.rr_next += 1;
                if !self.replica_down(k, t) {
                    return k;
                }
            },
            RoutingPolicy::LeastLoaded => {
                let mut best = usize::MAX;
                for k in 0..n {
                    if self.replica_down(k, t) {
                        continue;
                    }
                    if best == usize::MAX || self.replicas[k].load() < self.replicas[best].load() {
                        best = k;
                    }
                }
                best
            }
            RoutingPolicy::TaskAffinity => {
                let mut best = usize::MAX;
                let mut best_score = f64::INFINITY;
                for k in 0..n {
                    if self.replica_down(k, t) {
                        continue;
                    }
                    let eamc = self.replicas[k].engine().eamc();
                    let scorer = &mut self.scorers[k];
                    scorer.attach(eamc);
                    let index = eamc.index();
                    // task signature = the prefill routing the dispatcher
                    // can actually observe: the whole prompt normally, the
                    // first chunk's share under chunked prefill
                    record_prefill_signature(scorer, index, &req.seq, self.prefill_chunk);
                    // an empty EAMC (non-activation-aware bundles) scores
                    // neutrally; the load term then decides
                    let dist = scorer.nearest().map_or(0.0, |(_, d)| d);
                    let load = self.replicas[k].load() as f64 / self.max_batch as f64;
                    let score = dist + AFFINITY_LOAD_WEIGHT * load;
                    if best == usize::MAX || score < best_score {
                        best_score = score;
                        best = k;
                    }
                }
                best
            }
        }
    }

    /// Fire every crash window whose replica's clock has reached its crash
    /// instant ([`CrashWindow::fires_by`]): the replica's unfinished work
    /// — in-flight sequences as warm [`crate::engine::PreemptedSeq`]
    /// state, waiting/undispatched requests bare — is captured via
    /// [`ContinuousScheduler::fail_over`] and immediately re-dispatched to
    /// the surviving replicas under the routing policy (warm failover:
    /// `admit_resumed` on the survivor continues each sequence with
    /// identical per-token expert demands). A replica that idles past its
    /// whole window never fires it — there was nothing to lose — and the
    /// window degrades to pure dispatch filtering.
    ///
    /// The failover hops re-memoize both ends in the calendar, and firing
    /// anything re-arms `crash_pending`: a survivor's clock may have
    /// idle-hopped into *its own* window, which the single index-ordered
    /// pass (the lockstep contract) only catches on the next tick.
    fn fire_due_crashes(&mut self) {
        if self.fault_windows.is_empty() {
            return;
        }
        for wi in 0..self.fault_windows.len() {
            if self.fired[wi] {
                continue;
            }
            let w = self.fault_windows[wi].clone();
            if !w.fires_by(SimTime::from_f64(self.replicas[w.replica].now())) {
                continue;
            }
            self.fired[wi] = true;
            self.crash_pending = true;
            let handoff_t = self.replicas[w.replica].now();
            let mut captured = Vec::new();
            self.replicas[w.replica].fail_over(&mut captured);
            self.refresh(w.replica);
            for (req, saved) in captured {
                let dst = self.pick_replica(req, handoff_t);
                self.replicas[dst].submit_failover(req, saved, handoff_t);
                self.refresh(dst);
            }
        }
    }

    /// Queue one request (arrival order asserted) without re-sizing
    /// replica buffers; dispatch brings the receiving replica up to the
    /// watermark via [`Router::ensure_presized`], and bulk submission
    /// pre-sizes the whole fleet once via [`Router::presize_replicas`].
    fn enqueue(&mut self, req: &'r Request) {
        debug_assert!(
            self.pending.back().map_or(true, |p| p.arrival <= req.arrival),
            "requests must be submitted in arrival order"
        );
        self.total_requests += 1;
        // executed-iteration budget for replica pre-sizing (shared-budget
        // leftovers can split prompts past ceil(prompt/chunk) — see
        // `server::expected_iterations`)
        self.total_tokens += expected_iterations(&req.seq, self.prefill_chunk);
        self.pending.push_back(req);
    }

    /// Any replica may end up with the whole stream; pre-sizing after bulk
    /// submission keeps dispatch-time replica pushes *and* calendar pushes
    /// allocation-free mid-replay (pinned in `tests/alloc_guard.rs`).
    fn presize_replicas(&mut self) {
        for (k, rep) in self.replicas.iter_mut().enumerate() {
            rep.reserve_for(self.total_requests, self.total_tokens);
            self.presized[k] = self.total_requests;
        }
        // Calendar high-water mark: at most one live entry per replica,
        // plus one not-yet-collected stale entry per dispatch and per
        // failover refresh between garbage-collecting pops.
        let want = 2 * self.total_requests + self.replicas.len() + self.fault_windows.len() + 8;
        if want > self.calendar.len() {
            self.calendar.reserve(want - self.calendar.len());
        }
    }

    /// Bring replica `k`'s buffers up to the current submission watermark
    /// (no-op unless new requests were enqueued since its last re-size).
    fn ensure_presized(&mut self, k: usize) {
        if self.presized[k] != self.total_requests {
            self.replicas[k].reserve_for(self.total_requests, self.total_tokens);
            self.presized[k] = self.total_requests;
        }
    }

    /// Re-memoize replica `k`'s bound: bump its version (invalidating
    /// every calendar entry it already has) and push the current bound, if
    /// any. Called exactly where the bound-stability contract says the
    /// bound can change: after dispatching to `k`, after stepping `k`, and
    /// after a crash capture / failover hop touching `k`.
    fn refresh(&mut self, k: usize) {
        self.versions[k] = self.versions[k].wrapping_add(1);
        if let Some(t) = self.replicas[k].next_event_bound() {
            self.calendar.push(CalEntry {
                // `+ 0.0` maps a (theoretical) -0.0 bound to +0.0 so the
                // heap's total_cmp agrees with the scan's `<` on ties
                time: SimTime::from_f64(t + 0.0),
                idx: k as u32,
                version: self.versions[k],
            });
        }
    }

    /// Earliest live calendar entry, lazily discarding stale entries from
    /// the top. A live entry's time *is* its replica's current
    /// `next_event_bound` (the bound-stability contract).
    fn calendar_min(&mut self) -> Option<(f64, usize)> {
        while let Some(e) = self.calendar.peek() {
            if self.versions[e.idx as usize] == e.version {
                return Some((e.time.to_f64(), e.idx as usize));
            }
            self.calendar.pop();
        }
        None
    }

    /// Drop every memoized bound and re-push the live ones. Needed only
    /// after [`Router::tick_lockstep`] stepped replicas behind the
    /// calendar's back.
    fn rebuild_calendar(&mut self) {
        self.calendar.clear();
        for k in 0..self.replicas.len() {
            self.refresh(k);
        }
        self.calendar_stale = false;
    }

    /// Earliest unfired crash instant among replica `k`'s windows (∞ if
    /// none): the run-to-frontier batch must stop the moment `k`'s clock
    /// crosses it, so the window fires at exactly the iteration boundary
    /// the lockstep loop fired it at. Only `k`'s clock moves during a
    /// batch, so only `k`'s windows can newly fire.
    fn next_unfired_crash(&self, k: usize) -> SimTime {
        let mut m = SimTime::INFINITY;
        for (wi, w) in self.fault_windows.iter().enumerate() {
            if !self.fired[wi] && w.replica == k && w.crash < m {
                m = w.crash;
            }
        }
        m
    }

    /// Earliest next-event bound across replicas that still have work (the
    /// retired loop's O(N) dispatch gate; the calendar path reads the heap
    /// top instead).
    fn frontier(&self) -> Option<f64> {
        let mut m: Option<f64> = None;
        for rep in &self.replicas {
            if let Some(t) = rep.next_event_bound() {
                m = Some(match m {
                    Some(x) => x.min(t),
                    None => t,
                });
            }
        }
        m
    }

    /// The retired O(N)-scan lockstep event loop, kept verbatim as the
    /// bitwise reference for the calendar: one call fires due crashes,
    /// then either dispatches the next due arrival or advances the
    /// earliest-bounded replica by **one** scheduling quantum. The
    /// differential suites (`rust/tests/scheduler.rs`, `perf_events`) pin
    /// [`Router::tick`] against this loop; don't optimize it.
    ///
    /// Interleaving with calendar ticks is safe: stepping replicas here
    /// invalidates the memoized bounds, so the flags below force a
    /// calendar rebuild and a crash re-check on the next calendar tick.
    pub fn tick_lockstep(&mut self) -> bool {
        self.calendar_stale = true;
        self.crash_pending = true;
        self.fire_due_crashes();
        if let Some(&req) = self.pending.front() {
            // safe to route once no busy replica can produce an earlier
            // event (idle replicas don't change state on their own)
            let due = self.frontier().map_or(true, |f| req.arrival <= f);
            if due {
                self.pending.pop_front();
                let k = self.pick_replica(req, req.arrival);
                self.ensure_presized(k);
                self.replicas[k].submit(req); // moelint: allow(refresh-contract, lockstep reference keeps no memoized bounds — calendar_stale forces a wholesale rebuild)
                return true;
            }
        }
        // step the replica with the earliest next event
        let mut best: Option<(f64, usize)> = None;
        for (k, rep) in self.replicas.iter().enumerate() {
            if let Some(t) = rep.next_event_bound() {
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, k));
                }
            }
        }
        match best {
            Some((t, k)) => {
                let stepped = self.replicas[k].tick(); // moelint: allow(refresh-contract, lockstep reference keeps no memoized bounds — calendar_stale forces a wholesale rebuild)
                // a hard error in every profile: a bound with no progress
                // would spin `drain` forever in release builds
                assert!(
                    stepped,
                    "replica {k} reported next_event_bound = {t} but tick() made no \
                     progress; the bound/step contract is broken"
                );
                true
            }
            None => false,
        }
    }

    /// Drain through [`Router::tick_lockstep`] (the reference loop); same
    /// merged report shape as [`Scheduler::drain`].
    pub fn drain_lockstep(&mut self) -> ServeReport {
        while self.tick_lockstep() {}
        let mut out = ServeReport::default();
        for rep in &mut self.replicas {
            let r = rep.drain();
            out.merge(&r);
        }
        out
    }
}

/// Record the *observable* prefill signature of `seq` into an affinity
/// scorer: the proportional first-`chunk`-token share of every prefill row
/// cell (with `chunk = u32::MAX`, exactly the full prefill EAM — the
/// historical scorer input, bitwise-preserved). The truncated-cosine
/// distance is scale-invariant per row and [`EamcMatcher::nearest`]
/// normalizes by traced rows only, so a partial signature scores
/// meaningfully rather than degrading toward load-only dispatch. If the
/// chunk is so small that every proportional share rounds to zero (flat
/// routing over a tiny chunk), fall back to each layer's modal expert so
/// the scorer still sees a task signature.
fn record_prefill_signature(
    scorer: &mut EamcMatcher,
    index: &MatcherIndex,
    seq: &SequenceActivation,
    chunk: u32,
) {
    let prompt = seq.prompt_len as u32;
    if prompt == 0 {
        return; // nothing observable; the load term decides
    }
    let k = chunk.min(prompt);
    let mut any = false;
    for (l, row) in seq.routes[0].iter().enumerate() {
        for &(e, c) in row {
            let ck = prefill_chunk_tokens(c, 0, k, prompt);
            if ck > 0 {
                scorer.record(index, l, e as usize, ck);
                any = true;
            }
        }
    }
    if any {
        return;
    }
    for (l, row) in seq.routes[0].iter().enumerate() {
        // ties break to the later (higher-id) expert — deterministic
        if let Some(&(e, _)) = row.iter().max_by(|a, b| a.1.cmp(&b.1)) {
            scorer.record(index, l, e as usize, 1);
        }
    }
}

impl<'r> Scheduler<'r> for Router<'r> {
    /// Queue one request. Replica buffer pre-sizing is deferred to
    /// dispatch time ([`Router::ensure_presized`]), so M incremental
    /// submits cost O(M) total instead of the former O(M·N) fleet probe
    /// per call. Bulk callers should still prefer
    /// [`Scheduler::submit_all`], which pre-sizes the whole fleet once up
    /// front and thereby keeps warmed replays allocation-free.
    fn submit(&mut self, req: &'r Request) {
        self.enqueue(req);
    }

    /// One fleet pre-sizing pass for the whole slice instead of per-submit
    /// (and the calendar heap reserved to its high-water mark).
    fn submit_all(&mut self, reqs: &'r [Request]) {
        for req in reqs {
            self.enqueue(req);
        }
        self.presize_replicas();
    }

    /// One calendar event: dispatch the next due arrival, or pop the
    /// earliest-bounded replica and run it to the frontier (see the module
    /// docs). Bitwise-equivalent to [`Router::tick_lockstep`] iterated
    /// over the same span.
    // moelint: hot
    fn tick(&mut self) -> bool {
        if self.calendar_stale {
            self.rebuild_calendar();
        }
        if self.crash_pending {
            self.crash_pending = false;
            self.fire_due_crashes(); // may re-arm the flag
        }
        let front = self.calendar_min();
        if let Some(&req) = self.pending.front() {
            // safe to route once no busy replica can produce an earlier
            // event (idle replicas don't change state on their own)
            let due = front.map_or(true, |(f, _)| req.arrival <= f);
            if due {
                self.pending.pop_front();
                let k = self.pick_replica(req, req.arrival);
                self.ensure_presized(k);
                self.replicas[k].submit(req);
                self.refresh(k);
                if !self.fault_windows.is_empty() {
                    // the submit may idle-hop k's clock to the arrival
                    // instant, possibly across a crash edge; lockstep's
                    // unconditional per-tick pass would catch that next
                    // tick — re-arm so the calendar does too
                    self.crash_pending = true;
                }
                return true;
            }
        }
        let Some((mut bound, k)) = front else {
            return false; // no due arrivals, no bounded replicas: drained
        };
        // Run-to-frontier: k's live entry comes off the heap and k
        // executes consecutive quanta while the lockstep scan would keep
        // picking it. The frontier is frozen for the whole batch — only
        // k's state changes while it runs — so the second-earliest
        // calendar entry, the pending front, and k's earliest unfired
        // crash edge are the only events that can preempt it.
        self.calendar.pop();
        let other = self.calendar_min();
        let next_arrival = self.pending.front().map(|r| r.arrival);
        let next_crash = self.next_unfired_crash(k);
        loop {
            let stepped = self.replicas[k].tick();
            // a hard error in every profile: a bound with no progress
            // would spin `drain` forever in release builds
            assert!(
                stepped,
                "replica {k} reported next_event_bound = {bound} but tick() made no \
                 progress; the bound/step contract is broken"
            );
            if self.replicas[k].now() >= next_crash {
                // k crossed its own crash edge: the window fires before k
                // runs anything else, exactly where lockstep fired it (at
                // the head of the next tick)
                self.crash_pending = true;
                break;
            }
            match self.replicas[k].next_event_bound() {
                None => break, // k ran out of work
                Some(t) => bound = t,
            }
            // continue only while the lockstep scan would still pick k:
            // earliest bound (ties to the lowest index) with no pending
            // arrival due at or before it
            let k_first = match other {
                Some((to, j)) => bound < to || (bound == to && k < j),
                None => true,
            };
            if !k_first || next_arrival.map_or(false, |a| a <= bound) {
                break;
            }
        }
        self.refresh(k);
        true
    }

    fn drain(&mut self) -> ServeReport {
        while self.tick() {}
        let mut out = ServeReport::default();
        for rep in &mut self.replicas {
            let r = rep.drain();
            out.merge(&r);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheKind;
    use crate::engine::{ComputeModel, EngineConfig};
    use crate::memory::{Link, Tier, TierConfig};
    use crate::model::ModelSpec;
    use crate::trace::Eamc;
    use crate::util::Rng;
    use crate::workload::{ArrivalProcess, DatasetPreset, Workload};

    fn mk_engine(seed: u64, gpu: usize) -> (ModelSpec, SimEngine) {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), seed);
        let ds = w.gen_eam_dataset(40);
        let eamc = Eamc::construct(10, &ds, seed);
        let tier = TierConfig {
            gpu_capacity: gpu,
            dram_capacity: 200,
            backing: Tier::Ssd,
            ssd_to_dram: Link::new(6.0, 50e-6),
            dram_to_gpu: Link::new(32.0, 10e-6),
            n_gpus: 1,
            demand_extra_latency: SimTime::ZERO,
            demand_bw_factor: 1.0,
            gpu_policy: CacheKind::Activation,
            dram_policy: CacheKind::Activation,
            oracle_trace: Vec::new(),
            activation_terms: (true, true),
            prefetch_gpu_budget: 0.5,
        };
        let eng = SimEngine::new(
            spec.clone(),
            tier,
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        );
        (spec, eng)
    }

    fn mk_requests(n: usize, rps: f64, seed: u64) -> Vec<Request> {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), seed ^ 0x77);
        let mut rng = Rng::new(seed ^ 0xabc);
        let proc = ArrivalProcess::Poisson { rps };
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                t += proc.next_gap(&mut rng);
                Request::new(i as u64, t, w.gen_sequence())
            })
            .collect()
    }

    #[test]
    fn routing_policy_names_roundtrip() {
        for p in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::TaskAffinity,
        ] {
            assert_eq!(RoutingPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(RoutingPolicy::by_name("random"), None);
        assert_eq!(RoutingPolicy::default(), RoutingPolicy::RoundRobin);
    }

    #[test]
    fn calendar_entry_order_matches_the_lockstep_scan() {
        // earliest time wins; time ties break to the LOWEST index (the
        // scan's strict `t < bt` keeps the first minimum it saw)
        let mut h = BinaryHeap::new();
        for (t, i) in [(0.5, 3u32), (0.25, 2), (0.25, 1), (1.0, 0)] {
            h.push(CalEntry { time: SimTime::from_f64(t), idx: i, version: 0 });
        }
        let order: Vec<(f64, u32)> =
            std::iter::from_fn(|| h.pop().map(|e| (e.time.to_f64(), e.idx))).collect();
        assert_eq!(order, vec![(0.25, 1), (0.25, 2), (0.5, 3), (1.0, 0)]);
        // -0.0 normalization: `t + 0.0` folds the signed zero away so
        // total_cmp can't order it before a +0.0 tie partner
        assert_eq!((-0.0f64 + 0.0).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn router_serves_everything_across_replicas() {
        for policy in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::TaskAffinity,
        ] {
            let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
            let reqs = mk_requests(16, 8.0, 3);
            let mut router = Router::new(engines, Batcher::new(4, 0.1), policy, AdmissionPolicy::Fifo);
            router.submit_all(&reqs);
            let report = router.drain();
            assert_eq!(report.requests, 16, "{policy:?} must serve every request");
            assert_eq!(report.request_latency.len(), 16);
            assert_eq!(report.ttft.len(), 16);
            assert!(report.makespan > 0.0);
            assert!(report.token_throughput() > 0.0);
            // work actually spread across replicas under round-robin
            if policy == RoutingPolicy::RoundRobin {
                for rep in router.replicas() {
                    assert_eq!(rep.load(), 0, "all dispatched work finished");
                    assert!(rep.engine().now() > 0.0);
                }
            }
        }
    }

    #[test]
    fn round_robin_splits_evenly() {
        let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
        let reqs = mk_requests(10, 4.0, 5);
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::RoundRobin,
            AdmissionPolicy::Fifo,
        );
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 10);
        let per_replica: Vec<usize> = router
            .replicas()
            .iter()
            .map(|r| r.request_stats().len())
            .collect();
        assert_eq!(per_replica, vec![5, 5], "round-robin splits evenly");
    }

    #[test]
    fn task_affinity_routes_same_task_to_its_replica() {
        // two replicas whose EAMCs cover *disjoint task ranges* of the same
        // workload (same seed => identical task profiles): every sequence
        // of a task must land on the replica whose collection knows it
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let preset = DatasetPreset::by_name("translation").unwrap();
        let mk_replica = |tasks: std::ops::Range<usize>| -> SimEngine {
            let w = Workload::new(&spec, preset.clone(), 9);
            let mut rng = Rng::new(0xD15C ^ tasks.start as u64);
            let ds: Vec<crate::trace::Eam> = tasks
                .flat_map(|t| {
                    (0..6)
                        .map(|_| {
                            w.gen_sequence_for_task_with(t, &mut rng)
                                .to_eam(spec.n_layers, spec.experts_per_layer)
                        })
                        .collect::<Vec<_>>()
                })
                .collect();
            let eamc = Eamc::construct(8, &ds, 4);
            let tier = TierConfig {
                gpu_capacity: 64,
                dram_capacity: 200,
                backing: Tier::Ssd,
                ssd_to_dram: Link::new(6.0, 50e-6),
                dram_to_gpu: Link::new(32.0, 10e-6),
                n_gpus: 1,
                demand_extra_latency: SimTime::ZERO,
                demand_bw_factor: 1.0,
                gpu_policy: CacheKind::Activation,
                dram_policy: CacheKind::Activation,
                oracle_trace: Vec::new(),
                activation_terms: (true, true),
                prefetch_gpu_budget: 0.5,
            };
            SimEngine::new(
                spec.clone(),
                tier,
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            )
        };
        let engines = vec![mk_replica(0..4), mk_replica(4..8)];
        let mut w = Workload::new(&spec, preset.clone(), 9);
        // sparse arrivals so load never influences the affinity score;
        // task 6 lives only in replica 1's collection
        let reqs: Vec<Request> = (0..5)
            .map(|i| Request::new(i as u64, i as f64 * 40.0, w.gen_sequence_for_task(6)))
            .collect();
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::TaskAffinity,
            AdmissionPolicy::Fifo,
        );
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 5);
        let counts: Vec<usize> = router
            .replicas()
            .iter()
            .map(|r| r.request_stats().len())
            .collect();
        assert_eq!(
            counts,
            vec![0, 5],
            "task-6 sequences must stick to the replica whose EAMC covers task 6"
        );
    }

    #[test]
    fn task_affinity_survives_first_chunk_only_signatures() {
        // chunked-prefill composition: with a chunk smaller than every
        // prompt, the affinity scorer only sees the first chunk's share of
        // the signature — task routing must still separate the tasks
        // instead of silently degrading to load-only dispatch
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let preset = DatasetPreset::by_name("translation").unwrap();
        let mk_replica = |tasks: std::ops::Range<usize>| -> SimEngine {
            let w = Workload::new(&spec, preset.clone(), 9);
            let mut rng = Rng::new(0xD15C ^ tasks.start as u64);
            let ds: Vec<crate::trace::Eam> = tasks
                .flat_map(|t| {
                    (0..6)
                        .map(|_| {
                            w.gen_sequence_for_task_with(t, &mut rng)
                                .to_eam(spec.n_layers, spec.experts_per_layer)
                        })
                        .collect::<Vec<_>>()
                })
                .collect();
            let eamc = Eamc::construct(8, &ds, 4);
            let tier = TierConfig {
                gpu_capacity: 64,
                dram_capacity: 200,
                backing: Tier::Ssd,
                ssd_to_dram: Link::new(6.0, 50e-6),
                dram_to_gpu: Link::new(32.0, 10e-6),
                n_gpus: 1,
                demand_extra_latency: SimTime::ZERO,
                demand_bw_factor: 1.0,
                gpu_policy: CacheKind::Activation,
                dram_policy: CacheKind::Activation,
                oracle_trace: Vec::new(),
                activation_terms: (true, true),
                prefetch_gpu_budget: 0.5,
            };
            SimEngine::new(
                spec.clone(),
                tier,
                eamc,
                ComputeModel::a5000(),
                EngineConfig::default(),
            )
        };
        let engines = vec![mk_replica(0..4), mk_replica(4..8)];
        let mut w = Workload::new(&spec, preset.clone(), 9);
        let reqs: Vec<Request> = (0..5)
            .map(|i| Request::new(i as u64, i as f64 * 40.0, w.gen_sequence_for_task(6)))
            .collect();
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::TaskAffinity,
            AdmissionPolicy::Fifo,
        )
        .with_prefill_chunk(8); // below the preset's minimum prompt length
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 5);
        let counts: Vec<usize> = router
            .replicas()
            .iter()
            .map(|r| r.request_stats().len())
            .collect();
        assert_eq!(
            counts,
            vec![0, 5],
            "first-chunk signatures must still route task 6 to its replica"
        );
    }

    #[test]
    fn empty_fault_plan_router_replays_bitwise() {
        let run = |plan: Option<FaultPlan>| -> ServeReport {
            let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
            let reqs = mk_requests(16, 8.0, 3);
            let mut router = Router::new(
                engines,
                Batcher::new(4, 0.1),
                RoutingPolicy::RoundRobin,
                AdmissionPolicy::Fifo,
            );
            if let Some(p) = plan {
                router = router.with_fault_plan(&p);
            }
            router.submit_all(&reqs);
            router.drain()
        };
        let base = run(None);
        let empty = run(Some(FaultPlan::new(99)));
        assert_eq!(base.requests, empty.requests);
        assert_eq!(base.tokens, empty.tokens);
        assert_eq!(base.batches, empty.batches);
        assert_eq!(base.makespan.to_bits(), empty.makespan.to_bits());
        assert_eq!(base.demands, empty.demands);
        assert_eq!(base.gpu_hits, empty.gpu_hits);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(base.token_latency.samples()),
            bits(empty.token_latency.samples()),
            "an empty fault plan must not change the router replay"
        );
        assert_eq!(empty.transfer_retries, 0);
        assert_eq!(empty.demand_failures, 0);
    }

    #[test]
    fn calendar_replays_the_lockstep_loop_bitwise() {
        // one router drained through the calendar, an identically built
        // one through the retired lockstep reference — every counter and
        // sample must match to the bit, with and without a fault plan
        // (link faults + a mid-flight crash). The full scheduler-kind ×
        // plan × N matrix lives in rust/tests/scheduler.rs.
        let mk_plan = || {
            let mut plan = FaultPlan::new(0xCA1);
            plan.ssd_failure_p = 0.1;
            plan.gpu_failure_p = 0.05;
            plan.crashes.push(CrashWindow {
                replica: 0,
                crash: SimTime::from_f64(0.05),
                recover: SimTime::from_f64(1.5),
            });
            plan
        };
        for faulted in [false, true] {
            let run = |lockstep: bool| -> ServeReport {
                // small GPU so transfers (and thus link faults) engage
                let engines = vec![mk_engine(1, 8).1, mk_engine(2, 8).1];
                let reqs = mk_requests(14, 20.0, 7);
                let mut router = Router::new(
                    engines,
                    Batcher::new(4, 0.1),
                    RoutingPolicy::RoundRobin,
                    AdmissionPolicy::Fifo,
                );
                if faulted {
                    router = router.with_fault_plan(&mk_plan());
                }
                router.submit_all(&reqs);
                if lockstep {
                    router.drain_lockstep()
                } else {
                    router.drain()
                }
            };
            let cal = run(false);
            let lock = run(true);
            assert_eq!(cal.requests, lock.requests, "faulted={faulted}");
            assert_eq!(cal.tokens, lock.tokens, "faulted={faulted}");
            assert_eq!(cal.batches, lock.batches, "faulted={faulted}");
            assert_eq!(cal.demands, lock.demands, "faulted={faulted}");
            assert_eq!(cal.gpu_hits, lock.gpu_hits, "faulted={faulted}");
            assert_eq!(cal.transfer_retries, lock.transfer_retries, "faulted={faulted}");
            assert_eq!(cal.demand_failures, lock.demand_failures, "faulted={faulted}");
            assert_eq!(
                cal.makespan.to_bits(),
                lock.makespan.to_bits(),
                "faulted={faulted}"
            );
            let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(
                bits(cal.token_latency.samples()),
                bits(lock.token_latency.samples()),
                "calendar must replay lockstep bitwise (faulted={faulted})"
            );
        }
    }

    #[test]
    fn single_submits_replay_submit_all_bitwise() {
        // presize-by-delta must not change the simulation: incremental
        // submits (no fleet presize) and one bulk submit_all produce the
        // same replay, bit for bit
        let run = |bulk: bool| -> ServeReport {
            let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
            let reqs = mk_requests(12, 8.0, 3);
            let mut router = Router::new(
                engines,
                Batcher::new(4, 0.1),
                RoutingPolicy::RoundRobin,
                AdmissionPolicy::Fifo,
            );
            if bulk {
                router.submit_all(&reqs);
            } else {
                for req in &reqs {
                    router.submit(req);
                }
            }
            router.drain()
        };
        let bulk = run(true);
        let single = run(false);
        assert_eq!(bulk.requests, single.requests);
        assert_eq!(bulk.tokens, single.tokens);
        assert_eq!(bulk.makespan.to_bits(), single.makespan.to_bits());
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(bulk.token_latency.samples()),
            bits(single.token_latency.samples())
        );
    }

    #[test]
    fn replica_crash_fails_over_in_flight_work_to_the_survivor() {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), 0x77 ^ 3);
        // tight arrivals: both replicas are mid-flight when replica 0 dies
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request::new(i as u64, i as f64 * 0.01, w.gen_sequence()))
            .collect();
        let mut plan = FaultPlan::new(5);
        plan.crashes.push(CrashWindow {
            replica: 0,
            crash: SimTime::from_f64(0.02),
            recover: SimTime::INFINITY, // never comes back
        });
        let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::RoundRobin,
            AdmissionPolicy::Fifo,
        )
        .with_fault_plan(&plan);
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 4, "every request survives the crash");
        assert_eq!(report.request_latency.len(), 4);
        // the survivor ended up owning everything replica 0 lost
        let survivor_stats = router.replicas()[1].request_stats();
        assert!(
            survivor_stats.len() >= 3,
            "failed-over work must re-dispatch to the survivor (got {})",
            survivor_stats.len()
        );
        assert!(
            survivor_stats.iter().any(|s| s.preemptions > 0),
            "at least one sequence must resume from warm captured state"
        );
        assert!(survivor_stats.iter().all(|s| s.finished));
    }

    #[test]
    fn recovered_replica_rejoins_the_dispatch_set() {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), 0x77 ^ 9);
        // burst one: both replicas busy when replica 0 dies; burst two
        // arrives long after recovery and must spread across both again
        let mut reqs: Vec<Request> = (0..4)
            .map(|i| Request::new(i as u64, i as f64 * 0.01, w.gen_sequence()))
            .collect();
        for i in 0..4 {
            reqs.push(Request::new(4 + i as u64, 1000.0 + i as f64 * 0.01, w.gen_sequence()));
        }
        let mut plan = FaultPlan::new(5);
        plan.crashes.push(CrashWindow {
            replica: 0,
            crash: SimTime::from_f64(0.02),
            recover: SimTime::from_f64(500.0),
        });
        let engines = vec![mk_engine(1, 64).1, mk_engine(2, 64).1];
        let mut router = Router::new(
            engines,
            Batcher::new(4, 0.1),
            RoutingPolicy::RoundRobin,
            AdmissionPolicy::Fifo,
        )
        .with_fault_plan(&plan);
        router.submit_all(&reqs);
        let report = router.drain();
        assert_eq!(report.requests, 8);
        // replica 0 received post-recovery dispatches (round-robin resumes
        // including it once the window has passed)
        let r0 = router.replicas()[0].request_stats();
        assert!(
            r0.iter().any(|s| s.arrival >= 1000.0),
            "a recovered replica must rejoin the dispatch set"
        );
    }

    #[test]
    fn degenerate_chunk_signature_falls_back_to_modal_experts() {
        // a 1-token chunk of a flat prompt rounds every proportional share
        // to zero; the scorer must fall back to modal experts, not record
        // nothing. Construct the degenerate row directly.
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("translation").unwrap(), 3);
        let seq = w.gen_sequence();
        // a prompt row spread so thin every cell share rounds to zero at
        // chunk 1: counts are < prompt for every expert whenever at least
        // two experts split the row — true for generated traces with
        // prompt >= 16 and noise > 0; assert rather than assume
        let spread = seq.routes[0]
            .iter()
            .any(|row| row.len() >= 2 && row.iter().all(|&(_, c)| c < seq.prompt_len as u32));
        assert!(spread, "trace must have a spread prefill row for this test");
        let ds = w.gen_eam_dataset(20);
        let eamc = Eamc::construct(6, &ds, 5);
        let mut scorer = EamcMatcher::new();
        scorer.attach(&eamc);
        record_prefill_signature(&mut scorer, eamc.index(), &seq, 1);
        assert!(
            scorer.traced_rows() > 0,
            "fallback must leave a usable signature in the scorer"
        );
    }
}
