//! The request-lifecycle serving API.
//!
//! Serving is organized around the [`Scheduler`] trait — `submit` requests
//! in arrival order, `tick` one scheduling quantum at a time, `drain` to a
//! [`ServeReport`] — with a three-scheduler lineup sharing one engine
//! substrate, plus a multi-replica router in front:
//!
//! * [`StaticScheduler`] — AlpaServe-style run-to-completion batches (the
//!   paper's §8.2 methodology): requests accumulate until either
//!   `max_batch` sequences or `max_wait` elapses from the first queued
//!   request, then the whole batch holds the engine until its longest
//!   member finishes.
//! * [`ContinuousScheduler`] — continuous batching on the resumable
//!   [`crate::engine::BatchSession`]: arrivals join free slots at every
//!   iteration boundary and sequences retire the iteration they finish.
//!   Under [`AdmissionPolicy::Classes`] admission is priority- and
//!   SLO-aware instead of FIFO — served from a binary heap keyed by the
//!   time-invariant `(priority desc, deadline, arrival, idx)` [`AdmitKey`]
//!   (O(log n) per pop instead of an O(backlog) rescan) — and a
//!   high-priority arrival may *voluntarily preempt* a lower-priority
//!   sequence mid-flight ([`crate::engine::BatchSession::evict`] saves its
//!   traced EAM and position; [`crate::engine::BatchSession::admit_resumed`]
//!   continues it later with identical per-token expert demands).
//! * [`ChunkedScheduler`] — continuous batching plus **chunked prefill**
//!   (the vLLM token-budget knob): a joining prompt executes at most
//!   `prefill_chunk` tokens per iteration, interleaved with the in-flight
//!   decode tokens of the same session, so an iteration-0 prompt burst can
//!   no longer stall every in-flight decode for a whole prompt's worth of
//!   compute and expert fetches. The session admits the sequence in a
//!   `Prefilling(consumed..)` state, partial prefill rows feed the
//!   per-sequence EAM/matcher incrementally (prediction and prefetch see
//!   the routing signature as it accumulates), and TTFT/EAMC-recall
//!   accounting lands at the iteration the *last* chunk completes.
//! * [`router::Router`] — owns N engine replicas and dispatches one
//!   request stream across per-replica continuous (or chunked) schedulers
//!   with a pluggable [`router::RoutingPolicy`] (round-robin, least-loaded,
//!   or eMoE-style task affinity scored against each replica's EAMC; under
//!   chunked prefill the affinity score uses the first chunk's share of
//!   the prompt signature — what a real dispatcher would have seen).
//!
//! Compatibility is pinned bitwise: with default request classes the
//! continuous scheduler reproduces the pre-trait `serve_continuous` replay
//! exactly, the static scheduler reproduces `serve`, continuous at
//! `max_batch = 1` equals static, a 1-replica round-robin router equals a
//! bare continuous scheduler, a chunked scheduler with an unlimited
//! `prefill_chunk` equals the continuous scheduler, and the Classes
//! admission heap pops in exactly the retired rescan's order
//! (`rust/tests/parallel.rs`, `rust/tests/scheduler.rs`). All replays are
//! fully deterministic in virtual time.

pub mod router;

pub use router::{Router, RoutingPolicy};

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::engine::{BatchResult, FeedbackMode, PreemptedSeq, SessionState, SimEngine, StepResult};
use crate::metrics::LatencyRecorder;
use crate::util::units::SimTime;
use crate::workload::{Priority, Request, SequenceActivation};

/// Upper bound on the iterations a request will *execute* — the
/// token-latency sample budget `reserve_for` pre-sizes recorders with.
/// Unlimited prefill budget ⇒ exactly `seq.iterations()`. A finite chunk
/// budget ⇒ one iteration per prompt token plus the decode iterations:
/// `ceil(prompt/chunk)` is NOT a bound, because the shared per-iteration
/// budget hands a lower-ranked slot the *leftover* of a higher-ranked
/// slot's final partial chunk, splitting its prompt into sub-chunk grants
/// (each executed grant still covers ≥ 1 token, so `prompt` is).
pub(crate) fn expected_iterations(seq: &SequenceActivation, prefill_chunk: u32) -> usize {
    if prefill_chunk == u32::MAX {
        seq.iterations()
    } else {
        // zero-prompt sequences still execute one (empty) prefill iteration
        seq.prompt_len.max(1) + seq.gen_len
    }
}

/// The shared batching-window check used by both [`Batcher::new`] (hard
/// assert) and `config::ServeConfig::validate` (soft error): a NaN or
/// negative window would poison the static batcher's dispatch arithmetic
/// and silently mis-batch every request.
pub fn check_max_wait(window_s: f64) -> Result<(), String> {
    if window_s.is_finite() && window_s >= 0.0 {
        Ok(())
    } else {
        Err(format!("max_wait must be finite and >= 0, got {window_s}"))
    }
}

/// Batching policy. `max_wait` only applies to the static scheduler; the
/// continuous scheduler admits at iteration boundaries and never holds a
/// request back to grow a batch.
#[derive(Debug, Clone, Copy)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: SimTime,
}

impl Batcher {
    /// `window_s` is the raw-float config boundary for the batching window
    /// in seconds; it becomes the typed `max_wait` field.
    pub fn new(max_batch: usize, window_s: f64) -> Batcher {
        match Batcher::try_new(max_batch, window_s) {
            Ok(b) => b,
            Err(e) => panic!("{e}"), // moelint: allow(panic-free, assert-style ctor; try_new is the fallible form)
        }
    }

    /// Fallible form of [`Batcher::new`]: returns the validation message
    /// instead of aborting the process, so replay drivers (`benchsuite`'s
    /// per-point grid errors) can surface a bad batching window as data.
    pub fn try_new(max_batch: usize, window_s: f64) -> Result<Batcher, String> {
        if max_batch < 1 {
            return Err(format!("max_batch must be >= 1, got {max_batch}"));
        }
        check_max_wait(window_s)?;
        Ok(Batcher {
            max_batch,
            max_wait: SimTime::from_f64(window_s),
        })
    }

    /// Given arrival-sorted requests and the engine-free time, decide the
    /// next batch: returns `(dispatch_time, end_index_exclusive)` for the
    /// batch starting at `start_idx`.
    pub fn next_batch(
        &self,
        requests: &[&Request],
        start_idx: usize,
        engine_free: f64,
    ) -> (f64, usize) {
        let first = &requests[start_idx];
        let window_end = first.arrival + self.max_wait.to_f64();
        // time at which the batch would be full
        let full_idx = start_idx + self.max_batch - 1;
        let fill_time = if full_idx < requests.len() {
            requests[full_idx].arrival
        } else {
            f64::INFINITY
        };
        // dispatch when full or window expires — but never before the
        // engine is free (requests keep accumulating while it's busy).
        let policy_time = fill_time.min(window_end).max(first.arrival);
        let dispatch = policy_time.max(engine_free);
        // everyone who has arrived by the dispatch instant rides along
        let mut end = start_idx;
        while end < requests.len()
            && end - start_idx < self.max_batch
            && requests[end].arrival <= dispatch
        {
            end += 1;
        }
        debug_assert!(end > start_idx);
        (dispatch, end)
    }
}

/// Admission discipline of the continuous scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Strict arrival order, no preemption — the pre-priority behavior,
    /// bitwise-pinned by the differential suite.
    #[default]
    Fifo,
    /// Priority classes: free slots go to the highest
    /// [`crate::workload::Priority`] tier first (least SLO slack, then
    /// earliest arrival within a tier), and a waiting request may preempt
    /// an in-flight sequence of a *strictly lower* tier at an iteration
    /// boundary. With every request on the default class this degenerates
    /// to FIFO exactly.
    Classes,
}

impl AdmissionPolicy {
    pub fn by_name(s: &str) -> Option<AdmissionPolicy> {
        match s {
            "fifo" => Some(AdmissionPolicy::Fifo),
            "classes" => Some(AdmissionPolicy::Classes),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdmissionPolicy::Fifo => "fifo",
            AdmissionPolicy::Classes => "classes",
        }
    }
}

/// Outcome of one serving replay.
#[derive(Debug, Default)]
pub struct ServeReport {
    /// Per-forward-iteration (per-token) latency; the first iteration of a
    /// request carries its queueing delay, and the first iteration after a
    /// preemption carries the suspension gap.
    pub token_latency: LatencyRecorder,
    /// Per-request mean token latency (queueing included), recorded the
    /// iteration the request actually finishes.
    pub request_latency: LatencyRecorder,
    /// Time to first token per request: from arrival to the end of the
    /// request's first *executed* iteration.
    pub ttft: LatencyRecorder,
    /// Time per output token per request: mean latency of the iterations
    /// after the first (only recorded for multi-iteration requests).
    pub tpot: LatencyRecorder,
    /// Raw per-iteration latency of every *pure decode* step a request
    /// rode (its prefill already complete before the iteration started),
    /// without queueing/suspension charges — the decode-stall metric
    /// chunked prefill exists to cap. Continuous-substrate schedulers
    /// record it; the static scheduler (whole batches, no interleaving)
    /// leaves it empty.
    pub decode_latency: LatencyRecorder,
    pub requests: u64,
    pub tokens: u64,
    /// Static scheduler: dispatched batches. Continuous scheduler: engine
    /// iterations executed (there is no batch boundary to count). Router:
    /// iterations summed over replicas.
    pub batches: u64,
    /// Virtual makespan of the replay (max over replicas for the router).
    pub makespan: SimTime,
    /// Aggregate expert-demand outcomes from the memory simulator (summed
    /// over replicas): total demands and how many were already GPU-resident.
    pub demands: u64,
    pub gpu_hits: u64,
    /// Total bytes moved by prefetch transfers (dead-traffic accounting for
    /// the retired-prefetch cancellation experiments).
    pub prefetch_bytes: u64,
    /// Requests shed at admission because their SLO deadline had already
    /// passed (zero unless deadline shedding is enabled).
    pub shed: u64,
    /// Requests aborted at an iteration boundary after partial execution
    /// because their SLO deadline passed (zero unless shedding is enabled).
    pub timed_out: u64,
    /// Tokens of requests that completed within their SLO deadline
    /// (SLO-less requests always count) — the goodput numerator.
    pub goodput_tokens: u64,
    /// Demanded transfers that exhausted their fault-retry budget and were
    /// force-landed anyway (from `MemoryStats`; zero without a fault plan).
    pub demand_failures: u64,
    /// Transfer attempts retried by the fault layer (from `MemoryStats`;
    /// zero without a fault plan).
    pub transfer_retries: u64,
}

impl ServeReport {
    pub fn token_throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.makespan.to_f64()
        }
    }

    /// Goodput: completed-within-SLO tokens per second of makespan. With
    /// no SLOs attached this equals [`ServeReport::token_throughput`] for
    /// a fully-completed replay; under faults/shedding it is the paper's
    /// graceful-degradation surface (`perf_faults` pins its no-cliff
    /// shape).
    pub fn goodput(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.goodput_tokens as f64 / self.makespan.to_f64()
        }
    }

    /// Fraction of expert demands served without any blocking transfer.
    /// Zero-demand convention: 1.0 (matches `MemoryStats::gpu_hit_ratio`).
    pub fn gpu_hit_ratio(&self) -> f64 {
        if self.demands == 0 {
            1.0
        } else {
            self.gpu_hits as f64 / self.demands as f64
        }
    }

    /// Fold `other` into `self` (the router merges per-replica reports in
    /// replica order; merging into an empty report is the identity).
    pub fn merge(&mut self, other: &ServeReport) {
        self.token_latency.append(&other.token_latency);
        self.request_latency.append(&other.request_latency);
        self.ttft.append(&other.ttft);
        self.tpot.append(&other.tpot);
        self.decode_latency.append(&other.decode_latency);
        self.requests += other.requests;
        self.tokens += other.tokens;
        self.batches += other.batches;
        self.makespan = self.makespan.max(other.makespan);
        self.demands += other.demands;
        self.gpu_hits += other.gpu_hits;
        self.prefetch_bytes += other.prefetch_bytes;
        self.shed += other.shed;
        self.timed_out += other.timed_out;
        self.goodput_tokens += other.goodput_tokens;
        self.demand_failures += other.demand_failures;
        self.transfer_retries += other.transfer_retries;
    }

    /// Copy the engine-level demand/traffic tallies into the report (called
    /// once at drain, when the replay is complete).
    fn absorb_sim_stats(&mut self, engine: &SimEngine) {
        let st = engine.sim().stats();
        self.demands = st.demand_total();
        self.gpu_hits = st.demand_gpu_hits;
        self.prefetch_bytes = st.total_prefetch_bytes();
        self.demand_failures = st.demand_failures;
        self.transfer_retries = st.transfer_retries;
    }
}

/// The request-lifecycle interface every serving discipline implements.
///
/// Usage: `submit` the arrival-sorted request stream (all up front, or
/// incrementally as long as arrival order is respected), then either call
/// `drain` for the whole replay or interleave `tick` calls to advance one
/// scheduling quantum at a time. `drain` finalizes and returns the report;
/// it is a one-shot call (subsequent drains return an empty report).
pub trait Scheduler<'r> {
    /// Enqueue a request. Must be called in nondecreasing arrival order.
    fn submit(&mut self, req: &'r Request);

    /// Advance one scheduling quantum (one dispatched batch, one engine
    /// iteration, or one router event). Returns `false` when no work is
    /// left. Progress contract: while a scheduler reports a
    /// `next_event_bound`, `tick` must return `true` and make progress —
    /// the router turns a violation into a hard error in every build
    /// profile, because a bound with no progress would spin `drain`
    /// forever in release.
    fn tick(&mut self) -> bool;

    /// Run all submitted work to completion and return the report.
    fn drain(&mut self) -> ServeReport;

    /// Convenience: submit a whole arrival-sorted slice.
    fn submit_all(&mut self, reqs: &'r [Request]) {
        for r in reqs {
            self.submit(r);
        }
    }
}

/// Run-to-completion batch scheduler (the paper's §8.2 methodology; the
/// former free function `serve`, bitwise-preserved).
pub struct StaticScheduler<'r> {
    engine: SimEngine,
    batcher: Batcher,
    pending: Vec<&'r Request>,
    idx: usize,
    engine_free: f64,
    result: BatchResult,
    report: ServeReport,
    drained: bool,
}

impl<'r> StaticScheduler<'r> {
    pub fn new(engine: SimEngine, batcher: Batcher) -> StaticScheduler<'r> {
        let engine_free = engine.now();
        StaticScheduler {
            engine,
            batcher,
            pending: Vec::new(),
            idx: 0,
            engine_free,
            result: BatchResult::default(),
            report: ServeReport::default(),
            drained: false,
        }
    }

    pub fn engine(&self) -> &SimEngine {
        &self.engine
    }

    pub fn into_engine(self) -> SimEngine {
        self.engine
    }
}

impl<'r> Scheduler<'r> for StaticScheduler<'r> {
    fn submit(&mut self, req: &'r Request) {
        assert!(!self.drained, "submit after drain: the request would be lost");
        debug_assert!(
            self.pending.last().map_or(true, |p| p.arrival <= req.arrival),
            "requests must be submitted in arrival order"
        );
        self.pending.push(req);
    }

    /// Dispatch and run one batch to completion. Batching decisions look
    /// ahead only at requests already submitted, so submit the full stream
    /// before ticking to reproduce the historical replay.
    fn tick(&mut self) -> bool {
        if self.idx >= self.pending.len() {
            return false;
        }
        let (dispatch, end) = self
            .batcher
            .next_batch(&self.pending, self.idx, self.engine_free);
        let batch = &self.pending[self.idx..end];
        let seqs: Vec<_> = batch.iter().map(|r| r.seq.clone()).collect();
        self.engine.run_batch_into(&seqs, dispatch, &mut self.result);

        // queueing delay per request = dispatch - arrival
        for r in batch {
            let queue_delay = dispatch - r.arrival;
            let n_iters = r.seq.iterations().min(self.result.token_latencies.len());
            let mut mean = 0.0;
            for (i, &lat) in self.result.token_latencies[..n_iters].iter().enumerate() {
                let l = if i == 0 { lat + queue_delay } else { lat };
                self.report.token_latency.record(l);
                mean += l;
            }
            if n_iters > 0 {
                self.report.request_latency.record(mean / n_iters as f64);
                // TTFT = queueing delay + the batch's first iteration; TPOT
                // = mean of the remaining iterations the request rode in
                let ttft = self.result.token_latencies[0] + queue_delay;
                self.report.ttft.record(ttft);
                if n_iters > 1 {
                    self.report.tpot.record((mean - ttft) / (n_iters - 1) as f64);
                }
            }
            self.report.tokens += r.seq.total_tokens() as u64;
            // goodput: the whole batch completes at its longest member's
            // finish, so that instant is every member's (conservative)
            // completion time for the within-SLO test. Static never sheds.
            if r.class.slo.map_or(true, |s| self.result.finish <= r.arrival + s) {
                self.report.goodput_tokens += r.seq.total_tokens() as u64;
            }
        }
        self.report.requests += batch.len() as u64;
        self.report.batches += 1;
        self.engine_free = self.result.finish;
        self.idx = end;
        true
    }

    fn drain(&mut self) -> ServeReport {
        if self.drained {
            return ServeReport::default(); // one-shot: nothing new to report
        }
        self.drained = true;
        while self.tick() {}
        self.report.makespan = SimTime::from_f64(self.engine_free);
        self.report.absorb_sim_stats(&self.engine);
        std::mem::take(&mut self.report)
    }
}

/// Sentinel for "not currently mapped" slot/park indices.
const NONE_U32: u32 = u32::MAX;

/// Terminal disposition of a request under SLO-aware degraded-mode
/// serving. Without shedding enabled every request ends `Completed` — the
/// historical behavior, bitwise-pinned.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RequestOutcome {
    /// Ran to completion (within or past its SLO; goodput separates the
    /// two — see [`ServeReport::goodput_tokens`]).
    #[default]
    Completed,
    /// Aborted after partial execution: its SLO deadline passed while it
    /// was in flight or parked, and the slot was reclaimed via the evict
    /// path.
    TimedOut,
    /// Rejected at admission before executing anything: its deadline had
    /// already passed when a slot finally opened.
    Shed,
}

/// Per-request outcome exposed after a continuous replay (the priority /
/// preemption experiments slice latencies by class with this).
#[derive(Debug, Clone, Copy)]
pub struct RequestStat {
    pub id: u64,
    pub priority: Priority,
    pub arrival: f64,
    pub finished: bool,
    /// Terminal disposition (`Completed` unless deadline shedding fired).
    pub outcome: RequestOutcome,
    /// Mean per-token latency, queueing and suspension charges included
    /// (the `request_latency` sample of this request).
    pub latency: SimTime,
    /// Time to first token (0 if nothing executed).
    pub ttft: SimTime,
    /// How many times the sequence was preempted.
    pub preemptions: u32,
}

/// Continuous-batching scheduler on one engine (the former free function
/// `serve_continuous`, bitwise-preserved under [`AdmissionPolicy::Fifo`]),
/// plus priority-class admission and voluntary preemption under
/// [`AdmissionPolicy::Classes`].
pub struct ContinuousScheduler<'r> {
    engine: SimEngine,
    max_batch: usize,
    admission: AdmissionPolicy,
    /// Per-iteration prefill token budget (`u32::MAX` = unlimited, the
    /// plain continuous discipline). [`ChunkedScheduler`] sets it finite.
    prefill_chunk: u32,
    layers: usize,
    experts: usize,
    /// Suspended session continuation (`None` once drained).
    session: Option<SessionState>,
    step: StepResult,
    /// Submitted requests in arrival order; index = session external id.
    reqs: Vec<&'r Request>,
    /// First request not yet moved into the backlog.
    next_arrival: usize,
    /// FIFO backlog: arrived, unadmitted request indices in arrival order
    /// (deque: admission pops the front in O(1) even under deep overload
    /// backlogs). Empty under [`AdmissionPolicy::Classes`].
    waiting: VecDeque<u32>,
    /// Classes backlog: waiting *and* preempted requests keyed by their
    /// time-invariant [`AdmitKey`] (pop = next admission, O(log n); a
    /// popped request resumes rather than admits fresh iff it holds a park
    /// slot). Empty under [`AdmissionPolicy::Fifo`].
    class_heap: BinaryHeap<AdmitKey>,
    /// In-flight request indices (unordered; scanned for victims).
    active: Vec<u32>,
    /// Monotone admission counter — the low bits of the Classes prefill
    /// rank, so equal-tier prefills drain the chunk budget FCFS.
    admit_seq: u64,
    /// Pool of saved preemption states; `park_of` maps requests to slots.
    parked: Vec<PreemptedSeq>,
    free_park: Vec<u32>,
    finished: usize,
    expected_tokens: usize,
    // --- per-request accounting, index-aligned with `reqs` ---
    lat_sum: Vec<f64>,
    lat_n: Vec<u32>,
    /// Waiting time (initial queueing, suspension gap, or a zero-budget
    /// prefill stall) to fold into the next executed token's latency.
    pending_extra: Vec<f64>,
    charge: Vec<bool>,
    ttft_val: Vec<f64>,
    first_done: Vec<bool>,
    /// Iterations spent prefilling (chunks), incl. the completing one —
    /// the TPOT denominator excludes them.
    prefill_iters: Vec<u32>,
    evict_t: Vec<f64>,
    slot_of: Vec<u32>,
    park_of: Vec<u32>,
    preemptions: Vec<u32>,
    done: Vec<bool>,
    /// Terminal disposition per request (`Completed` unless shedding
    /// fired), index-aligned with `reqs`.
    outcome: Vec<RequestOutcome>,
    /// Deadline shedding / timeout aborts for SLO-carrying requests.
    /// Off by default — the fault-free replay is bitwise-pinned with the
    /// flag off, and SLO classes historically never aborted.
    shedding: bool,
    report: ServeReport,
}

/// Reserve to an absolute capacity target (`reserve` already no-ops once
/// capacity suffices) — the router pre-sizes replica buffers this way so
/// dispatch-time pushes inside a warmed iteration never allocate.
fn reserve_to<T>(v: &mut Vec<T>, total: usize) {
    v.reserve(total.saturating_sub(v.len()));
}

/// [`reserve_to`] for the wait deque.
fn reserve_deque_to<T>(v: &mut VecDeque<T>, total: usize) {
    v.reserve(total.saturating_sub(v.len()));
}

/// `(priority, slack, arrival, idx)` admission key: higher tier first,
/// then least SLO slack, then earliest arrival, then lowest index.
/// Retained as part of the rescan reference (see [`pick_candidate`]).
fn candidate_beats(
    a: (Priority, f64, f64, u32),
    b: (Priority, f64, f64, u32),
) -> bool {
    if a.0 != b.0 {
        return a.0 > b.0;
    }
    if a.1 != b.1 {
        return a.1 < b.1;
    }
    if a.2 != b.2 {
        return a.2 < b.2;
    }
    a.3 < b.3
}

/// **Reference implementation** of Classes admission: a full rescan of the
/// waiting and preempted lists per admission attempt — O(backlog) each.
/// The serving path now pops an [`AdmitKey`] heap instead (O(log n)); this
/// scan is kept as the executable specification the heap order is pinned
/// against bitwise in `rust/tests/scheduler.rs`. Returns
/// `(from_preempted, position_in_that_list)` of the best candidate.
pub fn pick_candidate(
    reqs: &[&Request],
    waiting: &VecDeque<u32>,
    preempted: &[u32],
    now: f64,
) -> Option<(bool, usize)> {
    let key = |i: u32| {
        let r = reqs[i as usize];
        (r.class.priority, r.class.slack(r.arrival, now), r.arrival, i)
    };
    let mut best: Option<((Priority, f64, f64, u32), bool, usize)> = None;
    for (pos, &i) in waiting.iter().enumerate() {
        let k = key(i);
        if best.map_or(true, |(bk, _, _)| candidate_beats(k, bk)) {
            best = Some((k, false, pos));
        }
    }
    for (pos, &i) in preempted.iter().enumerate() {
        let k = key(i);
        if best.map_or(true, |(bk, _, _)| candidate_beats(k, bk)) {
            best = Some((k, true, pos));
        }
    }
    best.map(|(_, from_preempted, pos)| (from_preempted, pos))
}

/// Indexed Classes admission key. The rescan compared `(priority desc,
/// slack asc, arrival asc, idx asc)` where slack = `arrival + slo − now`;
/// subtraction of a common `now` is monotone, so the slack order equals
/// the *deadline* (`arrival + slo`) order and the key is
/// **time-invariant**: computed once when a request enters the backlog
/// and valid forever after, which is what lets a binary heap replace the
/// per-attempt O(backlog) rescan with O(log n) pops. (The one divergence
/// class: two *distinct* deadlines whose `− now` rounds them equal — the
/// scan then fell through to its arrival tie-break by floating-point
/// accident; the heap keeps the true deadline order, which is the
/// intended semantics.) `Ord` is arranged so the max-heap top is the next
/// admission; `idx` is unique per request, so the order is total and the
/// pop sequence is pinned bitwise against [`pick_candidate`]'s scan order
/// in `rust/tests/scheduler.rs`.
#[derive(Debug, Clone, Copy)]
pub struct AdmitKey {
    priority: Priority,
    /// `arrival + slo`, `+inf` when the class carries no SLO.
    deadline: SimTime,
    arrival: f64,
    idx: u32,
}

/// The [`AdmitKey`] of request `idx` (index into the submission order).
pub fn admit_key(r: &Request, idx: u32) -> AdmitKey {
    AdmitKey {
        priority: r.class.priority,
        deadline: match r.class.slo {
            Some(s) => SimTime::from_f64(r.arrival + s),
            None => SimTime::INFINITY,
        },
        arrival: r.arrival,
        idx,
    }
}

impl AdmitKey {
    /// The request index this key admits.
    pub fn idx(&self) -> u32 {
        self.idx
    }
}

impl PartialEq for AdmitKey {
    fn eq(&self, other: &AdmitKey) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for AdmitKey {}

impl PartialOrd for AdmitKey {
    fn partial_cmp(&self, other: &AdmitKey) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for AdmitKey {
    fn cmp(&self, other: &AdmitKey) -> Ordering {
        // greatest = admitted first: higher priority, then earlier
        // deadline, then earlier arrival, then lower index (total_cmp:
        // the ±inf deadlines of SLO-less classes order totally)
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.deadline.total_cmp(&self.deadline))
            .then_with(|| other.arrival.total_cmp(&self.arrival))
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

/// Preemption victim: the *youngest of the lowest tier* among active
/// requests (min priority, then max arrival, then max index). Returns the
/// position in `active`.
fn pick_victim(reqs: &[&Request], active: &[u32]) -> Option<usize> {
    let mut best: Option<((Priority, f64, u32), usize)> = None;
    for (pos, &i) in active.iter().enumerate() {
        let r = reqs[i as usize];
        let k = (r.class.priority, r.arrival, i);
        let worse = |b: (Priority, f64, u32)| {
            if k.0 != b.0 {
                return k.0 < b.0;
            }
            if k.1 != b.1 {
                return k.1 > b.1;
            }
            k.2 > b.2
        };
        if best.map_or(true, |(bk, _)| worse(bk)) {
            best = Some((k, pos));
        }
    }
    best.map(|(_, pos)| pos)
}

impl<'r> ContinuousScheduler<'r> {
    pub fn new(
        mut engine: SimEngine,
        batcher: Batcher,
        admission: AdmissionPolicy,
    ) -> ContinuousScheduler<'r> {
        let start = engine.now();
        let session = engine.begin_session(start, FeedbackMode::Immediate).suspend();
        let (layers, experts) = (engine.spec().n_layers, engine.spec().experts_per_layer);
        let active = Vec::with_capacity(batcher.max_batch);
        ContinuousScheduler {
            engine,
            max_batch: batcher.max_batch,
            admission,
            prefill_chunk: u32::MAX,
            layers,
            experts,
            session: Some(session),
            step: StepResult::default(),
            reqs: Vec::new(),
            next_arrival: 0,
            waiting: VecDeque::new(),
            class_heap: BinaryHeap::new(),
            active,
            admit_seq: 0,
            parked: Vec::new(),
            free_park: Vec::new(),
            finished: 0,
            expected_tokens: 0,
            lat_sum: Vec::new(),
            lat_n: Vec::new(),
            pending_extra: Vec::new(),
            charge: Vec::new(),
            ttft_val: Vec::new(),
            first_done: Vec::new(),
            prefill_iters: Vec::new(),
            evict_t: Vec::new(),
            slot_of: Vec::new(),
            park_of: Vec::new(),
            preemptions: Vec::new(),
            done: Vec::new(),
            outcome: Vec::new(),
            shedding: false,
            report: ServeReport::default(),
        }
    }

    /// Enable SLO deadline shedding: requests whose deadline has already
    /// passed are rejected at admission ([`RequestOutcome::Shed`]) and
    /// in-flight SLO-carrying sequences past their deadline are aborted at
    /// iteration boundaries via the evict path
    /// ([`RequestOutcome::TimedOut`]). SLO-less requests are never shed.
    pub fn set_shedding(&mut self, on: bool) {
        self.shedding = on;
    }

    /// Set the per-iteration prefill token budget (`u32::MAX` = unlimited).
    /// [`ChunkedScheduler`] and [`Router::with_prefill_chunk`] route
    /// through this; with the unlimited default the replay is bitwise the
    /// plain continuous one.
    pub(crate) fn set_prefill_chunk(&mut self, chunk: u32) {
        assert!(chunk >= 1, "prefill_chunk must be >= 1 (u32::MAX = unlimited)");
        self.prefill_chunk = chunk;
    }

    /// Builder form of [`ContinuousScheduler::set_prefill_chunk`].
    pub(crate) fn with_prefill_chunk(mut self, chunk: u32) -> ContinuousScheduler<'r> {
        self.set_prefill_chunk(chunk);
        self
    }

    /// Arrived-but-unadmitted requests (waiting + preempted), whichever
    /// backlog structure the admission policy uses.
    fn backlog(&self) -> usize {
        self.waiting.len() + self.class_heap.len()
    }

    pub fn engine(&self) -> &SimEngine {
        &self.engine
    }

    pub fn into_engine(self) -> SimEngine {
        self.engine
    }

    /// Virtual time of the current iteration boundary.
    #[inline]
    pub fn now(&self) -> f64 {
        match &self.session {
            Some(s) => s.now(),
            None => self.engine.now(),
        }
    }

    /// Anything submitted and not yet finished?
    #[inline]
    pub fn has_work(&self) -> bool {
        self.finished < self.reqs.len()
    }

    /// Dispatched-but-unfinished request count (the router's load signal).
    #[inline]
    pub fn load(&self) -> usize {
        self.reqs.len() - self.finished
    }

    /// Earliest virtual time at which this scheduler's next state change
    /// can happen: the current boundary while anything is admitted or
    /// admissible, else the next queued arrival. `None` when idle-empty.
    /// The router dispatches a request once every replica's bound has
    /// reached its arrival — replica states at the arrival instant are
    /// then final, keeping the replay deterministic and causal.
    ///
    /// **Bound-stability contract:** the returned value changes only when
    /// this scheduler itself is mutated — `submit` / `submit_failover` /
    /// `tick` / `fail_over` / `drain`. The router's event calendar
    /// memoizes the bound under a per-replica version and re-reads it
    /// exactly at those mutation points; anything that moves the bound
    /// through another path must bump the memo or the calendar replay
    /// diverges from the lockstep reference.
    #[inline]
    pub fn next_event_bound(&self) -> Option<f64> {
        if !self.has_work() {
            return None;
        }
        if !self.active.is_empty() || self.backlog() > 0 {
            return Some(self.now());
        }
        debug_assert!(self.next_arrival < self.reqs.len());
        Some(self.reqs[self.next_arrival].arrival.max(self.now()))
    }

    /// Pre-size every per-request buffer and report recorder for a stream
    /// of `total_requests` requests / `total_tokens` iterations, so that
    /// later `submit` calls (the router dispatches mid-replay) and
    /// steady-state recording never reallocate.
    pub fn reserve_for(&mut self, total_requests: usize, total_tokens: usize) {
        reserve_to(&mut self.reqs, total_requests);
        reserve_deque_to(&mut self.waiting, total_requests);
        self.class_heap
            .reserve(total_requests.saturating_sub(self.class_heap.len()));
        reserve_to(&mut self.lat_sum, total_requests);
        reserve_to(&mut self.lat_n, total_requests);
        reserve_to(&mut self.pending_extra, total_requests);
        reserve_to(&mut self.charge, total_requests);
        reserve_to(&mut self.ttft_val, total_requests);
        reserve_to(&mut self.first_done, total_requests);
        reserve_to(&mut self.prefill_iters, total_requests);
        reserve_to(&mut self.evict_t, total_requests);
        reserve_to(&mut self.slot_of, total_requests);
        reserve_to(&mut self.park_of, total_requests);
        reserve_to(&mut self.preemptions, total_requests);
        reserve_to(&mut self.done, total_requests);
        reserve_to(&mut self.outcome, total_requests);
        let r = &mut self.report;
        r.token_latency
            .reserve(total_tokens.saturating_sub(r.token_latency.len()));
        r.request_latency
            .reserve(total_requests.saturating_sub(r.request_latency.len()));
        r.ttft.reserve(total_requests.saturating_sub(r.ttft.len()));
        r.tpot.reserve(total_requests.saturating_sub(r.tpot.len()));
        r.decode_latency
            .reserve(total_tokens.saturating_sub(r.decode_latency.len()));
    }

    /// Per-request outcomes (id, class, latency, TTFT, preemption count).
    pub fn request_stats(&self) -> Vec<RequestStat> {
        (0..self.reqs.len())
            .map(|i| RequestStat {
                id: self.reqs[i].id,
                priority: self.reqs[i].class.priority,
                arrival: self.reqs[i].arrival,
                finished: self.done[i],
                outcome: self.outcome[i],
                latency: SimTime::from_f64(if self.lat_n[i] == 0 {
                    0.0
                } else {
                    self.lat_sum[i] / self.lat_n[i] as f64
                }),
                ttft: SimTime::from_f64(self.ttft_val[i]),
                preemptions: self.preemptions[i],
            })
            .collect()
    }

    /// Admit from the backlog into free slots at the current boundary;
    /// under [`AdmissionPolicy::Classes`], additionally preempt
    /// strictly-lower-priority in-flight sequences for waiting
    /// higher-priority requests.
    ///
    /// Cost note: the FIFO path pops the deque front in O(1). Classes pops
    /// the [`AdmitKey`] heap in O(log backlog) per admission — the key is
    /// time-invariant, so the heap order never needs refreshing; the pop
    /// sequence equals the retired O(backlog) rescan's pick sequence
    /// bitwise (pinned in `rust/tests/scheduler.rs`). Victim selection
    /// still scans `active`, which is bounded by `max_batch`.
    fn admit_and_preempt(&mut self) {
        let Some(state) = self.session.take() else {
            return; // drained replica: nothing to admit into
        };
        let now = state.now();
        let mut session = self.engine.resume_session(state);
        loop {
            // next candidate under the admission discipline (peek — the
            // candidate stays in the backlog until actually admitted)
            let cand = match self.admission {
                AdmissionPolicy::Fifo => match self.waiting.front() {
                    Some(&i) => i as usize,
                    None => break,
                },
                AdmissionPolicy::Classes => match self.class_heap.peek() {
                    Some(k) => k.idx() as usize,
                    None => break,
                },
            };
            if self.shedding
                && self.reqs[cand]
                    .class
                    .slo
                    .map_or(false, |s| now >= self.reqs[cand].arrival + s)
            {
                // the candidate's deadline has already passed: no admission
                // can yield a within-SLO completion, so shed it instead of
                // burning a slot — load shedding at the admission gate. A
                // preempted candidate surrenders its park slot; one that
                // executed before being parked counts as timed out.
                match self.admission {
                    AdmissionPolicy::Fifo => {
                        self.waiting.pop_front();
                    }
                    AdmissionPolicy::Classes => {
                        self.class_heap.pop();
                    }
                }
                if self.park_of[cand] != NONE_U32 {
                    self.free_park.push(self.park_of[cand]);
                    self.park_of[cand] = NONE_U32;
                }
                if self.lat_n[cand] > 0 {
                    self.outcome[cand] = RequestOutcome::TimedOut;
                    self.report.timed_out += 1;
                } else {
                    self.outcome[cand] = RequestOutcome::Shed;
                    self.report.shed += 1;
                }
                self.done[cand] = true;
                self.finished += 1;
                continue;
            }
            if session.active() >= self.max_batch {
                // no free slot: under Classes the candidate may evict the
                // youngest lowest-tier in-flight sequence — but only a
                // *strictly* lower one, so equal tiers never thrash and
                // FIFO (which never preempts) just stops here
                if self.admission != AdmissionPolicy::Classes {
                    break;
                }
                let Some(vpos) = pick_victim(&self.reqs, &self.active) else {
                    break;
                };
                let v = self.active[vpos] as usize;
                if self.reqs[v].class.priority >= self.reqs[cand].class.priority {
                    break; // nobody strictly below the candidate — keep order
                }
                // evict the victim into a (recycled) park slot; the freed
                // engine slot then goes to the candidate below. The victim
                // re-enters the backlog under its (unchanged) key — it is
                // strictly below the candidate, so the next pop still
                // returns the candidate.
                let park = match self.free_park.pop() {
                    Some(p) => p,
                    None => {
                        self.parked.push(PreemptedSeq::new(self.layers, self.experts));
                        (self.parked.len() - 1) as u32
                    }
                };
                session.evict(self.slot_of[v] as usize, &mut self.parked[park as usize]);
                self.active.swap_remove(vpos);
                self.park_of[v] = park;
                self.slot_of[v] = NONE_U32;
                self.evict_t[v] = now;
                self.preemptions[v] += 1;
                self.class_heap.push(admit_key(self.reqs[v], v as u32));
            }
            // admit the candidate into the free slot; a park slot marks it
            // as a preempted sequence to resume rather than a fresh admit
            let i = match self.admission {
                AdmissionPolicy::Fifo => match self.waiting.pop_front() {
                    Some(i) => i as usize,
                    None => break, // peeked above — an empty pop means no candidate
                },
                AdmissionPolicy::Classes => match self.class_heap.pop() {
                    Some(k) => k.idx() as usize,
                    None => break,
                },
            };
            debug_assert_eq!(i, cand, "pop must return the peeked candidate");
            let slot;
            if self.park_of[i] != NONE_U32 {
                let park = self.park_of[i];
                slot = session.admit_resumed(&self.parked[park as usize]);
                self.free_park.push(park);
                self.park_of[i] = NONE_U32;
                self.slot_of[i] = slot as u32;
                // the suspension gap is charged to the next executed token
                self.pending_extra[i] += now - self.evict_t[i];
                self.charge[i] = true;
                self.active.push(i as u32);
            } else {
                slot = session.admit(i as u64, &self.reqs[i].seq);
                self.slot_of[i] = slot as u32;
                self.pending_extra[i] = now - self.reqs[i].arrival;
                self.charge[i] = true;
                self.active.push(i as u32);
            }
            if self.admission == AdmissionPolicy::Classes {
                // rank the slot's chunk-budget precedence by tier (then
                // FCFS within a tier): an interactive prefill must never
                // be budget-starved behind a lower-priority prompt — the
                // chunk grant honors the same order admission does
                let tier_inv = Priority::Interactive as u64 - self.reqs[i].class.priority as u64;
                session.set_prefill_rank(slot, (tier_inv << 56) | self.admit_seq);
            }
            self.admit_seq += 1;
        }
        self.session = Some(session.suspend());
    }

    /// Abort in-flight SLO-carrying sequences whose deadline passed at
    /// this iteration boundary, reclaiming their slots through the evict
    /// path (batch-EAM subtraction + owned-prefetch cancellation come for
    /// free). Only called with shedding enabled; the cheap scan keeps the
    /// no-timeout boundary session-free.
    fn abort_timed_out(&mut self, now: f64) {
        let past_deadline = |r: &Request| r.class.slo.map_or(false, |s| now >= r.arrival + s);
        if !self.active.iter().any(|&i| past_deadline(self.reqs[i as usize])) {
            return;
        }
        let Some(state) = self.session.take() else {
            return; // drained replica: no in-flight sequences to abort
        };
        let mut session = self.engine.resume_session(state);
        let mut pos = 0;
        while pos < self.active.len() {
            let i = self.active[pos] as usize;
            if !past_deadline(self.reqs[i]) {
                pos += 1;
                continue;
            }
            // evict into a recycled park slot and immediately return it:
            // the saved state is discarded — the request is over
            let park = match self.free_park.pop() {
                Some(p) => p,
                None => {
                    self.parked.push(PreemptedSeq::new(self.layers, self.experts));
                    (self.parked.len() - 1) as u32
                }
            };
            session.evict(self.slot_of[i] as usize, &mut self.parked[park as usize]);
            self.free_park.push(park);
            self.active.swap_remove(pos);
            self.slot_of[i] = NONE_U32;
            self.outcome[i] = RequestOutcome::TimedOut;
            self.report.timed_out += 1;
            self.done[i] = true;
            self.finished += 1;
        }
        self.session = Some(session.suspend());
    }

    /// Crash hand-off: surrender every unfinished request this scheduler
    /// owns, capturing in-flight and preempted sequences as
    /// [`PreemptedSeq`]s (warm state: traced EAM, position, per-token
    /// demands) and undispatched/waiting ones bare. Appended to `out` in
    /// submission-index (= arrival) order, so the router's re-dispatch is
    /// deterministic. The scheduler is left inert — everything is marked
    /// locally done (ownership transferred; its report keeps only the
    /// token samples of iterations it actually executed) — and rejoins
    /// the dispatch set on recovery via plain `submit`.
    pub fn fail_over(&mut self, out: &mut Vec<(&'r Request, Option<PreemptedSeq>)>) {
        let Some(state) = self.session.take() else {
            return; // fail_over after drain: already inert, nothing owned
        };
        let mut session = self.engine.resume_session(state);
        for i in 0..self.reqs.len() {
            if self.done[i] {
                continue;
            }
            let saved = if self.slot_of[i] != NONE_U32 {
                let mut s = PreemptedSeq::new(self.layers, self.experts);
                session.evict(self.slot_of[i] as usize, &mut s);
                self.slot_of[i] = NONE_U32;
                Some(s)
            } else if self.park_of[i] != NONE_U32 {
                let park = self.park_of[i] as usize;
                self.park_of[i] = NONE_U32;
                let s = std::mem::replace(
                    &mut self.parked[park],
                    PreemptedSeq::new(self.layers, self.experts),
                );
                self.free_park.push(park as u32);
                Some(s)
            } else {
                None
            };
            out.push((self.reqs[i], saved));
            self.done[i] = true;
            self.finished += 1;
        }
        self.next_arrival = self.reqs.len();
        self.waiting.clear();
        self.class_heap.clear();
        self.active.clear();
        self.session = Some(session.suspend());
    }

    /// Re-dispatch a failed-over request onto this (surviving) scheduler.
    /// `saved` is the warm state captured by [`ContinuousScheduler::fail_over`]
    /// on the crashed replica — parked here under the request's *local*
    /// index so the normal resume path (`admit_resumed`) continues it with
    /// identical per-token expert demands. `handoff_t` (the crash-fire
    /// instant) is clamped to this replica's clock so cross-replica skew
    /// never charges a negative suspension gap. Bypasses `submit`'s
    /// arrival-order assertion: a failed-over arrival is legitimately
    /// older than this replica's newest dispatch.
    pub fn submit_failover(
        &mut self,
        req: &'r Request,
        saved: Option<PreemptedSeq>,
        handoff_t: f64,
    ) {
        assert!(
            self.session.is_some(),
            "submit after drain: the request would be lost"
        );
        let i = self.reqs.len();
        self.push_request(req);
        if let Some(mut s) = saved {
            s.set_ext_id(i as u64);
            let park = match self.free_park.pop() {
                Some(p) => {
                    self.parked[p as usize] = s;
                    p
                }
                None => {
                    self.parked.push(s);
                    (self.parked.len() - 1) as u32
                }
            };
            self.park_of[i] = park;
            self.evict_t[i] = handoff_t.min(self.now());
            self.preemptions[i] += 1;
        }
    }

    /// Mutable engine access for the router's fault wiring (per-replica
    /// link-fault streams are installed through here).
    pub(crate) fn engine_mut(&mut self) -> &mut SimEngine {
        &mut self.engine
    }

    /// The `submit` body minus the arrival-order assertion — shared by the
    /// normal path and [`ContinuousScheduler::submit_failover`].
    fn push_request(&mut self, req: &'r Request) {
        self.reqs.push(req);
        self.lat_sum.push(0.0);
        self.lat_n.push(0);
        self.pending_extra.push(0.0);
        self.charge.push(false);
        self.ttft_val.push(0.0);
        self.first_done.push(false);
        self.prefill_iters.push(0);
        self.evict_t.push(0.0);
        self.slot_of.push(NONE_U32);
        self.park_of.push(NONE_U32);
        self.preemptions.push(0);
        self.done.push(false);
        self.outcome.push(RequestOutcome::Completed);
        // expected *executed iterations*, the token_latency sample budget:
        // under a finite chunk budget a prefill can span up to one
        // iteration per prompt token (see `expected_iterations`) — an
        // under-count here would let the recorder reallocate mid-replay
        // and void the allocation-free contract
        self.expected_tokens += expected_iterations(&req.seq, self.prefill_chunk);
        let (nr, nt) = (self.reqs.len(), self.expected_tokens);
        self.reserve_for(nr, nt);
    }
}

impl<'r> Scheduler<'r> for ContinuousScheduler<'r> {
    fn submit(&mut self, req: &'r Request) {
        assert!(
            self.session.is_some(),
            "submit after drain: the request would be lost"
        );
        debug_assert!(
            self.reqs.last().map_or(true, |p| p.arrival <= req.arrival),
            "requests must be submitted in arrival order"
        );
        self.push_request(req);
    }

    /// One engine iteration (admissions at the boundary included), or one
    /// idle hop to the next arrival.
    fn tick(&mut self) -> bool {
        if self.session.is_none() {
            return false; // drained
        }
        loop {
            let now = self.now();
            // iteration boundary: everyone already here joins the backlog
            while self.next_arrival < self.reqs.len()
                && self.reqs[self.next_arrival].arrival <= now
            {
                let i = self.next_arrival as u32;
                match self.admission {
                    AdmissionPolicy::Fifo => self.waiting.push_back(i),
                    AdmissionPolicy::Classes => {
                        self.class_heap.push(admit_key(self.reqs[i as usize], i))
                    }
                }
                self.next_arrival += 1;
            }
            if self.shedding {
                // timeout aborts happen before admission so the freed
                // slots are reusable at this very boundary
                self.abort_timed_out(now);
            }
            self.admit_and_preempt();
            if self.active.is_empty() {
                if self.next_arrival >= self.reqs.len() {
                    return false; // nothing in flight, nothing queued
                }
                debug_assert!(self.backlog() == 0);
                let t = self.reqs[self.next_arrival].arrival;
                let Some(state) = self.session.take() else {
                    return false; // drained: no session left to idle forward
                };
                let mut session = self.engine.resume_session(state);
                session.idle_until(t);
                self.session = Some(session.suspend());
                continue;
            }
            // execute one forward iteration for everything in flight, the
            // prompt tokens of joining sequences capped by the chunk budget
            let Some(state) = self.session.take() else {
                return false; // drained: no session left to step
            };
            let reqs = &self.reqs;
            let mut session = self.engine.resume_session(state);
            session.set_prefill_limit(self.prefill_chunk);
            let ran = session.step(|id| &reqs[id as usize].seq, &mut self.step);
            debug_assert!(ran, "active slots must step");
            self.session = Some(session.suspend());
            // the boundary the step just advanced to — every sequence the
            // step finished completed at exactly this instant (goodput's
            // within-SLO test below)
            let t_end = self.now();
            self.report.batches += 1; // = engine iterations under this scheduler
            let dt = self.step.latency();
            for &ext in &self.step.executed {
                let i = ext as usize;
                let mut l = dt;
                if self.charge[i] {
                    // the first token after (re)admission carries the
                    // queueing delay / suspension gap
                    l += self.pending_extra[i];
                    self.pending_extra[i] = 0.0;
                    self.charge[i] = false;
                }
                let was_decoding = self.first_done[i];
                if was_decoding {
                    // raw iteration latency of a pure decode step — the
                    // stall metric a joining prompt burst inflates and
                    // chunked prefill caps (charges excluded: queueing is
                    // not an iteration-length effect)
                    self.report.decode_latency.record(dt);
                }
                self.report.token_latency.record(l);
                self.lat_sum[i] += l;
                self.lat_n[i] += 1;
                if !was_decoding {
                    self.prefill_iters[i] += 1;
                    if !self.step.prefilling.contains(&ext) {
                        // the LAST prefill chunk just completed: the first
                        // token exists only now, so TTFT is everything
                        // accumulated from arrival through this iteration
                        // (= `l` itself when the prompt ran as one chunk)
                        self.first_done[i] = true;
                        self.ttft_val[i] = self.lat_sum[i];
                        self.report.ttft.record(self.ttft_val[i]);
                    }
                }
            }
            // zero-budget prefill slots rode the iteration without
            // executing; the gap is charged to their next executed chunk,
            // exactly like a suspension gap
            for &ext in &self.step.stalled {
                let i = ext as usize;
                self.pending_extra[i] += dt;
                self.charge[i] = true;
            }
            for &ext in &self.step.finished {
                let i = ext as usize;
                if self.lat_n[i] > 0 {
                    self.report
                        .request_latency
                        .record(self.lat_sum[i] / self.lat_n[i] as f64);
                }
                if self.lat_n[i] > self.prefill_iters[i] {
                    // mean decode-token latency: everything after the last
                    // prefill chunk, averaged over the decode iterations
                    let n_decode = (self.lat_n[i] - self.prefill_iters[i]) as f64;
                    self.report
                        .tpot
                        .record((self.lat_sum[i] - self.ttft_val[i]) / n_decode);
                }
                self.report.tokens += self.reqs[i].seq.total_tokens() as u64;
                self.report.requests += 1;
                let r = self.reqs[i];
                if r.class.slo.map_or(true, |s| t_end <= r.arrival + s) {
                    self.report.goodput_tokens += r.seq.total_tokens() as u64;
                }
                self.done[i] = true;
                self.slot_of[i] = NONE_U32;
                self.finished += 1;
                if let Some(p) = self.active.iter().position(|&r| r as usize == i) {
                    self.active.swap_remove(p);
                }
            }
            return true;
        }
    }

    fn drain(&mut self) -> ServeReport {
        while self.tick() {}
        match self.session.take() {
            Some(state) => {
                self.report.makespan = SimTime::from_f64(self.engine.resume_session(state).finish());
                self.report.absorb_sim_stats(&self.engine);
                std::mem::take(&mut self.report)
            }
            // one-shot: the session is gone, so is the report
            None => ServeReport::default(),
        }
    }
}

/// Continuous batching with **chunked prefill**: identical to
/// [`ContinuousScheduler`] except that a joining prompt executes at most
/// `prefill_chunk` tokens per iteration (the shared per-iteration budget
/// is granted to prefilling sequences in slot order; decode tokens are
/// never budgeted). Splitting the prefill across iteration boundaries
/// caps the latency an iteration-0 prompt burst inflicts on every
/// in-flight decode — the prompt-level analogue of the head-of-line
/// blocking continuous batching removed at the request level.
///
/// Semantics under chunking:
/// * the session holds the sequence in a `Prefilling(consumed..)` state;
///   each chunk routes its proportional share of the prompt's per-layer
///   expert counts (exact-telescoping split — any chunking accumulates
///   the identical per-sequence EAM), feeding prediction/prefetch the
///   accumulating routing signature;
/// * TTFT is recorded at the iteration the **last** chunk completes (the
///   first output token exists only then), TPOT over the decode
///   iterations that follow, and EAMC recall feedback still lands at
///   retirement over the full accumulated trace;
/// * a prefilling sequence granted zero budget (earlier slots consumed
///   the iteration's chunk) stalls for the iteration and the gap is
///   charged to its next executed chunk, like a suspension gap.
///
/// With `prefill_chunk = u32::MAX` (unlimited) the replay is **bitwise
/// identical** to [`ContinuousScheduler`] — pinned on the determinism
/// grid in `rust/tests/scheduler.rs`; `perf_prefill` measures what finite
/// chunks buy (capped decode p99) and cost (slightly more iterations).
pub struct ChunkedScheduler<'r> {
    inner: ContinuousScheduler<'r>,
}

impl<'r> ChunkedScheduler<'r> {
    /// `prefill_chunk` is the per-iteration prompt-token budget (>= 1;
    /// `u32::MAX` = unlimited, reproducing the continuous scheduler).
    pub fn new(
        engine: SimEngine,
        batcher: Batcher,
        admission: AdmissionPolicy,
        prefill_chunk: u32,
    ) -> ChunkedScheduler<'r> {
        ChunkedScheduler {
            inner: ContinuousScheduler::new(engine, batcher, admission)
                .with_prefill_chunk(prefill_chunk),
        }
    }

    pub fn engine(&self) -> &SimEngine {
        self.inner.engine()
    }

    pub fn into_engine(self) -> SimEngine {
        self.inner.into_engine()
    }

    /// Virtual time of the current iteration boundary.
    #[inline]
    pub fn now(&self) -> f64 {
        self.inner.now()
    }

    /// Anything submitted and not yet finished?
    #[inline]
    pub fn has_work(&self) -> bool {
        self.inner.has_work()
    }

    /// Dispatched-but-unfinished request count.
    #[inline]
    pub fn load(&self) -> usize {
        self.inner.load()
    }

    /// See [`ContinuousScheduler::next_event_bound`] — including the
    /// bound-stability contract the router's event calendar relies on.
    #[inline]
    pub fn next_event_bound(&self) -> Option<f64> {
        self.inner.next_event_bound()
    }

    /// See [`ContinuousScheduler::reserve_for`].
    pub fn reserve_for(&mut self, total_requests: usize, total_tokens: usize) {
        self.inner.reserve_for(total_requests, total_tokens);
    }

    /// Per-request outcomes (id, class, latency, TTFT, preemption count).
    pub fn request_stats(&self) -> Vec<RequestStat> {
        self.inner.request_stats()
    }

    /// See [`ContinuousScheduler::set_shedding`].
    pub fn set_shedding(&mut self, on: bool) {
        self.inner.set_shedding(on);
    }
}

impl<'r> Scheduler<'r> for ChunkedScheduler<'r> {
    fn submit(&mut self, req: &'r Request) {
        self.inner.submit(req);
    }

    fn tick(&mut self) -> bool {
        self.inner.tick()
    }

    fn drain(&mut self) -> ServeReport {
        self.inner.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheKind;
    use crate::engine::{ComputeModel, EngineConfig};
    use crate::memory::{Link, Tier, TierConfig};
    use crate::model::ModelSpec;
    use crate::trace::Eamc;
    use crate::util::Rng;
    use crate::workload::{ArrivalProcess, DatasetPreset, RequestClass, Workload};

    fn mk_requests(n: usize, rps: f64, seed: u64) -> (ModelSpec, Vec<Request>, Workload) {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), seed);
        let mut rng = Rng::new(seed ^ 0xabc);
        let proc = ArrivalProcess::Poisson { rps };
        let mut t = 0.0;
        let reqs = (0..n)
            .map(|i| {
                t += proc.next_gap(&mut rng);
                Request::new(i as u64, t, w.gen_sequence())
            })
            .collect();
        (spec, reqs, w)
    }

    fn engine_for(spec: &ModelSpec, w: &mut Workload) -> SimEngine {
        let ds = w.gen_eam_dataset(40);
        let eamc = Eamc::construct(10, &ds, 5);
        let tier = TierConfig {
            gpu_capacity: 64,
            dram_capacity: 200,
            backing: Tier::Ssd,
            ssd_to_dram: Link::new(6.0, 50e-6),
            dram_to_gpu: Link::new(32.0, 10e-6),
            n_gpus: 1,
            demand_extra_latency: SimTime::ZERO,
            demand_bw_factor: 1.0,
            gpu_policy: CacheKind::Activation,
            dram_policy: CacheKind::Activation,
            oracle_trace: Vec::new(),
            activation_terms: (true, true),
            prefetch_gpu_budget: 0.5,
        };
        SimEngine::new(
            spec.clone(),
            tier,
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        )
    }

    /// Regenerate the `(n, rps, seed)` trace and serve it statically —
    /// engine built from the same advanced workload stream the pre-trait
    /// tests used, so the pinned assertions replay identically.
    fn run_static(n: usize, rps: f64, seed: u64, batcher: Batcher) -> ServeReport {
        let (spec, reqs, mut w) = mk_requests(n, rps, seed);
        let eng = engine_for(&spec, &mut w);
        let mut s = StaticScheduler::new(eng, batcher);
        s.submit_all(&reqs);
        s.drain()
    }

    fn run_continuous(
        n: usize,
        rps: f64,
        seed: u64,
        batcher: Batcher,
        admission: AdmissionPolicy,
    ) -> (ServeReport, Vec<RequestStat>) {
        let (spec, reqs, mut w) = mk_requests(n, rps, seed);
        let eng = engine_for(&spec, &mut w);
        let mut s = ContinuousScheduler::new(eng, batcher, admission);
        s.submit_all(&reqs);
        let report = s.drain();
        let stats = s.request_stats();
        (report, stats)
    }

    #[test]
    fn batcher_respects_max_batch() {
        let (_, reqs, _) = mk_requests(50, 100.0, 1); // rapid arrivals
        let refs: Vec<&Request> = reqs.iter().collect();
        let b = Batcher::new(16, 1.0);
        let (_, end) = b.next_batch(&refs, 0, 0.0);
        assert!(end <= 16);
    }

    #[test]
    fn batcher_respects_max_wait_under_low_load() {
        let (_, reqs, _) = mk_requests(3, 0.1, 2); // sparse arrivals
        let refs: Vec<&Request> = reqs.iter().collect();
        let b = Batcher::new(16, 1.0);
        let (dispatch, end) = b.next_batch(&refs, 0, 0.0);
        // window expires before batch fills: dispatch ~ first arrival + 1s
        assert!((dispatch - (reqs[0].arrival + 1.0)).abs() < 1e-9);
        assert!(end >= 1);
    }

    #[test]
    fn batcher_waits_for_engine() {
        let (_, reqs, _) = mk_requests(5, 10.0, 3);
        let refs: Vec<&Request> = reqs.iter().collect();
        let b = Batcher::new(4, 0.5);
        let engine_free = reqs[4].arrival + 100.0;
        let (dispatch, end) = b.next_batch(&refs, 0, engine_free);
        assert_eq!(dispatch, engine_free);
        assert_eq!(end, 4, "everyone arrived while engine busy rides along");
    }

    #[test]
    #[should_panic(expected = "max_wait must be finite")]
    fn batcher_rejects_nan_max_wait() {
        Batcher::new(4, f64::NAN);
    }

    #[test]
    #[should_panic(expected = "max_wait must be finite")]
    fn batcher_rejects_negative_max_wait() {
        Batcher::new(4, -0.5);
    }

    #[test]
    #[should_panic(expected = "max_wait must be finite")]
    fn batcher_rejects_infinite_max_wait() {
        Batcher::new(4, f64::INFINITY);
    }

    #[test]
    fn static_scheduler_processes_all_requests() {
        let report = run_static(12, 2.0, 4, Batcher::new(8, 0.5));
        let (_, reqs, _) = mk_requests(12, 2.0, 4); // same deterministic trace
        assert_eq!(report.requests, 12);
        assert!(report.batches >= 2);
        assert!(report.token_latency.len() > 0);
        assert!(report.token_throughput() > 0.0);
        assert!(report.makespan >= reqs.last().unwrap().arrival);
        assert_eq!(report.ttft.len(), 12, "one TTFT sample per request");
        assert!(report.demands > 0, "sim stats must flow into the report");
    }

    #[test]
    fn continuous_scheduler_processes_all_requests() {
        let (report, stats) = run_continuous(12, 2.0, 4, Batcher::new(8, 0.5), AdmissionPolicy::Fifo);
        let (_, reqs, _) = mk_requests(12, 2.0, 4); // same deterministic trace
        assert_eq!(report.requests, 12);
        assert!(report.batches >= 12, "at least one iteration per request");
        assert!(report.token_latency.len() > 0);
        assert!(report.token_throughput() > 0.0);
        assert!(report.makespan >= reqs.last().unwrap().arrival);
        assert_eq!(
            report.request_latency.len(),
            12,
            "every request records a completion latency"
        );
        assert_eq!(report.ttft.len(), 12);
        assert!(stats.iter().all(|s| s.finished && s.preemptions == 0));
    }

    #[test]
    fn ttft_tpot_decompose_request_latency() {
        let (mut report, _) =
            run_continuous(6, 1.0, 8, Batcher::new(4, 0.5), AdmissionPolicy::Fifo);
        assert_eq!(report.ttft.len() as u64, report.requests);
        assert!(report.tpot.len() as u64 <= report.requests);
        assert!(report.ttft.p50() > 0.0);
        assert!(report.tpot.p50() > 0.0);
    }

    #[test]
    fn classes_with_default_requests_is_bitwise_fifo() {
        let (fifo, _) = run_continuous(20, 20.0, 6, Batcher::new(4, 0.1), AdmissionPolicy::Fifo);
        let (cls, _) = run_continuous(20, 20.0, 6, Batcher::new(4, 0.1), AdmissionPolicy::Classes);
        assert_eq!(fifo.requests, cls.requests);
        assert_eq!(fifo.tokens, cls.tokens);
        assert_eq!(fifo.batches, cls.batches);
        assert_eq!(fifo.makespan.to_bits(), cls.makespan.to_bits());
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(fifo.token_latency.samples()),
            bits(cls.token_latency.samples()),
            "default classes must not change the replay"
        );
    }

    #[test]
    fn continuous_beats_static_p99_under_overload() {
        // the head-of-line blocking continuous batching removes: under a
        // Poisson overload, late arrivals no longer wait for whole batches
        // to run to completion, so tail request latency must improve.
        let mut stat = run_static(30, 50.0, 5, Batcher::new(4, 0.1));
        let (mut cont, _) = run_continuous(30, 50.0, 5, Batcher::new(4, 0.1), AdmissionPolicy::Fifo);
        assert_eq!(cont.requests, stat.requests);
        assert_eq!(cont.tokens, stat.tokens);
        assert!(
            cont.request_latency.p99() < stat.request_latency.p99(),
            "continuous p99 {} must beat static p99 {} under overload",
            cont.request_latency.p99(),
            stat.request_latency.p99()
        );
    }

    #[test]
    fn queueing_delay_shows_up_under_overload() {
        let mut report = run_static(30, 50.0, 5, Batcher::new(4, 0.1)); // heavy overload
        let mut report2 = run_static(30, 0.2, 5, Batcher::new(4, 0.1)); // light load
        assert!(
            report.request_latency.p99() > report2.request_latency.p99(),
            "overloaded p99 {} must exceed light p99 {}",
            report.request_latency.p99(),
            report2.request_latency.p99()
        );
    }

    #[test]
    fn preemption_lowers_high_priority_p99_under_overload() {
        // The acceptance contract of the priority tentpole: under a mixed
        // overload, interactive requests must see lower tail latency with
        // class-aware admission + preemption than with FIFO admission.
        let run = |admission: AdmissionPolicy| -> Vec<RequestStat> {
            let (spec, mut reqs, mut w) = mk_requests(30, 50.0, 9);
            for (i, r) in reqs.iter_mut().enumerate() {
                r.class = if i % 4 == 0 {
                    RequestClass::interactive().with_slo(2.0)
                } else {
                    RequestClass::batch()
                };
            }
            let eng = engine_for(&spec, &mut w);
            let mut s = ContinuousScheduler::new(eng, Batcher::new(4, 0.1), admission);
            s.submit_all(&reqs);
            let _ = s.drain();
            s.request_stats()
        };
        let hi_p99 = |stats: &[RequestStat]| {
            let mut rec = LatencyRecorder::new();
            for s in stats {
                if s.priority == Priority::Interactive {
                    assert!(s.finished, "interactive request must finish");
                    rec.record(s.latency.to_f64());
                }
            }
            assert!(rec.len() > 0);
            rec.p99()
        };
        let fifo_stats = run(AdmissionPolicy::Fifo);
        let cls_stats = run(AdmissionPolicy::Classes);
        let fifo_p99 = hi_p99(&fifo_stats);
        let cls_p99 = hi_p99(&cls_stats);
        assert!(
            cls_p99 < fifo_p99,
            "priority+preemption interactive p99 {cls_p99} must beat FIFO {fifo_p99}"
        );
        // preemption actually fired on the batch tier
        assert!(
            cls_stats.iter().any(|s| s.preemptions > 0),
            "overload with mixed classes must trigger voluntary preemption"
        );
        // and every batch-tier request still finishes (no starvation)
        assert!(cls_stats.iter().all(|s| s.finished));
    }

    fn run_chunked(
        n: usize,
        rps: f64,
        seed: u64,
        batcher: Batcher,
        admission: AdmissionPolicy,
        chunk: u32,
    ) -> (ServeReport, Vec<RequestStat>) {
        let (spec, reqs, mut w) = mk_requests(n, rps, seed);
        let eng = engine_for(&spec, &mut w);
        let mut s = ChunkedScheduler::new(eng, batcher, admission, chunk);
        s.submit_all(&reqs);
        let report = s.drain();
        let stats = s.request_stats();
        (report, stats)
    }

    #[test]
    fn chunked_with_unlimited_budget_is_bitwise_continuous() {
        let (cont, _) = run_continuous(20, 20.0, 6, Batcher::new(4, 0.1), AdmissionPolicy::Fifo);
        let (chk, _) = run_chunked(
            20,
            20.0,
            6,
            Batcher::new(4, 0.1),
            AdmissionPolicy::Fifo,
            u32::MAX,
        );
        assert_eq!(cont.requests, chk.requests);
        assert_eq!(cont.tokens, chk.tokens);
        assert_eq!(cont.batches, chk.batches);
        assert_eq!(cont.makespan.to_bits(), chk.makespan.to_bits());
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(cont.token_latency.samples()),
            bits(chk.token_latency.samples()),
            "unlimited chunk budget must not change the replay"
        );
        assert_eq!(bits(cont.ttft.samples()), bits(chk.ttft.samples()));
        assert_eq!(bits(cont.tpot.samples()), bits(chk.tpot.samples()));
        assert_eq!(
            bits(cont.decode_latency.samples()),
            bits(chk.decode_latency.samples())
        );
    }

    #[test]
    fn chunked_finite_serves_all_work_across_more_iterations() {
        // chunk below the preset's minimum prompt: every prefill splits, so
        // the same work takes strictly more iterations, every request still
        // finishes, and TTFT/decode accounting stays per-request complete
        let (cont, _) = run_continuous(16, 8.0, 4, Batcher::new(4, 0.1), AdmissionPolicy::Fifo);
        let (chk, stats) = run_chunked(16, 8.0, 4, Batcher::new(4, 0.1), AdmissionPolicy::Fifo, 8);
        assert_eq!(chk.requests, cont.requests);
        assert_eq!(chk.tokens, cont.tokens);
        assert!(
            chk.batches > cont.batches,
            "splitting every prefill must add iterations ({} vs {})",
            chk.batches,
            cont.batches
        );
        assert_eq!(chk.ttft.len() as u64, chk.requests);
        assert_eq!(chk.request_latency.len() as u64, chk.requests);
        assert!(chk.decode_latency.len() > 0);
        assert!(stats.iter().all(|s| s.finished && s.ttft > 0.0));
    }

    #[test]
    fn chunked_caps_decode_stall_from_a_joining_long_prompt() {
        // The acceptance scenario in miniature: one sequence decodes while
        // a long-prompt request joins. Continuous executes the whole prompt
        // inside one shared iteration — every in-flight decode eats the
        // burst; chunked caps the per-iteration prompt share, so the worst
        // decode-step latency must drop.
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let synth = |prompt: usize, gen: usize, hot: usize| -> crate::workload::SequenceActivation {
            let route = |tokens: u32| -> Vec<Vec<(u16, u32)>> {
                (0..spec.n_layers)
                    .map(|l| vec![(((hot + l) % spec.experts_per_layer) as u16, tokens)])
                    .collect()
            };
            let mut routes = vec![route(prompt as u32)];
            for _ in 0..gen {
                routes.push(route(1));
            }
            crate::workload::SequenceActivation {
                task: 0,
                prompt_len: prompt,
                gen_len: gen,
                routes,
            }
        };
        let run = |chunk: u32| -> ServeReport {
            let mut w = {
                let (_, _, w) = mk_requests(1, 1.0, 7);
                w
            };
            let eng = engine_for(&spec, &mut w);
            let reqs = vec![
                Request::new(0, 0.0, synth(8, 200, 0)),
                Request::new(1, 0.05, synth(400, 4, 7)),
            ];
            let mut s = ChunkedScheduler::new(eng, Batcher::new(4, 0.1), AdmissionPolicy::Fifo, chunk);
            s.submit_all(&reqs);
            s.drain()
        };
        let mut cont = run(u32::MAX);
        let mut chk = run(16);
        assert_eq!(cont.requests, 2);
        assert_eq!(chk.requests, 2);
        assert_eq!(cont.tokens, chk.tokens);
        assert!(
            chk.decode_latency.max() < cont.decode_latency.max(),
            "chunked worst decode step {} must beat continuous {}",
            chk.decode_latency.max(),
            cont.decode_latency.max()
        );
    }

    #[test]
    fn chunked_composes_with_classes_preemption() {
        let (spec, mut reqs, mut w) = mk_requests(30, 50.0, 9);
        for (i, r) in reqs.iter_mut().enumerate() {
            r.class = if i % 4 == 0 {
                RequestClass::interactive().with_slo(2.0)
            } else {
                RequestClass::batch()
            };
        }
        let eng = engine_for(&spec, &mut w);
        let mut s = ChunkedScheduler::new(eng, Batcher::new(4, 0.1), AdmissionPolicy::Classes, 16);
        s.submit_all(&reqs);
        let report = s.drain();
        let stats = s.request_stats();
        assert_eq!(report.requests, 30);
        assert!(stats.iter().all(|st| st.finished), "no starvation under chunking");
        assert!(
            stats.iter().any(|st| st.preemptions > 0),
            "mixed-class overload must still trigger preemption under chunking"
        );
    }

    #[test]
    fn admit_key_order_matches_scan_key_semantics() {
        let (_, reqs, _) = mk_requests(1, 1.0, 3);
        let seq = reqs[0].seq.clone();
        let mk = |pri: Priority, slo: Option<f64>, arrival: f64, idx: u32| {
            let mut r = Request::new(idx as u64, arrival, seq.clone());
            r.class = RequestClass { priority: pri, slo };
            admit_key(&r, idx)
        };
        // priority dominates everything
        assert!(mk(Priority::Interactive, None, 9.0, 5) > mk(Priority::Batch, Some(0.1), 0.0, 0));
        // finite deadline beats no-SLO within a tier
        assert!(mk(Priority::Normal, Some(1.0), 0.0, 1) > mk(Priority::Normal, None, 0.0, 0));
        // tighter deadline first
        assert!(mk(Priority::Normal, Some(1.0), 0.0, 1) > mk(Priority::Normal, Some(5.0), 0.0, 0));
        // deadline tie -> earlier arrival
        assert!(mk(Priority::Normal, None, 1.0, 2) > mk(Priority::Normal, None, 2.0, 1));
        // full tie -> lower index
        assert!(mk(Priority::Normal, None, 1.0, 1) > mk(Priority::Normal, None, 1.0, 2));
    }

    #[test]
    fn drain_is_one_shot_for_both_schedulers() {
        let (spec, reqs, mut w) = mk_requests(4, 1.0, 14);
        let eng = engine_for(&spec, &mut w);
        let mut s = ContinuousScheduler::new(eng, Batcher::new(4, 0.5), AdmissionPolicy::Fifo);
        s.submit_all(&reqs);
        let first = s.drain();
        assert_eq!(first.requests, 4);
        let second = s.drain();
        assert_eq!(second.requests, 0, "second drain must be empty");
        assert_eq!(second.demands, 0, "no double-counted sim stats");
        assert_eq!(second.makespan, 0.0);

        let (spec2, reqs2, mut w2) = mk_requests(4, 1.0, 14);
        let eng2 = engine_for(&spec2, &mut w2);
        let mut st = StaticScheduler::new(eng2, Batcher::new(4, 0.5));
        st.submit_all(&reqs2);
        assert_eq!(st.drain().requests, 4);
        let again = st.drain();
        assert_eq!(again.requests, 0);
        assert_eq!(again.demands, 0);
    }

    #[test]
    fn try_new_propagates_validation_errors() {
        assert!(Batcher::try_new(0, 0.5).is_err());
        assert!(Batcher::try_new(4, f64::NAN).is_err());
        assert!(Batcher::try_new(4, -1.0).is_err());
        let b = Batcher::try_new(4, 0.5).unwrap();
        assert_eq!(b.max_batch, 4);
    }

    #[test]
    fn goodput_equals_throughput_without_slos() {
        // no SLOs anywhere: every completed token is a goodput token
        let (report, _) = run_continuous(12, 2.0, 4, Batcher::new(8, 0.5), AdmissionPolicy::Fifo);
        assert_eq!(report.goodput_tokens, report.tokens);
        assert_eq!(report.goodput().to_bits(), report.token_throughput().to_bits());
        assert_eq!(report.shed, 0);
        assert_eq!(report.timed_out, 0);
    }

    #[test]
    fn shedding_converts_hopeless_requests_into_shed_or_timeout() {
        let run = |shedding: bool| {
            let (spec, mut reqs, mut w) = mk_requests(30, 50.0, 9);
            for (i, r) in reqs.iter_mut().enumerate() {
                r.class = if i % 2 == 0 {
                    RequestClass::interactive().with_slo(0.05) // hopeless under overload
                } else {
                    RequestClass::batch()
                };
            }
            let eng = engine_for(&spec, &mut w);
            let mut s = ContinuousScheduler::new(eng, Batcher::new(2, 0.1), AdmissionPolicy::Classes);
            s.set_shedding(shedding);
            s.submit_all(&reqs);
            let report = s.drain();
            (report, s.request_stats())
        };
        let (off, off_stats) = run(false);
        assert_eq!(off.requests, 30, "shedding off: everything completes");
        assert_eq!(off.shed + off.timed_out, 0);
        assert!(off_stats.iter().all(|st| st.outcome == RequestOutcome::Completed));

        let (on, on_stats) = run(true);
        assert!(
            on.shed + on.timed_out > 0,
            "a 50 rps overload with 50 ms SLOs must shed or abort"
        );
        assert_eq!(on.requests + on.shed + on.timed_out, 30);
        assert!(on.goodput_tokens <= on.tokens);
        // every request still reaches a terminal state; SLO-less batch
        // requests are never shed
        assert!(on_stats.iter().all(|st| st.finished));
        assert!(on_stats
            .iter()
            .filter(|st| st.priority == Priority::Batch)
            .all(|st| st.outcome == RequestOutcome::Completed));
        // shedding frees capacity: the work the survivors represent is a
        // subset, so makespan cannot grow
        assert!(on.makespan <= off.makespan);
    }

    #[test]
    fn check_max_wait_is_shared_contract() {
        assert!(check_max_wait(0.0).is_ok());
        assert!(check_max_wait(1.5).is_ok());
        assert!(check_max_wait(f64::NAN).is_err());
        assert!(check_max_wait(-1.0).is_err());
        assert!(check_max_wait(f64::INFINITY).is_err());
    }
}
