//! Request router + batcher + serving loop (paper §8.2 methodology).
//!
//! Two schedulers share the engine:
//! * [`serve`] — **static** run-to-completion batches: requests accumulate
//!   until either `max_batch` sequences or `max_wait` elapses from the
//!   first queued request (16 / 1s in the paper, both from AlpaServe),
//!   then the whole batch runs to completion.
//! * [`serve_continuous`] — **continuous batching** on the resumable
//!   [`crate::engine::BatchSession`]: arrivals join free slots at every
//!   iteration boundary and sequences retire the iteration they finish,
//!   removing the static path's head-of-line blocking under load.
//!
//! Both replays are fully deterministic in virtual time.

use crate::engine::{FeedbackMode, SimEngine, StepResult};
use crate::metrics::LatencyRecorder;
use crate::workload::Request;

/// Batching policy. `max_wait` only applies to the static scheduler; the
/// continuous scheduler admits at iteration boundaries and never holds a
/// request back to grow a batch.
#[derive(Debug, Clone, Copy)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: f64,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: f64) -> Batcher {
        assert!(max_batch >= 1);
        // a NaN window would poison `next_batch`'s dispatch arithmetic and
        // silently mis-batch every request; reject it (and negatives) here
        assert!(
            max_wait.is_finite() && max_wait >= 0.0,
            "max_wait must be finite and >= 0, got {max_wait}"
        );
        Batcher {
            max_batch,
            max_wait,
        }
    }

    /// Given arrival-sorted requests and the engine-free time, decide the
    /// next batch: returns `(dispatch_time, end_index_exclusive)` for the
    /// batch starting at `start_idx`.
    pub fn next_batch(
        &self,
        requests: &[Request],
        start_idx: usize,
        engine_free: f64,
    ) -> (f64, usize) {
        let first = &requests[start_idx];
        let window_end = first.arrival + self.max_wait;
        // time at which the batch would be full
        let full_idx = start_idx + self.max_batch - 1;
        let fill_time = if full_idx < requests.len() {
            requests[full_idx].arrival
        } else {
            f64::INFINITY
        };
        // dispatch when full or window expires — but never before the
        // engine is free (requests keep accumulating while it's busy).
        let policy_time = fill_time.min(window_end).max(first.arrival);
        let dispatch = policy_time.max(engine_free);
        // everyone who has arrived by the dispatch instant rides along
        let mut end = start_idx;
        while end < requests.len()
            && end - start_idx < self.max_batch
            && requests[end].arrival <= dispatch
        {
            end += 1;
        }
        debug_assert!(end > start_idx);
        (dispatch, end)
    }
}

/// Outcome of one serving replay.
#[derive(Debug, Default)]
pub struct ServeReport {
    /// Per-forward-iteration (per-token) latency; the first iteration of a
    /// request carries its queueing delay.
    pub token_latency: LatencyRecorder,
    /// Per-request mean token latency (queueing included), recorded the
    /// iteration the request actually finishes.
    pub request_latency: LatencyRecorder,
    pub requests: u64,
    pub tokens: u64,
    /// Static scheduler: dispatched batches. Continuous scheduler: engine
    /// iterations executed (there is no batch boundary to count).
    pub batches: u64,
    /// Virtual makespan of the replay.
    pub makespan: f64,
}

impl ServeReport {
    pub fn token_throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.makespan
        }
    }
}

/// Replay `requests` (sorted by arrival) through `engine` with `batcher`.
pub fn serve(engine: &mut SimEngine, batcher: Batcher, requests: &[Request]) -> ServeReport {
    let mut report = ServeReport::default();
    let mut idx = 0;
    let mut engine_free = engine.now();
    while idx < requests.len() {
        let (dispatch, end) = batcher.next_batch(requests, idx, engine_free);
        let batch = &requests[idx..end];
        let seqs: Vec<_> = batch.iter().map(|r| r.seq.clone()).collect();
        let result = engine.run_batch(&seqs, dispatch);

        // queueing delay per request = dispatch - arrival
        for r in batch {
            let queue_delay = dispatch - r.arrival;
            let n_iters = r.seq.iterations().min(result.token_latencies.len());
            let mut mean = 0.0;
            for (i, &lat) in result.token_latencies[..n_iters].iter().enumerate() {
                let l = if i == 0 { lat + queue_delay } else { lat };
                report.token_latency.record(l);
                mean += l;
            }
            if n_iters > 0 {
                report.request_latency.record(mean / n_iters as f64);
            }
            report.tokens += r.seq.total_tokens() as u64;
        }
        report.requests += batch.len() as u64;
        report.batches += 1;
        engine_free = result.finish;
        idx = end;
    }
    report.makespan = engine_free;
    report
}

/// Replay `requests` (sorted by arrival) with **continuous batching**: one
/// resumable [`crate::engine::BatchSession`] spans the whole replay;
/// arrivals are admitted into free slots at every iteration boundary (up
/// to `batcher.max_batch` in flight) and sequences retire — recording
/// their completion latency — the iteration they finish, not at the batch
/// tail.
///
/// Degenerate case: with `max_batch = 1` the admission instants equal the
/// static scheduler's dispatch instants (`max(arrival, engine-free)`), so
/// the replay is bitwise identical to [`serve`] — pinned by the
/// differential suite in `rust/tests/parallel.rs`.
pub fn serve_continuous(
    engine: &mut SimEngine,
    batcher: Batcher,
    requests: &[Request],
) -> ServeReport {
    let mut report = ServeReport::default();
    let n = requests.len();
    // per-request accounting (request ids double as session external ids)
    let mut lat_sum = vec![0.0f64; n];
    let mut lat_n = vec![0u32; n];
    let mut queue_delay = vec![0.0f64; n];
    let mut first_pending = vec![false; n];
    let mut step = StepResult::default();
    let start = engine.now();
    let mut session = engine.begin_session(start, FeedbackMode::Immediate);
    let mut next = 0usize; // next request to admit
    loop {
        // iteration boundary: fill free slots with everyone already here
        while next < n
            && session.active() < batcher.max_batch
            && requests[next].arrival <= session.now()
        {
            let r = &requests[next];
            session.admit(next as u64, &r.seq);
            queue_delay[next] = session.now() - r.arrival;
            first_pending[next] = true;
            next += 1;
        }
        if session.active() == 0 {
            if next >= n {
                break;
            }
            session.idle_until(requests[next].arrival);
            continue;
        }
        let ran = session.step(|id| &requests[id as usize].seq, &mut step);
        debug_assert!(ran, "active slots must step");
        report.batches += 1; // = engine iterations under this scheduler
        let dt = step.latency();
        for &rid in &step.executed {
            let rid = rid as usize;
            let mut l = dt;
            if first_pending[rid] {
                // the request's first iteration carries its queueing delay
                l += queue_delay[rid];
                first_pending[rid] = false;
            }
            report.token_latency.record(l);
            lat_sum[rid] += l;
            lat_n[rid] += 1;
        }
        for &rid in &step.finished {
            let rid = rid as usize;
            if lat_n[rid] > 0 {
                report
                    .request_latency
                    .record(lat_sum[rid] / lat_n[rid] as f64);
            }
            report.tokens += requests[rid].seq.total_tokens() as u64;
            report.requests += 1;
        }
    }
    report.makespan = session.finish();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheKind;
    use crate::engine::{ComputeModel, EngineConfig};
    use crate::memory::{Link, Tier, TierConfig};
    use crate::model::ModelSpec;
    use crate::trace::Eamc;
    use crate::util::Rng;
    use crate::workload::{ArrivalProcess, DatasetPreset, Workload};

    fn mk_requests(n: usize, rps: f64, seed: u64) -> (ModelSpec, Vec<Request>, Workload) {
        let spec = ModelSpec::preset("switch-base-32").unwrap();
        let mut w = Workload::new(&spec, DatasetPreset::by_name("mixed").unwrap(), seed);
        let mut rng = Rng::new(seed ^ 0xabc);
        let proc = ArrivalProcess::Poisson { rps };
        let mut t = 0.0;
        let reqs = (0..n)
            .map(|i| {
                t += proc.next_gap(&mut rng);
                Request {
                    id: i as u64,
                    arrival: t,
                    seq: w.gen_sequence(),
                }
            })
            .collect();
        (spec, reqs, w)
    }

    fn engine_for(spec: &ModelSpec, w: &mut Workload) -> SimEngine {
        let ds = w.gen_eam_dataset(40);
        let eamc = Eamc::construct(10, &ds, 5);
        let tier = TierConfig {
            gpu_capacity: 64,
            dram_capacity: 200,
            backing: Tier::Ssd,
            ssd_to_dram: Link::new(6.0, 50e-6),
            dram_to_gpu: Link::new(32.0, 10e-6),
            n_gpus: 1,
            demand_extra_latency: 0.0,
            demand_bw_factor: 1.0,
            cache_kind: CacheKind::Activation,
            oracle_trace: Vec::new(),
            activation_terms: (true, true),
            prefetch_gpu_budget: 0.5,
        };
        SimEngine::new(
            spec.clone(),
            tier,
            eamc,
            ComputeModel::a5000(),
            EngineConfig::default(),
        )
    }

    #[test]
    fn batcher_respects_max_batch() {
        let (_, reqs, _) = mk_requests(50, 100.0, 1); // rapid arrivals
        let b = Batcher::new(16, 1.0);
        let (_, end) = b.next_batch(&reqs, 0, 0.0);
        assert!(end <= 16);
    }

    #[test]
    fn batcher_respects_max_wait_under_low_load() {
        let (_, reqs, _) = mk_requests(3, 0.1, 2); // sparse arrivals
        let b = Batcher::new(16, 1.0);
        let (dispatch, end) = b.next_batch(&reqs, 0, 0.0);
        // window expires before batch fills: dispatch ~ first arrival + 1s
        assert!((dispatch - (reqs[0].arrival + 1.0)).abs() < 1e-9);
        assert!(end >= 1);
    }

    #[test]
    fn batcher_waits_for_engine() {
        let (_, reqs, _) = mk_requests(5, 10.0, 3);
        let b = Batcher::new(4, 0.5);
        let engine_free = reqs[4].arrival + 100.0;
        let (dispatch, end) = b.next_batch(&reqs, 0, engine_free);
        assert_eq!(dispatch, engine_free);
        assert_eq!(end, 4, "everyone arrived while engine busy rides along");
    }

    #[test]
    fn serve_processes_all_requests() {
        let (spec, reqs, mut w) = mk_requests(12, 2.0, 4);
        let mut eng = engine_for(&spec, &mut w);
        let report = serve(&mut eng, Batcher::new(8, 0.5), &reqs);
        assert_eq!(report.requests, 12);
        assert!(report.batches >= 2);
        assert!(report.token_latency.len() > 0);
        assert!(report.token_throughput() > 0.0);
        assert!(report.makespan >= reqs.last().unwrap().arrival);
    }

    #[test]
    #[should_panic(expected = "max_wait must be finite")]
    fn batcher_rejects_nan_max_wait() {
        Batcher::new(4, f64::NAN);
    }

    #[test]
    #[should_panic(expected = "max_wait must be finite")]
    fn batcher_rejects_negative_max_wait() {
        Batcher::new(4, -0.5);
    }

    #[test]
    #[should_panic(expected = "max_wait must be finite")]
    fn batcher_rejects_infinite_max_wait() {
        Batcher::new(4, f64::INFINITY);
    }

    #[test]
    fn serve_continuous_processes_all_requests() {
        let (spec, reqs, mut w) = mk_requests(12, 2.0, 4);
        let mut eng = engine_for(&spec, &mut w);
        let report = serve_continuous(&mut eng, Batcher::new(8, 0.5), &reqs);
        assert_eq!(report.requests, 12);
        assert!(report.batches >= 12, "at least one iteration per request");
        assert!(report.token_latency.len() > 0);
        assert!(report.token_throughput() > 0.0);
        assert!(report.makespan >= reqs.last().unwrap().arrival);
        assert_eq!(
            report.request_latency.len(),
            12,
            "every request records a completion latency"
        );
    }

    #[test]
    fn continuous_beats_static_p99_under_overload() {
        // the head-of-line blocking continuous batching removes: under a
        // Poisson overload, late arrivals no longer wait for whole batches
        // to run to completion, so tail request latency must improve.
        let (spec, reqs, mut w) = mk_requests(30, 50.0, 5);
        let mut eng = engine_for(&spec, &mut w);
        let mut stat = serve(&mut eng, Batcher::new(4, 0.1), &reqs);
        let (spec2, reqs2, mut w2) = mk_requests(30, 50.0, 5); // same trace
        let mut eng2 = engine_for(&spec2, &mut w2);
        let mut cont = serve_continuous(&mut eng2, Batcher::new(4, 0.1), &reqs2);
        assert_eq!(cont.requests, stat.requests);
        assert_eq!(cont.tokens, stat.tokens);
        assert!(
            cont.request_latency.p99() < stat.request_latency.p99(),
            "continuous p99 {} must beat static p99 {} under overload",
            cont.request_latency.p99(),
            stat.request_latency.p99()
        );
    }

    #[test]
    fn queueing_delay_shows_up_under_overload() {
        let (spec, reqs, mut w) = mk_requests(30, 50.0, 5); // heavy overload
        let mut eng = engine_for(&spec, &mut w);
        let mut report = serve(&mut eng, Batcher::new(4, 0.1), &reqs);
        let (spec2, reqs2, mut w2) = mk_requests(30, 0.2, 5); // light load
        let mut eng2 = engine_for(&spec2, &mut w2);
        let mut report2 = serve(&mut eng2, Batcher::new(4, 0.1), &reqs2);
        assert!(
            report.request_latency.p99() > report2.request_latency.p99(),
            "overloaded p99 {} must exceed light p99 {}",
            report.request_latency.p99(),
            report2.request_latency.p99()
        );
    }
}
