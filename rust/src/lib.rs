//! # MoE-Infinity (reproduction)
//!
//! A reproduction of *"MoE-Infinity: Activation-Aware Expert Offloading for
//! Efficient MoE Serving"* (Xue et al., 2024) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: sequence-level expert
//!   activation tracing ([`trace`]), activation-aware prefetching
//!   ([`prefetch`]), activation-aware caching ([`cache`]), a multi-tier
//!   memory/PCIe discrete-event simulator ([`memory`]), the generative
//!   inference engine implementing the paper's Algorithm 1 ([`engine`]),
//!   a request-lifecycle serving API — `Scheduler` trait, priority classes
//!   with preemption, chunked prefill, task-affinity multi-replica
//!   `Router` ([`server`]),
//!   expert-parallel cluster support ([`cluster`]) and whole-system
//!   baselines ([`baselines`]).
//! * **L2** — a JAX decode-step MoE model (`python/compile/model.py`),
//!   AOT-lowered to HLO-text artifacts consumed by [`runtime`]).
//! * **L1** — Pallas kernels for the expert FFN and router
//!   (`python/compile/kernels/`), lowered inside the L2 artifacts.
//!
//! Python runs once at `make artifacts`; the serving path is pure rust.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every figure/table of the paper to a bench target.

pub mod baselines;
pub mod benchsuite;
pub mod cache;
pub mod cluster;
pub mod config;
pub mod engine;
pub mod faults;
pub mod lint;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod prefetch;
pub mod runtime;
pub mod server;
pub mod trace;
pub mod util;
pub mod workload;

pub use model::{ExpertKey, ModelSpec};
pub use trace::{Eam, Eamc};
