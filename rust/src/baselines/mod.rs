//! Whole-system baselines (paper §8.2): policy bundles over the same engine
//! and memory simulator, differing exactly in the dimensions the paper
//! describes.
//!
//! | System        | Backing | Prefetch                   | Cache      | Extras |
//! |---------------|---------|----------------------------|------------|--------|
//! | moe-infinity  | SSD     | activation-aware (Alg. 1)  | Alg. 2     | —      |
//! | zero-infinity | SSD     | TopK by id, next layer     | neighbor   | —      |
//! | zero-offload  | DRAM    | TopK by id, next layer     | neighbor   | —      |
//! | pytorch-um    | DRAM    | none (on-demand)           | LRU        | page-fault overhead |

use anyhow::{bail, Result};

use crate::cache::CacheKind;
use crate::memory::{Tier, TierConfig};
use crate::prefetch::PredictorKind;
use crate::util::units::SimTime;

/// All system bundle names.
pub const SYSTEMS: &[&str] = &[
    "moe-infinity",
    "zero-infinity",
    "zero-offload",
    "pytorch-um",
];

/// CUDA-UM page-fault handling cost per on-demand miss (driver fault +
/// page-table updates for a multi-MB expert's worth of pages).
pub const UM_FAULT_OVERHEAD: SimTime = SimTime::from_f64(2e-3);

/// CUDA-UM effective-bandwidth fraction: on-touch page migration reaches
/// roughly a tenth of the PCIe line rate (2-4 GB/s measured for on-touch
/// migration of large buffers vs 25+ GB/s pinned copies) (fault storms, 4KB-granularity
/// scheduling) — the mechanism behind the paper's "GPU utilization of
/// PyTorch-UM is below 10%" observation (§8.2).
pub const UM_BW_FACTOR: f64 = 0.1;

/// ZeRO's prefetch lookahead width (tuned per the paper's "automatic
/// performance tuning toolkit ... for exhibiting the best performance").
pub const ZERO_TOPK: usize = 8;

/// Adjust a base tier config for the selected system. Each bundle runs the
/// same policy on both cache tiers (the paper's systems do not distinguish
/// them); per-tier overrides layer on top via `ServeConfig::tier_config`.
pub fn apply_system(system: &str, mut base: TierConfig) -> Result<TierConfig> {
    fn set_policy(base: &mut TierConfig, kind: CacheKind) {
        base.gpu_policy = kind;
        base.dram_policy = kind;
    }
    match system {
        "moe-infinity" => {
            base.backing = Tier::Ssd;
            set_policy(&mut base, CacheKind::Activation);
        }
        "zero-infinity" => {
            base.backing = Tier::Ssd;
            set_policy(&mut base, CacheKind::Neighbor);
        }
        "zero-offload" => {
            base.backing = Tier::Dram;
            set_policy(&mut base, CacheKind::Neighbor);
        }
        "pytorch-um" => {
            base.backing = Tier::Dram;
            set_policy(&mut base, CacheKind::Lru);
            base.demand_extra_latency = UM_FAULT_OVERHEAD;
            base.demand_bw_factor = UM_BW_FACTOR;
        }
        other => bail!("unknown system '{other}' (expected one of {SYSTEMS:?})"),
    }
    Ok(base)
}

/// Whether the system fetches **every** expert of a layer before executing
/// it (ZeRO's dense-model offloading semantics — it has no router
/// visibility, so all parameters of the layer must be resident; this is the
/// root of the paper's 20x latency gap, §8.2).
pub fn fetch_all_for(system: &str) -> Result<bool> {
    Ok(match system {
        "moe-infinity" | "pytorch-um" => false,
        "zero-infinity" | "zero-offload" => true,
        other => bail!("unknown system '{other}' (expected one of {SYSTEMS:?})"),
    })
}

/// The prefetch predictor each system uses.
pub fn predictor_for(system: &str) -> Result<PredictorKind> {
    Ok(match system {
        "moe-infinity" => PredictorKind::ActivationAware { refine: true },
        "zero-infinity" | "zero-offload" => PredictorKind::TopK { k: ZERO_TOPK },
        "pytorch-um" => PredictorKind::NoPrefetch,
        other => bail!("unknown system '{other}' (expected one of {SYSTEMS:?})"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::Link;

    fn base() -> TierConfig {
        TierConfig {
            gpu_capacity: 8,
            dram_capacity: 16,
            backing: Tier::Ssd,
            ssd_to_dram: Link::new(6.0, 0.0),
            dram_to_gpu: Link::new(32.0, 0.0),
            n_gpus: 1,
            demand_extra_latency: SimTime::ZERO,
            demand_bw_factor: 1.0,
            gpu_policy: CacheKind::Activation,
            dram_policy: CacheKind::Activation,
            oracle_trace: Vec::new(),
            activation_terms: (true, true),
            prefetch_gpu_budget: 0.5,
        }
    }

    #[test]
    fn bundles_match_paper_table() {
        let mi = apply_system("moe-infinity", base()).unwrap();
        assert_eq!(mi.backing, Tier::Ssd);
        assert_eq!(mi.gpu_policy, CacheKind::Activation);
        assert_eq!(mi.dram_policy, CacheKind::Activation);

        let zi = apply_system("zero-infinity", base()).unwrap();
        assert_eq!(zi.backing, Tier::Ssd);
        assert_eq!(zi.gpu_policy, CacheKind::Neighbor);
        assert_eq!(zi.dram_policy, CacheKind::Neighbor);

        let zo = apply_system("zero-offload", base()).unwrap();
        assert_eq!(zo.backing, Tier::Dram);

        let um = apply_system("pytorch-um", base()).unwrap();
        assert_eq!(um.gpu_policy, CacheKind::Lru);
        assert_eq!(um.dram_policy, CacheKind::Lru);
        assert!(um.demand_extra_latency > 0.0);
    }

    #[test]
    fn predictors_match() {
        assert_eq!(
            predictor_for("moe-infinity").unwrap(),
            PredictorKind::ActivationAware { refine: true }
        );
        assert_eq!(
            predictor_for("zero-offload").unwrap(),
            PredictorKind::TopK { k: ZERO_TOPK }
        );
        assert_eq!(predictor_for("pytorch-um").unwrap(), PredictorKind::NoPrefetch);
    }

    #[test]
    fn fetch_all_matches_systems() {
        assert!(!fetch_all_for("moe-infinity").unwrap());
        assert!(fetch_all_for("zero-infinity").unwrap());
        assert!(fetch_all_for("zero-offload").unwrap());
        assert!(!fetch_all_for("pytorch-um").unwrap());
    }

    #[test]
    fn unknown_system_errors() {
        assert!(apply_system("vllm", base()).is_err());
        assert!(predictor_for("vllm").is_err());
    }
}
