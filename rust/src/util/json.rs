//! Minimal JSON parser/printer (offline substrate — no serde in the image).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the bench result dumps: objects, arrays, strings (with escapes), f64
//! numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a UTF-8 run
                    let start = self.i;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i += len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i.min(self.b.len())])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .unwrap()
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at {start}: {e}"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn handles_escapes() {
        let v = Json::parse(r#""line\nquote\" tab\t uA""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nquote\" tab\t uA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"config": {"batch": 4, "name": "tiny"}, "xs": [1.5, true, null, "s\"x"]}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "src_hash": "abc",
          "config": {"vocab": 512, "d_model": 64},
          "artifacts": {"embed": {"file": "embed.hlo.txt", "args": [{"shape": [4], "dtype": "int32"}], "outputs": 1}}
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.get("artifacts").unwrap().get("embed").unwrap().get("outputs").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(v.get("config").unwrap().get("vocab").unwrap().as_usize(), Some(512));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo 世界""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo 世界"));
    }
}
