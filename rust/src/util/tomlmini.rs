//! Minimal TOML-subset parser/printer (offline substrate — no `toml` crate
//! in the image). Supports exactly what our config files use: `[table]` /
//! `[a.b]` headers, `key = value` with string / float / integer / bool
//! values, and `#` comments.

use std::collections::BTreeMap;

/// A flat view of a TOML document: `"table.key" -> raw value`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut map = BTreeMap::new();
        let mut prefix = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(table) = line.strip_prefix('[') {
                let table = table
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated table header", ln + 1))?;
                prefix = table.trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim();
            let full_key = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            map.insert(full_key, parse_value(val, ln + 1)?);
        }
        Ok(TomlDoc { map })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    pub fn set_str(&mut self, key: &str, v: &str) {
        self.map.insert(key.into(), TomlValue::Str(v.into()));
    }

    pub fn set_num(&mut self, key: &str, v: f64) {
        self.map.insert(key.into(), TomlValue::Num(v));
    }

    pub fn set_bool(&mut self, key: &str, v: bool) {
        self.map.insert(key.into(), TomlValue::Bool(v));
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Serialize with dotted keys grouped into tables.
    pub fn to_string_pretty(&self) -> String {
        let mut top: Vec<(&String, &TomlValue)> = Vec::new();
        let mut tables: BTreeMap<&str, Vec<(&str, &TomlValue)>> = BTreeMap::new();
        for (k, v) in &self.map {
            match k.rsplit_once('.') {
                None => top.push((k, v)),
                Some((t, leaf)) => tables.entry(t).or_default().push((leaf, v)),
            }
        }
        let mut out = String::new();
        for (k, v) in top {
            out.push_str(&format!("{k} = {}\n", fmt_value(v)));
        }
        for (t, kvs) in tables {
            out.push_str(&format!("\n[{t}]\n"));
            for (k, v) in kvs {
                out.push_str(&format!("{k} = {}\n", fmt_value(v)));
            }
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, ln: usize) -> Result<TomlValue, String> {
    if let Some(s) = v.strip_prefix('"') {
        let s = s
            .strip_suffix('"')
            .ok_or_else(|| format!("line {ln}: unterminated string"))?;
        return Ok(TomlValue::Str(s.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    v.parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("line {ln}: cannot parse value '{v}'"))
}

fn fmt_value(v: &TomlValue) -> String {
    match v {
        TomlValue::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        TomlValue::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                format!("{}", *n as i64)
            } else {
                format!("{n}")
            }
        }
        TomlValue::Bool(b) => format!("{b}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_scalars() {
        let doc = TomlDoc::parse(
            r#"
            model = "switch-base-128"   # comment
            seed = 42

            [workload]
            rps = 1.5
            bursty = false
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("model").unwrap().as_str(), Some("switch-base-128"));
        assert_eq!(doc.get("seed").unwrap().as_u64(), Some(42));
        assert_eq!(doc.get("workload.rps").unwrap().as_f64(), Some(1.5));
        assert_eq!(doc.get("workload.bursty").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"name = "a#b""##).unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn roundtrip() {
        let mut doc = TomlDoc::default();
        doc.set_str("model", "nllb-moe-128");
        doc.set_num("memory.gpu_gb", 24.0);
        doc.set_num("memory.pcie_bw", 32.5);
        let text = doc.to_string_pretty();
        let back = TomlDoc::parse(&text).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = TomlDoc::parse("a = ").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err2 = TomlDoc::parse("x = 1\n[broken\ny = 2").unwrap_err();
        assert!(err2.contains("line 2"), "{err2}");
    }
}
