//! Small shared utilities: a deterministic RNG and float helpers.
//!
//! Every stochastic component in the library (workload generation, k-means
//! init, synthetic weights) draws from [`Rng`], a SplitMix64/xoshiro256++
//! generator, so every experiment is reproducible from a single `u64` seed —
//! no external randomness, no global state.

pub mod alloc;
pub mod detmap;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod tomlmini;
pub mod units;

pub use detmap::{det_map_with_capacity, det_set_with_capacity, DetMap, DetSet};
pub use pool::Pool;
pub use units::{Bandwidth, Bytes, SimTime};

/// Deterministic xoshiro256++ PRNG seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Different seeds give independent
    /// streams for all practical purposes.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent child generator (for per-sequence streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// SplitMix64-derived per-task stream: a generator that is a pure
    /// function of `(seed, stream)`, independent of any other stream of the
    /// same seed. This is what makes parallel workload/dataset generation
    /// reproducible — task `i` of a [`pool::Pool`] map draws from
    /// `Rng::for_stream(seed, i)` regardless of which worker runs it or in
    /// what order, so the output is bitwise identical at any thread count
    /// (unlike [`Rng::fork`], which consumes the parent's sequential
    /// stream and therefore depends on call order).
    pub fn for_stream(seed: u64, stream: u64) -> Rng {
        // two SplitMix64 mixes keep (seed, stream) and (seed', stream')
        // collisions out of reach for any practical grid
        let mut s = seed;
        let base = splitmix64(&mut s);
        let mut t = base ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(splitmix64(&mut t))
    }

    /// Next raw 64-bit output (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used for Poisson
    /// arrival inter-arrival times.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Gamma(shape k, scale theta) via Marsaglia-Tsang (k >= 1 fast path,
    /// boost for k < 1). Used for bursty (CV > 1) arrival processes and
    /// Dirichlet sampling in the workload generator.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        debug_assert!(k > 0.0 && theta > 0.0);
        if k < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let u = self.f64().max(1e-300);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gauss();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 * theta;
            }
        }
    }

    /// Dirichlet(alpha) over `n` categories with symmetric concentration.
    pub fn dirichlet(&mut self, n: usize, alpha: f64) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha, 1.0).max(1e-300)).collect();
        let s: f64 = v.iter().sum();
        for x in v.iter_mut() {
            *x /= s;
        }
        v
    }

    /// Sample an index from an (unnormalized) weight slice.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Format a byte count human-readably (for logs and bench tables).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

/// Format seconds as an adaptive ms/s string.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.0}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn streams_are_reproducible_and_independent() {
        let mut a = Rng::for_stream(7, 3);
        let mut b = Rng::for_stream(7, 3);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // distinct streams of the same seed differ, and differ from the
        // plain sequential generator of that seed
        let mut c = Rng::for_stream(7, 4);
        let mut d = Rng::new(7);
        let x = Rng::for_stream(7, 3).next_u64();
        assert_ne!(x, c.next_u64());
        assert_ne!(x, d.next_u64());
        // same stream id under a different seed differs too
        assert_ne!(x, Rng::for_stream(8, 3).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gamma_mean_var() {
        let mut r = Rng::new(17);
        let (k, theta) = (3.0, 2.0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, theta)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - k * theta).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Rng::new(19);
        let n = 20_000;
        let mean = (0..n).map(|_| r.gamma(0.3, 1.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.3).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentration() {
        let mut r = Rng::new(23);
        let p = r.dirichlet(16, 0.1);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // low alpha => concentrated: max weight should dominate
        let mx = p.iter().cloned().fold(0.0, f64::max);
        assert!(mx > 0.3, "alpha=0.1 should concentrate, max={mx}");
        let q = r.dirichlet(16, 100.0);
        let mx2 = q.iter().cloned().fold(0.0, f64::max);
        assert!(mx2 < 0.2, "alpha=100 should be near-uniform, max={mx2}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(29);
        let w = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.categorical(&w), 2);
        }
        let w2 = [1.0, 3.0];
        let n = 40_000;
        let ones = (0..n).filter(|_| r.categorical(&w2) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_secs(0.0005), "500us");
        assert_eq!(fmt_secs(0.25), "250.0ms");
        assert_eq!(fmt_secs(2.0), "2.00s");
    }
}
