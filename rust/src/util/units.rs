//! Typed simulation units: [`SimTime`], [`Bytes`] and [`Bandwidth`].
//!
//! The cost model's headline arithmetic is `dt = lat + bytes / bw` — an
//! expression that silently accepts seconds, bytes and bytes/s in any
//! combination when everything is a raw `f64`. These newtypes make the
//! unit algebra part of the type system: only unit-correct combinations
//! have operators (`Bytes / Bandwidth -> SimTime`, `SimTime + SimTime`,
//! `Bandwidth * f64` for brownout factors), and every crossing back into
//! raw floats goes through a named, grep-able escape hatch
//! (`to_f64`/`from_f64`, `to_u64`/`from_u64`, [`floor_bytes`]).
//! `moelint`'s R7 `raw-units` rule bans hint-named raw-`f64` params and
//! fields in the sim/serving modules, so new quantities either carry
//! their unit in the type or show a visible conversion at the boundary.
//!
//! **Bitwise contract:** every operator here is a `#[inline]` transparent
//! wrapper around exactly the `f64`/`u64` operation the raw code
//! performed, in the same order — the 2-replica calendar replay and the
//! empty-fault-plan differential stay bitwise identical across the
//! migration (pinned in `rust/tests/scheduler.rs` and `memory/sim.rs`
//! tests; the arithmetic identities themselves are pinned below).

use std::fmt;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, Sub, SubAssign};

/// A point or span on the simulated clock, in seconds.
///
/// Arithmetic closes over `SimTime` (`+`, `-`) and scales by
/// dimensionless `f64` factors (`*`, `/`); mixing with raw floats
/// requires [`SimTime::from_f64`]/[`SimTime::to_f64`]. Comparisons
/// against raw `f64` are allowed (asserts like `makespan > 0.0` stay
/// readable) — only *arithmetic* must be unit-correct.
#[derive(Debug, Clone, Copy, Default, PartialEq, PartialOrd)]
pub struct SimTime(f64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0.0);
    pub const INFINITY: SimTime = SimTime(f64::INFINITY);

    /// Escape hatch in: wrap a raw seconds value. Boundary use only —
    /// constructor params, config plumbing, engine call sites.
    #[inline]
    pub const fn from_f64(secs: f64) -> SimTime {
        SimTime(secs)
    }

    /// Escape hatch out: the raw seconds value. Boundary use only —
    /// reporting, JSON rows, engine call sites.
    #[inline]
    pub const fn to_f64(self) -> f64 {
        self.0
    }

    /// Raw IEEE-754 bits — the currency of the bitwise differential pins.
    #[inline]
    pub fn to_bits(self) -> u64 {
        self.0.to_bits()
    }

    /// Total order over the underlying float (`f64::total_cmp`).
    #[inline]
    pub fn total_cmp(&self, other: &SimTime) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }

    #[inline]
    pub fn min(self, other: SimTime) -> SimTime {
        SimTime(self.0.min(other.0))
    }

    #[inline]
    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }

    #[inline]
    pub fn is_finite(self) -> bool {
        self.0.is_finite()
    }
}

impl Add for SimTime {
    type Output = SimTime;
    #[inline]
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    #[inline]
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl AddAssign for SimTime {
    #[inline]
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl SubAssign for SimTime {
    #[inline]
    fn sub_assign(&mut self, rhs: SimTime) {
        self.0 -= rhs.0;
    }
}

/// Scaling by a dimensionless factor (retry multipliers, slack fractions).
impl Mul<f64> for SimTime {
    type Output = SimTime;
    #[inline]
    fn mul(self, rhs: f64) -> SimTime {
        SimTime(self.0 * rhs)
    }
}

/// Scaling by a dimensionless factor (demand-priority bandwidth boost).
impl Div<f64> for SimTime {
    type Output = SimTime;
    #[inline]
    fn div(self, rhs: f64) -> SimTime {
        SimTime(self.0 / rhs)
    }
}

impl DivAssign<f64> for SimTime {
    #[inline]
    fn div_assign(&mut self, rhs: f64) {
        self.0 /= rhs;
    }
}

/// `makespan > 0.0`-style comparisons stay readable without an escape
/// hatch: comparison against raw floats is unit-safe (it cannot produce
/// a wrongly-united value), unlike arithmetic.
impl PartialEq<f64> for SimTime {
    #[inline]
    fn eq(&self, other: &f64) -> bool {
        self.0 == *other
    }
}

impl PartialOrd<f64> for SimTime {
    #[inline]
    fn partial_cmp(&self, other: &f64) -> Option<std::cmp::Ordering> {
        self.0.partial_cmp(other)
    }
}

impl PartialEq<SimTime> for f64 {
    #[inline]
    fn eq(&self, other: &SimTime) -> bool {
        *self == other.0
    }
}

impl PartialOrd<SimTime> for f64 {
    #[inline]
    fn partial_cmp(&self, other: &SimTime) -> Option<std::cmp::Ordering> {
        self.partial_cmp(&other.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

/// A byte count (expert tensor sizes, cache budgets).
///
/// Exact integer arithmetic; the only float crossing is
/// [`Bytes::from_gb`] (via [`floor_bytes`]) and the cost-model division
/// [`Bytes`]` / `[`Bandwidth`]` -> `[`SimTime`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Bytes(u64);

impl Bytes {
    pub const ZERO: Bytes = Bytes(0);

    /// Escape hatch in: wrap a raw byte count.
    #[inline]
    pub const fn from_u64(bytes: u64) -> Bytes {
        Bytes(bytes)
    }

    /// Escape hatch out: the raw byte count (accounting counters, JSON).
    #[inline]
    pub const fn to_u64(self) -> u64 {
        self.0
    }

    /// Checked GB→bytes floor: `(gb * 1e9) as u64` with the floor made
    /// explicit and the domain asserted (finite, non-negative, in range).
    /// This is the shared helper behind every config/bench capacity knob;
    /// see [`floor_bytes`].
    #[inline]
    pub fn from_gb(gb: f64) -> Bytes {
        Bytes(floor_bytes(gb * 1e9))
    }
}

impl Add for Bytes {
    type Output = Bytes;
    #[inline]
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}

impl AddAssign for Bytes {
    #[inline]
    fn add_assign(&mut self, rhs: Bytes) {
        self.0 += rhs.0;
    }
}

impl Sub for Bytes {
    type Output = Bytes;
    #[inline]
    fn sub(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 - rhs.0)
    }
}

/// The cost model's core identity: bytes over bandwidth is a duration.
/// Bit-for-bit the raw expression `bytes as f64 / bw`.
impl Div<Bandwidth> for Bytes {
    type Output = SimTime;
    #[inline]
    fn div(self, rhs: Bandwidth) -> SimTime {
        SimTime(self.0 as f64 / rhs.0)
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.0.fmt(f)
    }
}

/// A transfer rate in bytes per second.
///
/// Constructed from the config's GB/s knobs; scaled by dimensionless
/// brownout factors; consumed by [`Bytes`]` / `[`Bandwidth`].
#[derive(Debug, Clone, Copy, Default, PartialEq, PartialOrd)]
pub struct Bandwidth(f64);

impl Bandwidth {
    /// GB/s config knob → bytes/s (the raw code's `gb_s * 1e9`).
    #[inline]
    pub fn from_gb_per_s(gb_s: f64) -> Bandwidth {
        Bandwidth(gb_s * 1e9)
    }

    /// Escape hatch in: wrap a raw bytes/s value.
    #[inline]
    pub const fn from_f64(bytes_per_s: f64) -> Bandwidth {
        Bandwidth(bytes_per_s)
    }

    /// Escape hatch out: the raw bytes/s value.
    #[inline]
    pub const fn to_f64(self) -> f64 {
        self.0
    }
}

/// Brownout scaling: a degraded link is the same link at a fraction of
/// its rate.
impl Mul<f64> for Bandwidth {
    type Output = Bandwidth;
    #[inline]
    fn mul(self, rhs: f64) -> Bandwidth {
        Bandwidth(self.0 * rhs)
    }
}

/// Checked float→bytes floor: the one sanctioned truncating cast for
/// byte quantities. Debug builds assert the domain (finite, non-negative,
/// below 2^53 so the f64 grid still resolves individual bytes); release
/// builds keep the raw cast's exact semantics (`as u64` floors).
///
/// Replaces the retired R4 `float-cast` pragma sites: instead of a
/// heuristic lint plus per-line suppressions, the floor is a named
/// function you can grep for.
#[inline]
pub fn floor_bytes(x: f64) -> u64 {
    debug_assert!(
        x.is_finite() && x >= 0.0 && x < 9_007_199_254_740_992.0,
        "floor_bytes domain: {x}"
    );
    x as u64
}

/// Checked fraction-of-capacity floor for slot budgets
/// (`prefetch_gpu_budget * cache capacity`). Same contract as
/// [`floor_bytes`]: debug-asserted domain, bit-identical
/// `(frac * slots as f64) as usize` floor in release.
#[inline]
pub fn budget_slots(frac: f64, slots: usize) -> usize {
    debug_assert!(
        frac.is_finite() && frac >= 0.0,
        "budget_slots fraction domain: {frac}"
    );
    (frac * slots as f64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_arithmetic_matches_raw_f64_bitwise() {
        let xs = [0.0, 1.5e-3, 0.1, 7.25, 1e9, f64::INFINITY];
        let ys = [0.0, 3.0e-4, 0.9, 2.5, 1e-9];
        for &a in &xs {
            for &b in &ys {
                let (ta, tb) = (SimTime::from_f64(a), SimTime::from_f64(b));
                assert_eq!((ta + tb).to_bits(), (a + b).to_bits());
                assert_eq!((ta - tb).to_bits(), (a - b).to_bits());
                assert_eq!((ta * b).to_bits(), (a * b).to_bits());
                if b != 0.0 {
                    assert_eq!((ta / b).to_bits(), (a / b).to_bits());
                }
                assert_eq!(ta.max(tb).to_bits(), a.max(b).to_bits());
                assert_eq!(ta.min(tb).to_bits(), a.min(b).to_bits());
                assert_eq!(ta.partial_cmp(&tb), a.partial_cmp(&b));
            }
        }
        let mut acc = SimTime::ZERO;
        let mut raw = 0.0f64;
        for &a in &xs[..4] {
            acc += SimTime::from_f64(a);
            raw += a;
        }
        assert_eq!(acc.to_bits(), raw.to_bits());
        acc -= SimTime::from_f64(0.125);
        raw -= 0.125;
        assert_eq!(acc.to_bits(), raw.to_bits());
        acc /= 3.0;
        raw /= 3.0;
        assert_eq!(acc.to_bits(), raw.to_bits());
    }

    #[test]
    fn simtime_compares_against_raw_floats() {
        let t = SimTime::from_f64(1.5);
        assert!(t > 0.0);
        assert!(t == 1.5);
        assert!(0.0 < t);
        assert!(2.0 > t);
        assert!(!SimTime::INFINITY.is_finite());
        assert_eq!(SimTime::ZERO, 0.0);
        assert_eq!(
            SimTime::from_f64(-0.0).total_cmp(&SimTime::ZERO),
            std::cmp::Ordering::Less
        );
    }

    #[test]
    fn bytes_over_bandwidth_is_the_raw_division() {
        // the cost-model identity: dt = bytes as f64 / bw, bit-for-bit
        for &bytes in &[1u64, 4096, 350_000_000, u64::MAX >> 12] {
            for &gb_s in &[0.5, 1.0, 12.0, 64.0] {
                let raw = bytes as f64 / (gb_s * 1e9);
                let typed = Bytes::from_u64(bytes) / Bandwidth::from_gb_per_s(gb_s);
                assert_eq!(typed.to_bits(), raw.to_bits());
                // brownout scaling composes identically
                let raw_b = bytes as f64 / (gb_s * 1e9 * 0.35);
                let typed_b = Bytes::from_u64(bytes) / (Bandwidth::from_gb_per_s(gb_s) * 0.35);
                assert_eq!(typed_b.to_bits(), raw_b.to_bits());
            }
        }
    }

    #[test]
    fn bytes_integer_arithmetic() {
        let a = Bytes::from_u64(10);
        let b = Bytes::from_u64(3);
        assert_eq!((a + b).to_u64(), 13);
        assert_eq!((a - b).to_u64(), 7);
        let mut acc = Bytes::ZERO;
        acc += a;
        acc += b;
        assert_eq!(acc.to_u64(), 13);
        assert!(a > b);
    }

    #[test]
    fn checked_floors_match_raw_casts() {
        for &gb in &[0.0, 0.5, 1.0, 15.0, 23.999] {
            assert_eq!(Bytes::from_gb(gb).to_u64(), (gb * 1e9) as u64);
        }
        assert_eq!(floor_bytes(1.9), 1);
        assert_eq!(floor_bytes(15e9), 15e9 as u64);
        for &(frac, slots) in &[(0.0, 10usize), (0.3, 7), (0.99, 128), (1.0, 0)] {
            assert_eq!(budget_slots(frac, slots), (frac * slots as f64) as usize);
        }
    }

    #[test]
    #[should_panic(expected = "floor_bytes domain")]
    #[cfg(debug_assertions)]
    fn floor_bytes_rejects_negative() {
        floor_bytes(-1.0);
    }
}
