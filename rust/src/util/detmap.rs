//! Deterministic hash containers for the sim/serving decision paths.
//!
//! `std::collections::HashMap`'s default `RandomState` seeds its hasher from
//! process-global entropy, so *iteration order* — and therefore any decision
//! that ever walks a map — varies run to run. Every replay guarantee this
//! repo pins (lockstep ≡ calendar, pooled ≡ serial, static ≡ continuous)
//! would silently depend on no decision path ever iterating such a map.
//! [`DetMap`]/[`DetSet`] close that hole structurally: the same `HashMap`/
//! `HashSet` API over a fixed-seed hasher, so contents *and order* are a
//! pure function of the insert/remove history. The `moelint` R1 rule
//! (`det-map`) forbids the default-hasher types in the sim/serving modules
//! (`cache`, `prefetch`, `memory`, `server`, `engine`, `trace`, `faults`),
//! making this the only hash container those paths can construct.
//!
//! The hasher is FNV-1a over the written bytes with a SplitMix64-style
//! finalizer for avalanche (the raw FNV low bits are too regular for
//! `HashMap`'s power-of-two bucket masking). It is fully deterministic and
//! dependency-free; it is **not** DoS-resistant, which is fine for a
//! simulator whose keys are internal (`ExpertKey`, slot ids), not
//! attacker-controlled.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasher, Hasher};

/// FNV-1a offset basis (the fixed "seed" — identical in every process).
const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Byte-stream hasher: FNV-1a accumulation, SplitMix64 finalization.
#[derive(Debug, Clone)]
pub struct DetHasher {
    h: u64,
}

impl Default for DetHasher {
    fn default() -> DetHasher {
        DetHasher { h: FNV_OFFSET }
    }
}

impl Hasher for DetHasher {
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h = (self.h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }

    fn finish(&self) -> u64 {
        // SplitMix64 finalizer: avalanches the regular FNV state so the low
        // bits (HashMap's bucket index) depend on every input byte
        let mut z = self.h;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Zero-sized fixed-seed `BuildHasher` — the deterministic stand-in for
/// `RandomState`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DetState;

impl BuildHasher for DetState {
    type Hasher = DetHasher;

    fn build_hasher(&self) -> DetHasher {
        DetHasher::default()
    }
}

/// `HashMap` with run-to-run deterministic hashing and iteration order.
/// Construct with `DetMap::default()` (or [`det_map_with_capacity`]); every
/// other `HashMap` method is available unchanged.
pub type DetMap<K, V> = HashMap<K, V, DetState>;

/// `HashSet` with run-to-run deterministic hashing and iteration order.
pub type DetSet<T> = HashSet<T, DetState>;

/// `DetMap::with_capacity` — inherent impls can't be added to an alias of a
/// foreign type, so capacity construction is a free function.
pub fn det_map_with_capacity<K, V>(capacity: usize) -> DetMap<K, V> {
    DetMap::with_capacity_and_hasher(capacity, DetState)
}

/// `DetSet::with_capacity` (see [`det_map_with_capacity`]).
pub fn det_set_with_capacity<T>(capacity: usize) -> DetSet<T> {
    DetSet::with_capacity_and_hasher(capacity, DetState)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ExpertKey;

    #[test]
    fn same_history_same_iteration_order() {
        // two maps built through an identical insert/remove history iterate
        // identically — the property RandomState denies
        let build = || {
            let mut m: DetMap<ExpertKey, u64> = DetMap::default();
            for l in 0..8 {
                for e in 0..16 {
                    m.insert(ExpertKey::new(l, e), (l * 100 + e) as u64);
                }
            }
            for e in 0..16 {
                m.remove(&ExpertKey::new(3, e));
            }
            m
        };
        let (a, b) = (build(), build());
        let ka: Vec<_> = a.iter().collect();
        let kb: Vec<_> = b.iter().collect();
        assert_eq!(ka, kb, "iteration order must be reproducible");
    }

    #[test]
    fn set_order_is_reproducible() {
        let build = || {
            let mut s: DetSet<u64> = DetSet::default();
            for i in 0..500u64 {
                s.insert(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            }
            s.iter().copied().collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn behaves_like_a_map() {
        let mut m = det_map_with_capacity::<&str, u32>(4);
        assert!(m.capacity() >= 4);
        m.insert("a", 1);
        m.insert("b", 2);
        assert_eq!(m.get("a"), Some(&1));
        assert_eq!(m.insert("a", 3), Some(1));
        assert_eq!(m.remove("b"), Some(2));
        assert_eq!(m.len(), 1);
        let s: DetSet<u32> = [1, 2, 3].into_iter().collect();
        assert!(s.contains(&2) && !s.contains(&4));
        let s2 = det_set_with_capacity::<u32>(16);
        assert!(s2.is_empty() && s2.capacity() >= 16);
    }

    #[test]
    fn hasher_disperses_sequential_keys() {
        // sanity on the finalizer: sequential ExpertKeys must not collide in
        // the low bits (HashMap masks finish() to the table size)
        let mut low = DetSet::default();
        for e in 0..64usize {
            let mut h = DetHasher::default();
            std::hash::Hash::hash(&ExpertKey::new(0, e), &mut h);
            low.insert(h.finish() & 0xFF);
        }
        assert!(low.len() > 32, "low-bit dispersion too weak: {}", low.len());
    }
}
