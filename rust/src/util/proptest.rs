//! Tiny property-testing harness (offline substrate — no `proptest` crate).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` inputs drawn
//! from `gen`; on failure it reports the failing case index and seed so the
//! exact input can be regenerated deterministically. Shrinking is traded
//! away for determinism + zero dependencies.

use crate::util::Rng;

/// Run `prop` on `cases` generated inputs; panic with a reproducible report
/// on the first failure.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed})\ninput: {input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` for richer
/// failure messages.
pub fn forall_res<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(1, 100, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        forall(2, 100, |r| r.below(10), |&x| x < 5);
    }

    #[test]
    fn res_variant_reports_message() {
        forall_res(3, 50, |r| r.f64(), |&x| {
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }
}
