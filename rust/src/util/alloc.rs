//! Counting global allocator for allocation-regression tests.
//!
//! [`CountingAlloc`] wraps the system allocator and counts alloc/realloc
//! calls made **while the current thread is inside a
//! [`measure`] scope**. Scoping is per-thread (a const-initialized
//! `thread_local` flag, safe to read inside the allocator: `Cell<bool>`
//! has no destructor and no lazy initialization), so a test binary can
//! assert zero allocations for its hot region without the libtest harness
//! or other threads polluting the counter.
//!
//! Install it in a test crate (the final binary owns the global allocator):
//!
//! ```ignore
//! #[global_allocator]
//! static A: moe_infinity::util::alloc::CountingAlloc =
//!     moe_infinity::util::alloc::CountingAlloc::new();
//!
//! let (_, stats) = moe_infinity::util::alloc::measure(|| hot_path());
//! assert_eq!(stats.allocs, 0);
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static REALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static IN_SCOPE: Cell<bool> = const { Cell::new(false) };
}

/// Allocation counts observed inside a [`measure`] scope.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocStats {
    /// `alloc`/`alloc_zeroed` calls.
    pub allocs: u64,
    /// `realloc` calls (buffer growth counts here, not in `allocs`).
    pub reallocs: u64,
    /// Bytes requested across both.
    pub bytes: u64,
}

impl AllocStats {
    /// Total heap events (what "zero allocation" asserts on).
    pub fn total(&self) -> u64 {
        self.allocs + self.reallocs
    }
}

fn snapshot() -> AllocStats {
    AllocStats {
        allocs: ALLOCS.load(Ordering::Relaxed),
        reallocs: REALLOCS.load(Ordering::Relaxed),
        bytes: BYTES.load(Ordering::Relaxed),
    }
}

/// Run `f` with this thread's allocations counted; returns `f`'s result and
/// the counts attributed to the scope. Requires [`CountingAlloc`] to be the
/// process's `#[global_allocator]` — with the default system allocator the
/// stats are all zero (the flag is set but nothing increments).
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, AllocStats) {
    let before = snapshot();
    IN_SCOPE.with(|s| s.set(true));
    let out = f();
    IN_SCOPE.with(|s| s.set(false));
    let after = snapshot();
    (
        out,
        AllocStats {
            allocs: after.allocs - before.allocs,
            reallocs: after.reallocs - before.reallocs,
            bytes: after.bytes - before.bytes,
        },
    )
}

/// System-allocator wrapper that counts in-scope allocations.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> CountingAlloc {
        CountingAlloc
    }
}

impl Default for CountingAlloc {
    fn default() -> CountingAlloc {
        CountingAlloc::new()
    }
}

#[inline]
fn in_scope() -> bool {
    // `try_with` avoids touching TLS during thread teardown
    IN_SCOPE.try_with(|s| s.get()).unwrap_or(false)
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if in_scope() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if in_scope() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if in_scope() {
            REALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the library's unit-test binary does not install CountingAlloc
    // as the global allocator (tests/alloc_guard.rs does), so these only
    // exercise the scoping mechanics, not real counts.

    #[test]
    fn measure_returns_closure_result() {
        let (v, stats) = measure(|| 40 + 2);
        assert_eq!(v, 42);
        assert_eq!(stats.allocs + stats.reallocs, stats.total());
    }

    #[test]
    fn stats_total_sums() {
        let s = AllocStats {
            allocs: 3,
            reallocs: 2,
            bytes: 100,
        };
        assert_eq!(s.total(), 5);
    }
}
