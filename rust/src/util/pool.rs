//! Dependency-free scoped worker pool with **deterministic ordered
//! reduction** (vendored-deps policy: no rayon).
//!
//! The offline side of the system — `Eamc::construct`'s Eq. 1 k-means and
//! the figure benches' (system × config) experiment grids — is
//! embarrassingly parallel: every work item is a pure function of its
//! index. [`Pool`] exploits that while keeping the repo's determinism
//! contract: results are always collected **in submission order**, workers
//! never touch shared mutable state, and no RNG ever runs off the main
//! thread (parallel stochastic work derives per-task streams with
//! [`crate::util::Rng::for_stream`]). Consequently every `Pool` computation
//! is bitwise identical at any thread count — enforced end-to-end by
//! `rust/tests/parallel.rs`.
//!
//! Design notes:
//! * A `Pool` is just a thread-count policy; each `map`/`fill` call spawns
//!   short-lived `std::thread::scope` workers, so there is no persistent
//!   state, nested calls simply spawn their own scope, and a panicking
//!   task propagates to the caller like a serial panic would.
//! * `threads == 1` (or trivially small inputs) runs inline on the caller
//!   with zero spawns — that *is* the serial reference path the
//!   differential tests compare against.
//! * Dynamic scheduling (atomic chunk counter) keeps wildly uneven items
//!   (grid points) balanced; the ordered reduction on the caller makes the
//!   schedule invisible in the output.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker-count policy for scoped parallel maps. Cheap to construct; holds
/// no threads or queues of its own.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool running `threads` workers per call (clamped to >= 1).
    pub fn new(threads: usize) -> Pool {
        Pool {
            threads: threads.max(1),
        }
    }

    /// The serial reference pool: every call runs inline on the caller.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Thread count from the `MOE_POOL_THREADS` env var, defaulting to the
    /// machine's available parallelism. `MOE_POOL_THREADS=1` forces every
    /// offline path serial (scripts/tier1.sh uses this for the determinism
    /// re-check).
    pub fn from_env() -> Pool {
        let n = std::env::var("MOE_POOL_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            });
        Pool::new(n)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// `(0..n).map(f)` with dynamic scheduling across the pool; the result
    /// vector is indexed by task, so the output is independent of both the
    /// schedule and the thread count. A panic in any task propagates.
    pub fn map_range<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.threads.min(n);
        // chunked grabbing amortizes the atomic; any chunking is
        // result-invariant because the reduction below is by index
        let chunk = (n / (workers * 8)).max(1);
        let next = AtomicUsize::new(0);
        let f = &f;
        let next_ref = &next;
        let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(workers);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    s.spawn(move || {
                        let mut local: Vec<(usize, R)> = Vec::new();
                        loop {
                            let start = next_ref.fetch_add(chunk, Ordering::Relaxed);
                            if start >= n {
                                break;
                            }
                            for i in start..(start + chunk).min(n) {
                                local.push((i, f(i)));
                            }
                        }
                        local
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok(part) => parts.push(part),
                    // re-raise the worker's panic payload on the caller
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        });
        // deterministic ordered reduction: place by task index
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for part in parts {
            for (i, r) in part {
                debug_assert!(slots[i].is_none(), "task {i} produced twice");
                slots[i] = Some(r);
            }
        }
        slots
            .into_iter()
            .map(|s| s.expect("pool: task never ran"))
            .collect()
    }

    /// Ordered map over a slice: `out[i] = f(i, &items[i])`.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.map_range(items.len(), |i| f(i, &items[i]))
    }

    /// In-place variant reusing a caller-owned buffer: `out[i] = f(i)`.
    /// Statically partitioned into contiguous blocks (each worker writes a
    /// disjoint sub-slice), so no allocation beyond thread spawn — the
    /// k-means assignment pass reuses one buffer across all iterations.
    pub fn fill<R, F>(&self, out: &mut [R], f: F)
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let n = out.len();
        if self.threads == 1 || n <= 1 {
            for (i, slot) in out.iter_mut().enumerate() {
                *slot = f(i);
            }
            return;
        }
        let workers = self.threads.min(n);
        let chunk = (n + workers - 1) / workers; // div_ceil (MSRV 1.70)
        let f = &f;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(workers);
            for (w, block) in out.chunks_mut(chunk).enumerate() {
                let base = w * chunk;
                handles.push(s.spawn(move || {
                    for (j, slot) in block.iter_mut().enumerate() {
                        *slot = f(base + j);
                    }
                }));
            }
            for h in handles {
                if let Err(payload) = h.join() {
                    std::panic::resume_unwind(payload);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_range_matches_serial_at_any_thread_count() {
        let serial: Vec<u64> = (0..257).map(|i| (i as u64).wrapping_mul(0x9E37) ^ 7).collect();
        for threads in [1, 2, 3, 8] {
            let got = Pool::new(threads).map_range(257, |i| (i as u64).wrapping_mul(0x9E37) ^ 7);
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn map_over_slice_is_ordered() {
        let items: Vec<i64> = (0..100).map(|i| i * 3).collect();
        let got = Pool::new(4).map(&items, |i, &x| x + i as i64);
        let want: Vec<i64> = (0..100).map(|i| i * 4).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn zero_and_single_task_edges() {
        let p = Pool::new(8);
        assert!(p.map_range(0, |i| i).is_empty());
        assert_eq!(p.map_range(1, |i| i + 41), vec![41]);
        let mut empty: [usize; 0] = [];
        p.fill(&mut empty, |i| i); // must not spawn or panic
    }

    #[test]
    fn fill_matches_map_range() {
        for threads in [1, 2, 8] {
            let p = Pool::new(threads);
            let mut buf = vec![0usize; 73];
            p.fill(&mut buf, |i| i * i + 1);
            assert_eq!(buf, p.map_range(73, |i| i * i + 1), "threads={threads}");
        }
    }

    #[test]
    fn fill_reuses_buffer_across_calls() {
        let p = Pool::new(2);
        let mut buf = vec![0usize; 50];
        p.fill(&mut buf, |i| i);
        p.fill(&mut buf, |i| i + 1);
        assert_eq!(buf[49], 50);
    }

    #[test]
    fn panics_propagate_from_workers() {
        for threads in [1, 4] {
            let p = Pool::new(threads);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                p.map_range(64, |i| {
                    if i == 37 {
                        panic!("task 37 exploded");
                    }
                    i
                })
            }));
            assert!(r.is_err(), "threads={threads}: worker panic must surface");
        }
    }

    #[test]
    fn nested_pools_work() {
        let outer = Pool::new(2);
        let got = outer.map_range(4, |i| {
            let inner = Pool::new(2);
            inner.map_range(8, |j| i * 8 + j).iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..4).map(|i| (0..8).map(|j| i * 8 + j).sum()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn from_env_clamps_and_parses() {
        // do not mutate the process env here (tests run threaded);
        // just check the constructor clamps
        assert_eq!(Pool::new(0).threads(), 1);
        assert!(Pool::from_env().threads() >= 1);
    }
}
