//! Shared experiment harness used by the figure benches (`rust/benches/`)
//! and the examples: builds engines from system names, replays workloads,
//! and measures prefetch prediction accuracy the way §8.3 defines it.

use crate::cache::CacheKind;
use crate::config::{SchedulerKind, ServeConfig};
use crate::engine::{ComputeModel, EngineConfig, SimEngine};
use crate::memory::TierConfig;
use crate::model::ModelSpec;
use crate::prefetch::{Predictor, PredictorKind};
use crate::server::{
    Batcher, ChunkedScheduler, ContinuousScheduler, Router, Scheduler, ServeReport,
    StaticScheduler,
};
use crate::trace::{Eam, Eamc};
use crate::util::{Pool, Rng};
use crate::workload::{ArrivalProcess, DatasetPreset, Priority, Request, Workload};

/// Build an EAMC from a freshly generated offline trace (§4.2's "relevant
/// dataset" = the validation split of the same distribution). Dataset
/// generation and clustering run on [`Pool::from_env`]; the result is
/// bitwise identical at any thread count.
pub fn build_eamc(spec: &ModelSpec, dataset: &DatasetPreset, n: usize, cap: usize, seed: u64) -> Eamc {
    build_eamc_with(spec, dataset, n, cap, seed, &Pool::from_env())
}

/// [`build_eamc`] on an explicit pool. The offline trace uses per-sequence
/// `Rng::for_stream` streams (seeded from `seed`), so the generated
/// dataset — and therefore the constructed EAMC — is a pure function of
/// the arguments, independent of scheduling.
pub fn build_eamc_with(
    spec: &ModelSpec,
    dataset: &DatasetPreset,
    n: usize,
    cap: usize,
    seed: u64,
    pool: &Pool,
) -> Eamc {
    let w = Workload::new(spec, dataset.clone(), seed);
    let ds = w.gen_eam_dataset_par(pool, n, seed ^ 0xDA7A);
    Eamc::construct_with(cap, &ds, seed ^ 0x9E37, pool)
}

/// Build a ready-to-serve engine from a [`ServeConfig`].
pub fn build_engine(cfg: &ServeConfig) -> anyhow::Result<SimEngine> {
    build_engine_with(cfg, &Pool::from_env())
}

/// [`build_engine`] with the offline EAMC construction on an explicit pool.
pub fn build_engine_with(cfg: &ServeConfig, pool: &Pool) -> anyhow::Result<SimEngine> {
    let spec = cfg.model_spec()?;
    let dataset = DatasetPreset::by_name(&cfg.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", cfg.dataset))?;
    let tier = cfg.tier_config()?;
    let eamc = if cfg.predictor_kind()? == (PredictorKind::ActivationAware { refine: true }) {
        build_eamc_with(
            &spec,
            &dataset,
            cfg.eamc.trace_sequences,
            cfg.eamc.capacity,
            cfg.seed,
            pool,
        )
    } else {
        Eamc::new(cfg.eamc.capacity, spec.n_layers, spec.experts_per_layer)
    };
    Ok(SimEngine::new(
        spec,
        tier,
        eamc,
        ComputeModel::a5000(),
        EngineConfig {
            predictor: cfg.predictor_kind()?,
            fetch_all_experts: crate::baselines::fetch_all_for(&cfg.system)?,
            cancel_retired_prefetch: cfg.cancel_retired_prefetch,
            ..Default::default()
        },
    ))
}

/// Build the `cfg.replicas` engines served behind the router. Replica 0
/// uses `cfg.seed` verbatim (a 1-replica router is therefore bitwise
/// identical to the bare scheduler); later replicas offset the seed, so
/// their offline EAMCs sample the same workload distribution differently —
/// which is what gives task-affinity routing a signal to separate tasks on
/// from the very first request.
pub fn build_replica_engines_with(cfg: &ServeConfig, pool: &Pool) -> anyhow::Result<Vec<SimEngine>> {
    (0..cfg.replicas)
        .map(|i| {
            let mut c = cfg.clone();
            c.seed = cfg.seed.wrapping_add(i as u64 * 0x9E37);
            build_engine_with(&c, pool)
        })
        .collect()
}

/// Arrival process at `rps` under the config's burstiness knob.
fn arrival_proc(cfg: &ServeConfig, rps: f64) -> ArrivalProcess {
    if cfg.workload.cv > 1.0 {
        ArrivalProcess::Bursty {
            rps,
            cv: cfg.workload.cv,
        }
    } else {
        ArrivalProcess::Poisson { rps }
    }
}

/// Flash-crowd arrival timestamps: gaps draw at `workload.rps` outside the
/// `[flash_start, flash_end)` window and at `workload.flash_rps` inside it
/// (burstiness `cv` applies in both phases). With `flash_rps == rps` this
/// reproduces [`ArrivalProcess::timestamps`] draw for draw; callers only
/// reach it when the overlay is actually on, so the historical single-rate
/// stream stays byte-identical.
fn flash_timestamps(cfg: &ServeConfig, rng: &mut Rng) -> Vec<f64> {
    let w = &cfg.workload;
    let base = arrival_proc(cfg, w.rps);
    let peak = arrival_proc(cfg, w.flash_rps);
    let mut out = Vec::new();
    let mut t = 0.0;
    loop {
        // the gap's rate is decided by where the previous arrival left the
        // clock — a piecewise-constant-rate renewal process
        let proc = if t >= w.flash_start && t < w.flash_end {
            &peak
        } else {
            &base
        };
        t += proc.next_gap(rng);
        if t >= w.duration {
            break;
        }
        out.push(t);
    }
    out
}

/// Generate the request stream for a config.
pub fn build_requests(cfg: &ServeConfig) -> anyhow::Result<Vec<Request>> {
    let spec = cfg.model_spec()?;
    let dataset = DatasetPreset::by_name(&cfg.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", cfg.dataset))?;
    let mut w = Workload::new(&spec, dataset, cfg.seed ^ 0xFACE);
    let mut rng = Rng::new(cfg.seed ^ 0xBEEF);
    let flash = cfg.workload.flash_rps > 0.0 && cfg.workload.flash_end > cfg.workload.flash_start;
    let ts = if flash {
        flash_timestamps(cfg, &mut rng)
    } else {
        let proc = arrival_proc(cfg, cfg.workload.rps);
        proc.timestamps(cfg.workload.duration, &mut rng)
    };
    let mut reqs: Vec<Request> = ts
        .into_iter()
        .enumerate()
        .map(|(i, arrival)| Request::new(i as u64, arrival, w.gen_sequence()))
        .collect();
    // class tagging draws from its own stream, and only when requested —
    // the default (0.0) stream is byte-identical to the class-unaware one
    if cfg.workload.interactive_frac > 0.0 {
        let mut crng = Rng::new(cfg.seed ^ 0xC1A55);
        for r in reqs.iter_mut() {
            if crng.f64() < cfg.workload.interactive_frac {
                r.class.priority = Priority::Interactive;
                // an SLO only attaches when configured (default 0.0 keeps
                // the historical classes: priority without a deadline)
                if cfg.workload.interactive_slo > 0.0 {
                    r.class.slo = Some(cfg.workload.interactive_slo);
                }
            }
        }
    }
    Ok(reqs)
}

/// Run a full serving replay for a config: engine + arrivals + batcher.
pub fn run_serve(cfg: &ServeConfig) -> anyhow::Result<ServeReport> {
    run_serve_with(cfg, &Pool::from_env())
}

/// [`run_serve`] with offline construction on an explicit pool (the replay
/// itself is single-threaded — it is one or more engines' virtual
/// timelines). `cfg.scheduler` selects the serving discipline,
/// `cfg.priority` the continuous admission policy, and `cfg.replicas` /
/// `cfg.routing` put a multi-replica [`Router`] in front; every
/// combination replays the identical request trace.
pub fn run_serve_with(cfg: &ServeConfig, pool: &Pool) -> anyhow::Result<ServeReport> {
    // surface invalid fields (e.g. a NaN batching.max_wait) as a per-point
    // Err — `Batcher::new` would otherwise assert and abort a whole grid
    cfg.validate()?;
    let requests = build_requests(cfg)?;
    // satellite of the fault-injection PR: a bad batching config is a
    // per-point Err, not a process abort mid-grid
    let batcher = Batcher::try_new(cfg.batching.max_batch, cfg.batching.max_wait)
        .map_err(|e| anyhow::anyhow!(e))?;
    let plan = cfg.fault_plan();
    if cfg.replicas > 1 {
        let engines = build_replica_engines_with(cfg, pool)?;
        let mut router = Router::new(engines, batcher, cfg.routing, cfg.priority);
        if cfg.scheduler == SchedulerKind::Chunked {
            router = router.with_prefill_chunk(cfg.prefill_chunk_u32());
        }
        if let Some(p) = &plan {
            router = router.with_fault_plan(p);
        }
        if cfg.faults.shedding {
            router.set_shedding(true);
        }
        router.submit_all(&requests);
        return Ok(router.drain());
    }
    let mut engine = build_engine_with(cfg, pool)?;
    if let Some(p) = &plan {
        engine.set_fault_plan(p);
    }
    Ok(match cfg.scheduler {
        SchedulerKind::Static => {
            let mut s = StaticScheduler::new(engine, batcher);
            s.submit_all(&requests);
            s.drain()
        }
        SchedulerKind::Continuous => {
            let mut s = ContinuousScheduler::new(engine, batcher, cfg.priority);
            s.set_shedding(cfg.faults.shedding);
            s.submit_all(&requests);
            s.drain()
        }
        SchedulerKind::Chunked => {
            let mut s =
                ChunkedScheduler::new(engine, batcher, cfg.priority, cfg.prefill_chunk_u32());
            s.set_shedding(cfg.faults.shedding);
            s.submit_all(&requests);
            s.drain()
        }
    })
}

/// Replay an experiment grid: every [`ServeConfig`] point is an independent
/// engine + workload, so points run across the pool's workers; results come
/// back **in submission order** and are bitwise identical to running each
/// point serially (differential tests in `rust/tests/parallel.rs`). Each
/// point's own offline construction runs serially — the grid is the
/// parallelism axis, and nesting pools would only oversubscribe cores.
pub fn run_grid(configs: &[ServeConfig], pool: &Pool) -> Vec<anyhow::Result<ServeReport>> {
    let inner = Pool::serial();
    pool.map(configs, |_, cfg| run_serve_with(cfg, &inner))
}

/// §8.3 prediction-accuracy probe (Figs. 9): for each sequence and each
/// layer transition, compare the predictor's next-layer expert set (top-k =
/// actual activated count) against the actually activated experts; returns
/// mean recall. Pure predictor measurement — no memory simulation.
pub fn prediction_accuracy(
    spec: &ModelSpec,
    kind: PredictorKind,
    eamc: &Eamc,
    workload: &mut Workload,
    n_sequences: usize,
) -> f64 {
    let mut predictor = Predictor::new(kind, spec.n_layers, spec.experts_per_layer);
    let mut buf = Vec::new();
    let mut correct = 0usize;
    let mut total = 0usize;
    for _ in 0..n_sequences {
        let seq = workload.gen_sequence();
        let mut cur = Eam::new(spec.n_layers, spec.experts_per_layer);
        // the standing prediction: re-computed when the strategy refines,
        // otherwise the stale one keeps being consulted (so the §8.3
        // one-shot ablation is charged for its staleness at every layer)
        let mut standing = crate::prefetch::Prediction::default();
        for iter in 0..seq.iterations() {
            for l in 0..spec.n_layers {
                for &(e, c) in &seq.routes[iter][l] {
                    cur.record(l, e as usize, c);
                    predictor.observe_route(l, e as usize, c);
                }
                if predictor.should_predict(l, iter) {
                    predictor.predict(&cur, eamc, None, l, &mut buf);
                    standing = crate::prefetch::Prediction { items: buf.clone() };
                }
                if l + 1 < spec.n_layers {
                    let actual: Vec<usize> =
                        seq.routes[iter][l + 1].iter().map(|&(e, _)| e as usize).collect();
                    if actual.is_empty() {
                        continue;
                    }
                    let top: Vec<_> = standing
                        .for_layer(l + 1)
                        .into_iter()
                        .take(actual.len())
                        .map(|k| k.expert as usize)
                        .collect();
                    for e in &actual {
                        total += 1;
                        if top.contains(e) {
                            correct += 1;
                        }
                    }
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

/// Convenience: a [`TierConfig`] sized in *expert counts* for policy
/// micro-benchmarks (cache/bandwidth sweeps).
pub fn tier_with(
    _spec: &ModelSpec,
    gpu_experts: usize,
    dram_experts: usize,
    ssd_gb_s: f64,
    pcie_gb_s: f64,
    cache: CacheKind,
) -> TierConfig {
    TierConfig {
        gpu_capacity: gpu_experts,
        dram_capacity: dram_experts,
        backing: crate::memory::Tier::Ssd,
        ssd_to_dram: crate::memory::Link::new(ssd_gb_s, 50e-6),
        dram_to_gpu: crate::memory::Link::new(pcie_gb_s, 10e-6),
        n_gpus: 1,
        demand_extra_latency: crate::util::units::SimTime::ZERO,
        demand_bw_factor: 1.0,
        gpu_policy: cache,
        dram_policy: cache,
        oracle_trace: Vec::new(),
        activation_terms: (true, true),
        prefetch_gpu_budget: 0.5,
    }
}

/// Minimal wall-clock micro-benchmark helper (offline substrate — the image
/// has no criterion): warms up, then reports ns/op over `iters` calls of the
/// hot closure. `black_box` prevents the optimizer from deleting the work.
pub fn time_ns_per_op<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    // moelint: allow(wall-clock, host timing is this helper's entire purpose)
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// Machine-readable bench emitter: collects `name → ns/op` pairs and
/// writes them as a flat JSON object (e.g. `BENCH_hotpath.json`), so CI and
/// EXPERIMENTS.md tooling can diff hot-path numbers across commits without
/// scraping the printed tables.
#[derive(Debug, Default)]
pub struct BenchJson {
    entries: Vec<(String, f64)>,
}

impl BenchJson {
    pub fn new() -> BenchJson {
        BenchJson::default()
    }

    pub fn add(&mut self, name: &str, ns_per_op: f64) {
        self.entries.push((name.to_string(), ns_per_op));
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let map: std::collections::BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        Json::Obj(map)
    }

    /// Write the collected entries to `path` (overwrites).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

/// Markdown-ish table printer shared by the figure benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        // moelint: allow(print, Table::print exists to write bench reports to stdout)
        println!("\n## {title}");
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain(std::iter::once(h.len()))
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        // moelint: allow(print, bench report header row)
        println!("{}", fmt_row(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        // moelint: allow(print, bench report separator row)
        println!("{}", fmt_row(&sep));
        for r in &self.rows {
            // moelint: allow(print, bench report data rows)
            println!("{}", fmt_row(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_engine_and_requests_from_default_config() {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.workload.duration = 10.0;
        cfg.eamc.trace_sequences = 30;
        cfg.eamc.capacity = 8;
        let engine = build_engine(&cfg).unwrap();
        assert_eq!(engine.spec().name, "switch-base-32");
        let reqs = build_requests(&cfg).unwrap();
        assert!(!reqs.is_empty());
    }

    #[test]
    fn run_serve_end_to_end_small() {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.workload.duration = 8.0;
        cfg.workload.rps = 1.0;
        cfg.eamc.trace_sequences = 30;
        cfg.eamc.capacity = 8;
        let report = run_serve(&cfg).unwrap();
        assert!(report.requests > 0);
        assert!(report.token_throughput() > 0.0);
    }

    #[test]
    fn run_serve_continuous_end_to_end_small() {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.workload.duration = 8.0;
        cfg.workload.rps = 1.0;
        cfg.eamc.trace_sequences = 30;
        cfg.eamc.capacity = 8;
        cfg.scheduler = SchedulerKind::Continuous;
        let report = run_serve(&cfg).unwrap();
        assert!(report.requests > 0);
        assert!(report.token_throughput() > 0.0);
        assert_eq!(report.request_latency.len() as u64, report.requests);
    }

    #[test]
    fn run_serve_chunked_end_to_end_small() {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.workload.duration = 8.0;
        cfg.workload.rps = 2.0;
        cfg.eamc.trace_sequences = 30;
        cfg.eamc.capacity = 8;
        cfg.scheduler = SchedulerKind::Chunked;
        cfg.prefill_chunk = 16;
        let report = run_serve(&cfg).unwrap();
        assert!(report.requests > 0);
        assert!(report.token_throughput() > 0.0);
        assert_eq!(report.request_latency.len() as u64, report.requests);
        assert_eq!(report.ttft.len() as u64, report.requests);
        assert!(report.decode_latency.len() > 0, "decode samples must record");
    }

    #[test]
    fn run_serve_router_end_to_end_small() {
        use crate::server::RoutingPolicy;
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.workload.duration = 8.0;
        cfg.workload.rps = 2.0;
        cfg.eamc.trace_sequences = 30;
        cfg.eamc.capacity = 8;
        cfg.scheduler = SchedulerKind::Continuous;
        cfg.replicas = 2;
        for routing in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::TaskAffinity,
        ] {
            cfg.routing = routing;
            let report = run_serve(&cfg).unwrap();
            assert!(report.requests > 0, "{routing:?}");
            assert_eq!(report.request_latency.len() as u64, report.requests);
            assert_eq!(report.ttft.len() as u64, report.requests);
            assert!(report.token_throughput() > 0.0);
        }
    }

    #[test]
    fn faulty_config_serves_end_to_end_and_counts_faults() {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.workload.duration = 8.0;
        cfg.workload.rps = 1.0;
        cfg.eamc.trace_sequences = 30;
        cfg.eamc.capacity = 8;
        cfg.scheduler = SchedulerKind::Continuous;
        let clean = run_serve(&cfg).unwrap();
        assert_eq!(clean.transfer_retries, 0);
        assert_eq!(clean.demand_failures, 0);
        cfg.faults.gpu_failure_p = 0.5;
        let faulty = run_serve(&cfg).unwrap();
        assert_eq!(faulty.requests, clean.requests, "faults must not lose requests");
        assert_eq!(faulty.tokens, clean.tokens);
        assert!(faulty.transfer_retries > 0, "p=0.5 must force retries");
        assert!(
            faulty.makespan >= clean.makespan,
            "retries cost simulated time"
        );
    }

    #[test]
    fn interactive_slo_attaches_to_interactive_requests_only() {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.workload.duration = 20.0;
        cfg.workload.rps = 2.0;
        cfg.workload.interactive_frac = 0.5;
        let untimed = build_requests(&cfg).unwrap();
        cfg.workload.interactive_slo = 1.5;
        let timed = build_requests(&cfg).unwrap();
        assert_eq!(untimed.len(), timed.len());
        for (a, b) in untimed.iter().zip(&timed) {
            assert_eq!(a.class.priority, b.class.priority, "slo must not retag");
            assert!(a.class.slo.is_none());
            match b.class.priority {
                Priority::Interactive => assert_eq!(b.class.slo, Some(1.5)),
                _ => assert!(b.class.slo.is_none()),
            }
        }
    }

    #[test]
    fn interactive_frac_tags_classes_without_touching_the_trace() {
        let mut cfg = ServeConfig::default();
        cfg.model = "switch-base-32".into();
        cfg.workload.duration = 20.0;
        cfg.workload.rps = 2.0;
        let plain = build_requests(&cfg).unwrap();
        cfg.workload.interactive_frac = 0.5;
        let tagged = build_requests(&cfg).unwrap();
        assert_eq!(plain.len(), tagged.len());
        let n_hi = tagged
            .iter()
            .filter(|r| r.class.priority == Priority::Interactive)
            .count();
        assert!(n_hi > 0 && n_hi < tagged.len(), "got {n_hi} interactive");
        for (a, b) in plain.iter().zip(&tagged) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.seq.routes, b.seq.routes, "tagging must not perturb traces");
        }
        assert!(plain
            .iter()
            .all(|r| r.class.priority == Priority::Normal && r.class.slo.is_none()));
    }

    #[test]
    fn run_grid_matches_serial_run_serve_in_order() {
        let mut base = ServeConfig::default();
        base.model = "switch-base-32".into();
        base.workload.duration = 6.0;
        base.eamc.trace_sequences = 20;
        base.eamc.capacity = 6;
        let grid: Vec<ServeConfig> = [0.5, 2.0]
            .iter()
            .map(|&rps| {
                let mut c = base.clone();
                c.workload.rps = rps;
                c
            })
            .collect();
        let par = run_grid(&grid, &Pool::new(4));
        assert_eq!(par.len(), grid.len());
        for (cfg, got) in grid.iter().zip(par) {
            let want = run_serve_with(cfg, &Pool::serial()).unwrap();
            let got = got.unwrap();
            assert_eq!(got.requests, want.requests);
            assert_eq!(got.tokens, want.tokens);
            assert_eq!(got.batches, want.batches);
            assert_eq!(got.makespan.to_bits(), want.makespan.to_bits());
            assert_eq!(got.token_latency.samples(), want.token_latency.samples());
        }
    }

    #[test]
    fn prediction_accuracy_aware_beats_topk() {
        let spec = ModelSpec::preset("switch-base-64").unwrap();
        let ds = DatasetPreset::by_name("translation").unwrap();
        let eamc = build_eamc(&spec, &ds, 60, 12, 3);
        let mut w1 = Workload::new(&spec, ds.clone(), 3); // same distribution
        let aware = prediction_accuracy(
            &spec,
            PredictorKind::ActivationAware { refine: true },
            &eamc,
            &mut w1,
            10,
        );
        let mut w2 = Workload::new(&spec, ds, 3);
        let topk =
            prediction_accuracy(&spec, PredictorKind::TopK { k: 8 }, &eamc, &mut w2, 10);
        assert!(
            aware > topk,
            "activation-aware accuracy {aware} must beat topk {topk}"
        );
        assert!(aware > 0.3, "aware accuracy {aware} too low");
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test");
    }

    #[test]
    fn bench_json_roundtrips() {
        use crate::util::json::Json;
        let mut b = BenchJson::new();
        b.add("EAMC nearest", 1234.5);
        b.add("cache insert+evict", 88.0);
        let text = b.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("EAMC nearest").and_then(|j| j.as_f64()),
            Some(1234.5)
        );
        assert_eq!(
            parsed.get("cache insert+evict").and_then(|j| j.as_f64()),
            Some(88.0)
        );
    }
}
