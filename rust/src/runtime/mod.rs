//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` crate. The interchange is
//! HLO **text** — see `aot.py` for why (jax >= 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects in proto form).
//!
//! Weights are passed as runtime *arguments* on every call: that is the
//! deliberate design that makes expert offloading possible (an expert's
//! tensors can live anywhere; whoever owns them feeds them in), mirroring
//! the paper's per-expert fetch granularity.

mod artifacts;

pub use artifacts::{ArtifactManifest, ArtifactSpec};

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::model::weights::TinyConfig;

/// Compiled executables for every decode-step piece of the tiny MoE.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub cfg: TinyConfig,
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("shape {dims:?} does not match data len {}", data.len()));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

impl Runtime {
    /// Load every artifact listed in `manifest.json` and compile it on the
    /// CPU PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = ArtifactManifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for (name, art) in &manifest.artifacts {
            let path = artifacts_dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime {
            client,
            exes,
            cfg: manifest.config,
        })
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let out = exe.execute::<xla::Literal>(args)?;
        Ok(out[0][0].to_literal_sync()?)
    }

    /// `ids [B] i32, emb [V,D] -> x [B,D]`.
    pub fn embed(&self, ids: &[i32], emb: &[f32]) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let out = self.run(
            "embed",
            &[
                lit_i32(ids, &[c.batch as i64])?,
                lit_f32(emb, &[c.vocab as i64, c.d_model as i64])?,
            ],
        )?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// One attention step; returns `(x', k', v')` flattened.
    #[allow(clippy::too_many_arguments)]
    pub fn attn_step(
        &self,
        x: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
        pos: i32,
        wq: &[f32],
        wk: &[f32],
        wv: &[f32],
        wo: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let c = &self.cfg;
        let (b, s, d) = (c.batch as i64, c.max_seq as i64, c.d_model as i64);
        let out = self.run(
            "attn_step",
            &[
                lit_f32(x, &[b, d])?,
                lit_f32(k_cache, &[b, s, d])?,
                lit_f32(v_cache, &[b, s, d])?,
                xla::Literal::scalar(pos),
                lit_f32(wq, &[d, d])?,
                lit_f32(wk, &[d, d])?,
                lit_f32(wv, &[d, d])?,
                lit_f32(wo, &[d, d])?,
            ],
        )?;
        let (o, nk, nv) = out.to_tuple3()?;
        Ok((o.to_vec::<f32>()?, nk.to_vec::<f32>()?, nv.to_vec::<f32>()?))
    }

    /// Top-1 router (the L1 Pallas kernel): `-> (gates [B], idx [B])`.
    pub fn router(&self, x: &[f32], wr: &[f32]) -> Result<(Vec<f32>, Vec<i32>)> {
        let c = &self.cfg;
        let out = self.run(
            "router",
            &[
                lit_f32(x, &[c.batch as i64, c.d_model as i64])?,
                lit_f32(wr, &[c.d_model as i64, c.n_experts as i64])?,
            ],
        )?;
        let (g, i) = out.to_tuple2()?;
        Ok((g.to_vec::<f32>()?, i.to_vec::<i32>()?))
    }

    /// Expert FFN (the L1 Pallas kernel) over a padded `[B,D]` row block.
    pub fn expert(
        &self,
        x: &[f32],
        w1: &[f32],
        b1: &[f32],
        w2: &[f32],
        b2: &[f32],
    ) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (b, d, f) = (c.batch as i64, c.d_model as i64, c.d_ff as i64);
        let out = self.run(
            "expert",
            &[
                lit_f32(x, &[b, d])?,
                lit_f32(w1, &[d, f])?,
                lit_f32(b1, &[f])?,
                lit_f32(w2, &[f, d])?,
                lit_f32(b2, &[d])?,
            ],
        )?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Residual + gated combine.
    pub fn combine(&self, x: &[f32], eo: &[f32], gates: &[f32], sel: &[f32]) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (b, d) = (c.batch as i64, c.d_model as i64);
        let out = self.run(
            "combine",
            &[
                lit_f32(x, &[b, d])?,
                lit_f32(eo, &[b, d])?,
                lit_f32(gates, &[b])?,
                lit_f32(sel, &[b])?,
            ],
        )?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Greedy next-token head.
    pub fn lm_head(&self, x: &[f32], w_out: &[f32]) -> Result<Vec<i32>> {
        let c = &self.cfg;
        let out = self.run(
            "lm_head",
            &[
                lit_f32(x, &[c.batch as i64, c.d_model as i64])?,
                lit_f32(w_out, &[c.d_model as i64, c.vocab as i64])?,
            ],
        )?;
        Ok(out.to_tuple1()?.to_vec::<i32>()?)
    }
}
