//! Artifact manifest parsing (`artifacts/manifest.json`), via the in-tree
//! JSON parser (`util::json`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::model::weights::TinyConfig;
use crate::util::json::Json;

/// Shape/dtype of one artifact argument as recorded by `aot.py`.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One compiled artifact's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: usize,
}

/// The manifest: geometry + artifact table.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub src_hash: String,
    pub config: TinyConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let data = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        let j = Json::parse(&data).map_err(|e| anyhow!("parsing manifest.json: {e}"))?;
        let src_hash = j
            .get("src_hash")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string();
        let config = TinyConfig::from_json(
            j.get("config").ok_or_else(|| anyhow!("manifest missing 'config'"))?,
        )?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact '{name}' missing 'file'"))?
                .to_string();
            let outputs = spec
                .get("outputs")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("artifact '{name}' missing 'outputs'"))?;
            let args = spec
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact '{name}' missing 'args'"))?
                .iter()
                .map(|a| {
                    let shape = a
                        .get("shape")
                        .and_then(Json::as_arr)
                        .map(|xs| xs.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default();
                    let dtype = a
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string();
                    ArgSpec { shape, dtype }
                })
                .collect();
            artifacts.insert(name.clone(), ArtifactSpec { file, args, outputs });
        }
        Ok(ArtifactManifest {
            src_hash,
            config,
            artifacts,
        })
    }

    /// Paths of all artifact files, for existence checks.
    pub fn files(&self) -> Vec<String> {
        self.artifacts.values().map(|a| a.file.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These run against the built artifacts if present; skipped in clean
    /// checkouts (integration tests cover the full path after
    /// `make artifacts`).
    fn dir() -> Option<std::path::PathBuf> {
        let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn manifest_parses_and_lists_all_pieces() {
        let Some(d) = dir() else { return };
        let m = ArtifactManifest::load(&d).unwrap();
        for piece in ["embed", "attn_step", "router", "expert", "combine", "lm_head"] {
            assert!(m.artifacts.contains_key(piece), "missing {piece}");
        }
        assert_eq!(m.artifacts["attn_step"].outputs, 3);
        assert!(!m.src_hash.is_empty());
        for f in m.files() {
            assert!(d.join(&f).exists(), "artifact file {f} missing");
        }
    }

    #[test]
    fn manifest_geometry_matches_default_tiny() {
        let Some(d) = dir() else { return };
        let m = ArtifactManifest::load(&d).unwrap();
        assert_eq!(m.config, TinyConfig::default_tiny());
    }

    #[test]
    fn arg_shapes_match_geometry() {
        let Some(d) = dir() else { return };
        let m = ArtifactManifest::load(&d).unwrap();
        let c = &m.config;
        let router = &m.artifacts["router"];
        assert_eq!(router.args[0].shape, vec![c.batch, c.d_model]);
        assert_eq!(router.args[1].shape, vec![c.d_model, c.n_experts]);
        let expert = &m.artifacts["expert"];
        assert_eq!(expert.args[1].shape, vec![c.d_model, c.d_ff]);
    }
}
