//! `moelint` CLI — lint the repo's determinism & hot-path rules.
//!
//! Usage: `moelint [--json] [--rules] [ROOT]`
//!
//! * `ROOT` defaults to the current directory; it must contain `rust/src`
//!   (the walk covers `rust/src`, `rust/benches`, `rust/tests`).
//! * `--json` emits newline-delimited JSON objects instead of the
//!   gcc-style `path:line:col: moelint(rule): msg` lines.
//! * `--rules` prints the rule catalogue and exits 0.
//!
//! Exit codes (the contract `scripts/tier1.sh` and CI rely on):
//!   0 — clean, no findings
//!   1 — one or more findings (each printed to stdout)
//!   2 — usage error or I/O failure (message on stderr)

use std::path::PathBuf;
use std::process::ExitCode;

use moe_infinity::lint::{lint_tree, rules::RULES, LINT_ROOTS};

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--rules" => {
                for r in RULES {
                    println!("{}  {:<11} {}", r.id, r.name, r.summary);
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!("usage: moelint [--json] [--rules] [ROOT]");
                println!("lints {} for determinism & hot-path rules", LINT_ROOTS.join(", "));
                println!("exit codes: 0 clean, 1 findings, 2 usage/IO error");
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("moelint: unknown option `{a}` (try --help)");
                return ExitCode::from(2);
            }
            a => {
                if root.is_some() {
                    eprintln!("moelint: more than one ROOT argument");
                    return ExitCode::from(2);
                }
                root = Some(PathBuf::from(a));
            }
        }
    }
    let root = root.unwrap_or_else(|| PathBuf::from("."));
    if !root.join("rust/src").is_dir() {
        eprintln!(
            "moelint: `{}` does not look like the repo root (no rust/src)",
            root.display()
        );
        return ExitCode::from(2);
    }

    let findings = match lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("moelint: walk failed: {e}");
            return ExitCode::from(2);
        }
    };
    for f in &findings {
        if json {
            println!("{}", f.to_json());
        } else {
            println!("{f}");
        }
    }
    if findings.is_empty() {
        eprintln!("moelint: clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("moelint: {} finding(s)", findings.len());
        ExitCode::from(1)
    }
}
