//! `moelint` CLI — lint the repo's determinism & hot-path rules.
//!
//! Usage: `moelint [--json] [--rules] [--stats] [ROOT]`
//!
//! * `ROOT` defaults to the current directory; it must contain `rust/src`
//!   (the walk covers `rust/src`, `rust/benches`, `rust/tests`).
//! * `--json` emits newline-delimited JSON objects instead of the
//!   gcc-style `path:line:col: moelint(rule): msg` lines.
//! * `--rules` prints the rule catalogue and exits 0.
//! * `--stats` appends the per-rule finding/pragma tally (a table, or one
//!   JSON object under `--json` — the CI artifact row).
//!
//! When `scripts/lint_budget.json` exists under ROOT, the per-rule pragma
//! counts are checked against it: exceeding any rule's budgeted cap is a
//! failure even with zero findings, so suppression debt can shrink
//! silently but never grow.
//!
//! Exit codes (the contract `scripts/tier1.sh` and CI rely on):
//!   0 — clean, no findings, within pragma budget
//!   1 — one or more findings, or pragma budget exceeded
//!   2 — usage error or I/O failure (message on stderr)

use std::path::PathBuf;
use std::process::ExitCode;

use moe_infinity::lint::{
    check_budget, lint_tree_with_stats, parse_budget, rules::RULES, BUDGET_PATH, LINT_ROOTS,
};

fn main() -> ExitCode {
    let mut json = false;
    let mut stats_out = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--stats" => stats_out = true,
            "--rules" => {
                for r in RULES {
                    println!("{}  {:<16} {}", r.id, r.name, r.summary);
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!("usage: moelint [--json] [--rules] [--stats] [ROOT]");
                println!("lints {} for determinism & hot-path rules", LINT_ROOTS.join(", "));
                println!("exit codes: 0 clean, 1 findings/budget exceeded, 2 usage/IO error");
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("moelint: unknown option `{a}` (try --help)");
                return ExitCode::from(2);
            }
            a => {
                if root.is_some() {
                    eprintln!("moelint: more than one ROOT argument");
                    return ExitCode::from(2);
                }
                root = Some(PathBuf::from(a));
            }
        }
    }
    let root = root.unwrap_or_else(|| PathBuf::from("."));
    if !root.join("rust/src").is_dir() {
        eprintln!(
            "moelint: `{}` does not look like the repo root (no rust/src)",
            root.display()
        );
        return ExitCode::from(2);
    }

    let (findings, stats) = match lint_tree_with_stats(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("moelint: walk failed: {e}");
            return ExitCode::from(2);
        }
    };
    for f in &findings {
        if json {
            println!("{}", f.to_json());
        } else {
            println!("{f}");
        }
    }

    // pragma-budget ratchet: enforced whenever the budget file exists
    let mut violations = Vec::new();
    let budget_file = root.join(BUDGET_PATH);
    if budget_file.is_file() {
        match std::fs::read_to_string(&budget_file) {
            Ok(src) => match parse_budget(&src) {
                Some(budget) => violations = check_budget(&stats, &budget),
                None => {
                    eprintln!("moelint: `{}` is not a flat {{\"rule\": n}} object", BUDGET_PATH);
                    return ExitCode::from(2);
                }
            },
            Err(e) => {
                eprintln!("moelint: cannot read `{}`: {e}", BUDGET_PATH);
                return ExitCode::from(2);
            }
        }
    }
    for v in &violations {
        eprintln!("moelint: {v}");
    }

    if stats_out {
        if json {
            println!("{}", stats.to_json());
        } else {
            println!("{:<16} {:>8} {:>8}", "rule", "findings", "pragmas");
            for (name, f, p) in &stats.per_rule {
                println!("{name:<16} {f:>8} {p:>8}");
            }
            println!(
                "{:<16} {:>8} {:>8}",
                "total",
                stats.total_findings(),
                stats.total_pragmas()
            );
        }
    }

    if findings.is_empty() && violations.is_empty() {
        eprintln!("moelint: clean");
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "moelint: {} finding(s), {} budget violation(s)",
            findings.len(),
            violations.len()
        );
        ExitCode::from(1)
    }
}
