//! Activation-aware expert caching (paper §6) and the replacement-policy
//! zoo it is benchmarked against (§8.4 baselines plus the classic
//! web-cache designs).
//!
//! A cache tier holds up to `capacity` experts (experts are uniformly sized,
//! so capacity is expressed in expert slots; byte budgets are converted by
//! the caller). Replacement is pluggable **per tier**: `TierConfig` carries
//! independent `gpu_policy` / `dram_policy` kinds, and every policy receives
//! a [`CacheCtx`] stamped with the tier it serves ([`CacheTier`]) and the
//! cost of re-fetching an evicted entry from that tier's backing store
//! (`fetch_cost`, derived from the inbound [`crate::memory::Link`]):
//!
//! * [`ActivationPolicy`] — the paper's Algorithm 2: victim = cached expert
//!   with minimal `(cur_ratio + ε) · (1 − layer_idx/L)` (reference scan).
//! * [`IndexedActivationPolicy`] — the same decisions from an incrementally
//!   maintained lazy-deletion heap: O(log n) steady-state victim picks
//!   (what the serving stack instantiates).
//! * [`LruPolicy`] — CUDA-unified-memory-style least-recently-used.
//! * [`LfuPolicy`] — BrainStorm-style least-frequently-used (counter resets
//!   on eviction, the weakness §8.4 calls out).
//! * [`LfuDaPolicy`] — LFU with dynamic aging (`K = freq + age`, age jumps
//!   to the victim's K on eviction), fixing the counter-reset weakness:
//!   re-inserted entries start competitive with long-resident ones.
//! * [`SlruPolicy`] — segmented LRU: probation/protected segments, so one
//!   scan cannot flush entries that were ever re-referenced.
//! * [`GdsfPolicy`] — GreedyDual-Size-Frequency: priority
//!   `H = age + freq · fetch_cost`, the first cost-aware policy (uses the
//!   per-tier `fetch_cost` in [`CacheCtx`]).
//! * [`NeighborPolicy`] — ZeRO-Infinity-style: keep id-neighbors together.
//! * [`OraclePolicy`] — Belady's optimal from a known future access trace,
//!   the §8.4 upper bound.
//!
//! Every O(log n) heap policy is pinned by a differential proptest against
//! a naive reference scan (`tests/properties.rs`); `perf_tiers` sweeps the
//! zoo across tier shapes into `BENCH_tiers.json`.

mod policies;

pub use policies::{
    ActivationPolicy, GdsfPolicy, IndexedActivationPolicy, LfuDaPolicy, LfuPolicy, LruPolicy,
    NeighborPolicy, OraclePolicy, Policy, SlruPolicy,
};

use crate::model::ExpertKey;
use crate::util::{det_map_with_capacity, DetMap, DetSet};
use crate::trace::Eam;

/// Which tier of the memory hierarchy a cache instance serves. Kept local
/// to `cache/` (rather than reusing [`crate::memory::Tier`]) so policies
/// never depend on the simulator's tier topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheTier {
    Gpu,
    Dram,
}

/// Replacement-decision context: Algorithm 2 consults the EAM of the
/// sequence *currently being processed*; cost-aware policies (GDSF)
/// additionally consult the tier identity and backing-fetch cost.
#[derive(Clone, Copy)]
pub struct CacheCtx<'a> {
    pub cur_eam: &'a Eam,
    pub n_layers: usize,
    /// Which tier this decision is for. [`MemorySim`](crate::memory::MemorySim)
    /// re-stamps the context per tier; standalone callers default to `Gpu`.
    pub tier: CacheTier,
    /// Relative cost of re-fetching an evicted entry from this tier's
    /// backing store — the inbound link's per-expert service time, as a
    /// unit-free weight. `1.0` when unknown (standalone callers); the
    /// activation policy and all §8.4 baselines ignore it.
    pub fetch_cost: f64,
}

impl<'a> CacheCtx<'a> {
    /// Context with default tier identity (`Gpu`) and unit fetch cost —
    /// what every caller outside `MemorySim` wants.
    pub fn new(cur_eam: &'a Eam, n_layers: usize) -> CacheCtx<'a> {
        CacheCtx {
            cur_eam,
            n_layers,
            tier: CacheTier::Gpu,
            fetch_cost: 1.0,
        }
    }

    /// Re-stamp the tier identity and fetch cost (used by `MemorySim` to
    /// specialize one engine-provided context per cache tier).
    pub fn for_tier(mut self, tier: CacheTier, fetch_cost: f64) -> CacheCtx<'a> {
        self.tier = tier;
        self.fetch_cost = fetch_cost;
        self
    }
}

/// Which policy to instantiate (config / bench matrix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheKind {
    Activation,
    Lru,
    Lfu,
    Lfuda,
    Slru,
    Gdsf,
    Neighbor,
    Oracle,
}

impl CacheKind {
    pub fn name(&self) -> &'static str {
        match self {
            CacheKind::Activation => "activation",
            CacheKind::Lru => "lru",
            CacheKind::Lfu => "lfu",
            CacheKind::Lfuda => "lfuda",
            CacheKind::Slru => "slru",
            CacheKind::Gdsf => "gdsf",
            CacheKind::Neighbor => "neighbor",
            CacheKind::Oracle => "oracle",
        }
    }

    /// Inverse of [`CacheKind::name`] (config / CLI parsing).
    pub fn by_name(s: &str) -> Option<CacheKind> {
        match s {
            "activation" => Some(CacheKind::Activation),
            "lru" => Some(CacheKind::Lru),
            "lfu" => Some(CacheKind::Lfu),
            "lfuda" => Some(CacheKind::Lfuda),
            "slru" => Some(CacheKind::Slru),
            "gdsf" => Some(CacheKind::Gdsf),
            "neighbor" => Some(CacheKind::Neighbor),
            "oracle" => Some(CacheKind::Oracle),
            _ => None,
        }
    }
}

/// One cache tier with a pluggable replacement policy.
///
/// Supports *eviction protection* (paper §6.2: "give priority to prefetched
/// experts over those already cached"): protected keys — prefetched experts
/// that have not been used yet — are skipped during victim selection unless
/// every resident entry is protected.
pub struct ExpertCache {
    capacity: usize,
    slots: Vec<ExpertKey>,
    index: DetMap<ExpertKey, usize>,
    policy: Box<dyn Policy>,
    protected: DetSet<ExpertKey>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl ExpertCache {
    pub fn new(capacity: usize, policy: Box<dyn Policy>) -> ExpertCache {
        ExpertCache {
            capacity,
            slots: Vec::with_capacity(capacity),
            index: det_map_with_capacity(capacity),
            policy,
            protected: DetSet::default(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn contains(&self, key: ExpertKey) -> bool {
        self.index.contains_key(&key)
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Record an access; returns `true` on hit. Misses are counted but the
    /// caller decides whether/when to insert (after the fetch completes).
    pub fn access(&mut self, key: ExpertKey) -> bool {
        if self.index.contains_key(&key) {
            self.hits += 1;
            self.policy.on_access(key);
            true
        } else {
            self.misses += 1;
            self.policy.on_miss(key);
            false
        }
    }

    /// Insert after a fetch (Alg. 2 `PUT`). Returns the evicted expert, if
    /// the cache was full. Inserting a resident key refreshes its policy
    /// state and evicts nothing.
    pub fn insert(&mut self, key: ExpertKey, ctx: &CacheCtx) -> Option<ExpertKey> {
        if self.capacity == 0 {
            return None;
        }
        if self.index.contains_key(&key) {
            self.policy.on_access(key);
            return None;
        }
        let evicted = if self.slots.len() == self.capacity {
            let old = self.choose_victim(ctx);
            let v = *self.index.get(&old).expect("victim must be resident"); // moelint: allow(panic-free, choose_victim returns a key drawn from index; a miss is a corrupted-cache invariant worth crashing on)
            self.protected.remove(&old);
            self.policy.on_evict(old);
            self.index.remove(&old);
            self.slots[v] = key;
            self.index.insert(key, v);
            self.evictions += 1;
            Some(old)
        } else {
            self.slots.push(key);
            self.index.insert(key, self.slots.len() - 1);
            None
        };
        self.policy.on_insert(key);
        evicted
    }

    /// Victim selection with protection: the protected set is passed to the
    /// policy as an exclusion filter (no candidate materialization — this
    /// used to allocate two Vecs per eviction under protection). Protection
    /// is void when it would leave no candidates.
    fn choose_victim(&mut self, ctx: &CacheCtx) -> ExpertKey {
        if self.protected.is_empty() || self.protected.len() >= self.slots.len() {
            self.policy.victim(&self.slots, None, ctx)
        } else {
            self.policy.victim(&self.slots, Some(&self.protected), ctx)
        }
    }

    /// Mark a resident key as protected from eviction (prefetched, unused).
    pub fn protect(&mut self, key: ExpertKey) {
        if self.index.contains_key(&key) {
            self.protected.insert(key);
        }
    }

    /// Lift protection (the expert was used, or the sequence ended).
    pub fn unprotect(&mut self, key: ExpertKey) {
        self.protected.remove(&key);
    }

    pub fn clear_protection(&mut self) {
        self.protected.clear();
    }

    pub fn protected_count(&self) -> usize {
        self.protected.len()
    }

    pub fn is_protected(&self, key: ExpertKey) -> bool {
        self.protected.contains(&key)
    }

    /// Remove a specific key (used when an upper tier steals the slot).
    pub fn remove(&mut self, key: ExpertKey) -> bool {
        if let Some(i) = self.index.remove(&key) {
            self.protected.remove(&key);
            self.policy.on_evict(key);
            let last = self.slots.len() - 1;
            self.slots.swap(i, last);
            self.slots.pop();
            if i < self.slots.len() {
                self.index.insert(self.slots[i], i);
            }
            true
        } else {
            false
        }
    }

    /// Fraction of accesses that hit. Zero-access convention: `1.0` — an
    /// empty denominator means "nothing missed", not "everything missed" —
    /// matching [`crate::memory::MemoryStats::gpu_hit_ratio`],
    /// `MemoryStats::prefetch_coverage`, and `BatchResult::recall`.
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
    }

    pub fn keys(&self) -> &[ExpertKey] {
        &self.slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_with(eam: &Eam) -> CacheCtx<'_> {
        CacheCtx::new(eam, eam.layers())
    }

    #[test]
    fn fills_before_evicting() {
        let eam = Eam::new(2, 4);
        let mut c = ExpertCache::new(2, Box::new(LruPolicy::new()));
        assert!(c.insert(ExpertKey::new(0, 0), &ctx_with(&eam)).is_none());
        assert!(c.insert(ExpertKey::new(0, 1), &ctx_with(&eam)).is_none());
        let ev = c.insert(ExpertKey::new(1, 0), &ctx_with(&eam));
        assert!(ev.is_some());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn capacity_never_exceeded() {
        let eam = Eam::new(4, 16);
        let mut c = ExpertCache::new(3, Box::new(LfuPolicy::new()));
        for l in 0..4 {
            for e in 0..16 {
                c.insert(ExpertKey::new(l, e), &ctx_with(&eam));
                assert!(c.len() <= 3);
            }
        }
    }

    #[test]
    fn hit_miss_accounting() {
        let eam = Eam::new(2, 2);
        let mut c = ExpertCache::new(2, Box::new(LruPolicy::new()));
        let k = ExpertKey::new(0, 0);
        assert!(!c.access(k));
        c.insert(k, &ctx_with(&eam));
        assert!(c.access(k));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn reinsert_resident_key_is_noop() {
        let eam = Eam::new(2, 2);
        let mut c = ExpertCache::new(1, Box::new(LruPolicy::new()));
        let k = ExpertKey::new(0, 0);
        c.insert(k, &ctx_with(&eam));
        assert!(c.insert(k, &ctx_with(&eam)).is_none());
        assert_eq!(c.len(), 1);
        assert_eq!(c.evictions(), 0);
    }

    #[test]
    fn remove_keeps_index_consistent() {
        let eam = Eam::new(2, 4);
        let mut c = ExpertCache::new(3, Box::new(LruPolicy::new()));
        let (a, b, d) = (ExpertKey::new(0, 0), ExpertKey::new(0, 1), ExpertKey::new(0, 2));
        c.insert(a, &ctx_with(&eam));
        c.insert(b, &ctx_with(&eam));
        c.insert(d, &ctx_with(&eam));
        assert!(c.remove(a));
        assert!(!c.remove(a));
        assert!(c.contains(b) && c.contains(d));
        assert_eq!(c.len(), 2);
        // after swap-remove, access to the moved key still works
        assert!(c.access(d));
    }

    #[test]
    fn zero_capacity_cache_accepts_nothing() {
        let eam = Eam::new(1, 1);
        let mut c = ExpertCache::new(0, Box::new(LruPolicy::new()));
        assert!(c.insert(ExpertKey::new(0, 0), &ctx_with(&eam)).is_none());
        assert_eq!(c.len(), 0);
        assert!(!c.contains(ExpertKey::new(0, 0)));
    }

    #[test]
    fn zero_access_hit_ratio_is_unity() {
        // the cross-crate zero-denominator convention: an untouched cache
        // reports 1.0 ("nothing missed"), exactly like
        // MemoryStats::gpu_hit_ratio and prefetch_coverage
        let c = ExpertCache::new(4, Box::new(LruPolicy::new()));
        assert_eq!(c.hit_ratio(), 1.0);
        let mut c2 = ExpertCache::new(4, Box::new(LruPolicy::new()));
        assert!(!c2.access(ExpertKey::new(0, 0)));
        assert_eq!(c2.hit_ratio(), 0.0, "one miss drops the ratio to 0");
        c2.reset_stats();
        assert_eq!(c2.hit_ratio(), 1.0, "reset restores the empty convention");
    }

    #[test]
    fn all_protected_voids_protection() {
        // §6.2 edge case: when every resident is protected, protection is
        // void and the policy still yields a victim (no wedge, no panic)
        let eam = Eam::new(1, 8);
        let mut c = ExpertCache::new(2, Box::new(LruPolicy::new()));
        let (a, b, d) = (ExpertKey::new(0, 0), ExpertKey::new(0, 1), ExpertKey::new(0, 2));
        c.insert(a, &ctx_with(&eam));
        c.insert(b, &ctx_with(&eam));
        c.protect(a);
        c.protect(b);
        assert_eq!(c.protected_count(), 2);
        let ev = c.insert(d, &ctx_with(&eam));
        assert_eq!(ev, Some(a), "LRU victim despite both entries being protected");
        assert!(!c.is_protected(a), "eviction clears the victim's protection");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn remove_clears_protection() {
        let eam = Eam::new(1, 8);
        let mut c = ExpertCache::new(3, Box::new(LruPolicy::new()));
        let (a, b) = (ExpertKey::new(0, 0), ExpertKey::new(0, 1));
        c.insert(a, &ctx_with(&eam));
        c.insert(b, &ctx_with(&eam));
        c.protect(a);
        assert!(c.is_protected(a));
        assert!(c.remove(a));
        assert!(!c.is_protected(a), "remove() must clear the protected set");
        assert_eq!(c.protected_count(), 0);
        // a re-inserted key does not inherit stale protection
        c.insert(a, &ctx_with(&eam));
        assert!(!c.is_protected(a));
    }
}
