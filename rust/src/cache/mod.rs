//! Activation-aware expert caching (paper §6) and the baseline policies the
//! paper compares against (§8.4).
//!
//! A cache tier holds up to `capacity` experts (experts are uniformly sized,
//! so capacity is expressed in expert slots; byte budgets are converted by
//! the caller). Replacement is pluggable:
//!
//! * [`ActivationPolicy`] — the paper's Algorithm 2: victim = cached expert
//!   with minimal `(cur_ratio + ε) · (1 − layer_idx/L)` (reference scan).
//! * [`IndexedActivationPolicy`] — the same decisions from an incrementally
//!   maintained lazy-deletion heap: O(log n) steady-state victim picks
//!   (what the serving stack instantiates).
//! * [`LruPolicy`] — CUDA-unified-memory-style least-recently-used.
//! * [`LfuPolicy`] — BrainStorm-style least-frequently-used (counter resets
//!   on eviction, the weakness §8.4 calls out).
//! * [`NeighborPolicy`] — ZeRO-Infinity-style: keep id-neighbors together.
//! * [`OraclePolicy`] — Belady's optimal from a known future access trace,
//!   the §8.4 upper bound.

mod policies;

pub use policies::{
    ActivationPolicy, IndexedActivationPolicy, LfuPolicy, LruPolicy, NeighborPolicy,
    OraclePolicy, Policy,
};

use crate::model::ExpertKey;
use crate::util::{det_map_with_capacity, DetMap, DetSet};
use crate::trace::Eam;

/// Replacement-decision context: Algorithm 2 consults the EAM of the
/// sequence *currently being processed*.
pub struct CacheCtx<'a> {
    pub cur_eam: &'a Eam,
    pub n_layers: usize,
}

/// Which policy to instantiate (config / bench matrix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheKind {
    Activation,
    Lru,
    Lfu,
    Neighbor,
    Oracle,
}

impl CacheKind {
    pub fn name(&self) -> &'static str {
        match self {
            CacheKind::Activation => "activation",
            CacheKind::Lru => "lru",
            CacheKind::Lfu => "lfu",
            CacheKind::Neighbor => "neighbor",
            CacheKind::Oracle => "oracle",
        }
    }
}

/// One cache tier with a pluggable replacement policy.
///
/// Supports *eviction protection* (paper §6.2: "give priority to prefetched
/// experts over those already cached"): protected keys — prefetched experts
/// that have not been used yet — are skipped during victim selection unless
/// every resident entry is protected.
pub struct ExpertCache {
    capacity: usize,
    slots: Vec<ExpertKey>,
    index: DetMap<ExpertKey, usize>,
    policy: Box<dyn Policy>,
    protected: DetSet<ExpertKey>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl ExpertCache {
    pub fn new(capacity: usize, policy: Box<dyn Policy>) -> ExpertCache {
        ExpertCache {
            capacity,
            slots: Vec::with_capacity(capacity),
            index: det_map_with_capacity(capacity),
            policy,
            protected: DetSet::default(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn contains(&self, key: ExpertKey) -> bool {
        self.index.contains_key(&key)
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Record an access; returns `true` on hit. Misses are counted but the
    /// caller decides whether/when to insert (after the fetch completes).
    pub fn access(&mut self, key: ExpertKey) -> bool {
        if self.index.contains_key(&key) {
            self.hits += 1;
            self.policy.on_access(key);
            true
        } else {
            self.misses += 1;
            self.policy.on_miss(key);
            false
        }
    }

    /// Insert after a fetch (Alg. 2 `PUT`). Returns the evicted expert, if
    /// the cache was full. Inserting a resident key refreshes its policy
    /// state and evicts nothing.
    pub fn insert(&mut self, key: ExpertKey, ctx: &CacheCtx) -> Option<ExpertKey> {
        if self.capacity == 0 {
            return None;
        }
        if self.index.contains_key(&key) {
            self.policy.on_access(key);
            return None;
        }
        let evicted = if self.slots.len() == self.capacity {
            let old = self.choose_victim(ctx);
            let v = *self.index.get(&old).expect("victim must be resident"); // moelint: allow(panic-free, choose_victim returns a key drawn from index; a miss is a corrupted-cache invariant worth crashing on)
            self.protected.remove(&old);
            self.policy.on_evict(old);
            self.index.remove(&old);
            self.slots[v] = key;
            self.index.insert(key, v);
            self.evictions += 1;
            Some(old)
        } else {
            self.slots.push(key);
            self.index.insert(key, self.slots.len() - 1);
            None
        };
        self.policy.on_insert(key);
        evicted
    }

    /// Victim selection with protection: the protected set is passed to the
    /// policy as an exclusion filter (no candidate materialization — this
    /// used to allocate two Vecs per eviction under protection). Protection
    /// is void when it would leave no candidates.
    fn choose_victim(&mut self, ctx: &CacheCtx) -> ExpertKey {
        if self.protected.is_empty() || self.protected.len() >= self.slots.len() {
            self.policy.victim(&self.slots, None, ctx)
        } else {
            self.policy.victim(&self.slots, Some(&self.protected), ctx)
        }
    }

    /// Mark a resident key as protected from eviction (prefetched, unused).
    pub fn protect(&mut self, key: ExpertKey) {
        if self.index.contains_key(&key) {
            self.protected.insert(key);
        }
    }

    /// Lift protection (the expert was used, or the sequence ended).
    pub fn unprotect(&mut self, key: ExpertKey) {
        self.protected.remove(&key);
    }

    pub fn clear_protection(&mut self) {
        self.protected.clear();
    }

    pub fn protected_count(&self) -> usize {
        self.protected.len()
    }

    pub fn is_protected(&self, key: ExpertKey) -> bool {
        self.protected.contains(&key)
    }

    /// Remove a specific key (used when an upper tier steals the slot).
    pub fn remove(&mut self, key: ExpertKey) -> bool {
        if let Some(i) = self.index.remove(&key) {
            self.protected.remove(&key);
            self.policy.on_evict(key);
            let last = self.slots.len() - 1;
            self.slots.swap(i, last);
            self.slots.pop();
            if i < self.slots.len() {
                self.index.insert(self.slots[i], i);
            }
            true
        } else {
            false
        }
    }

    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
    }

    pub fn keys(&self) -> &[ExpertKey] {
        &self.slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_with(eam: &Eam) -> CacheCtx<'_> {
        CacheCtx {
            cur_eam: eam,
            n_layers: eam.layers(),
        }
    }

    #[test]
    fn fills_before_evicting() {
        let eam = Eam::new(2, 4);
        let mut c = ExpertCache::new(2, Box::new(LruPolicy::new()));
        assert!(c.insert(ExpertKey::new(0, 0), &ctx_with(&eam)).is_none());
        assert!(c.insert(ExpertKey::new(0, 1), &ctx_with(&eam)).is_none());
        let ev = c.insert(ExpertKey::new(1, 0), &ctx_with(&eam));
        assert!(ev.is_some());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn capacity_never_exceeded() {
        let eam = Eam::new(4, 16);
        let mut c = ExpertCache::new(3, Box::new(LfuPolicy::new()));
        for l in 0..4 {
            for e in 0..16 {
                c.insert(ExpertKey::new(l, e), &ctx_with(&eam));
                assert!(c.len() <= 3);
            }
        }
    }

    #[test]
    fn hit_miss_accounting() {
        let eam = Eam::new(2, 2);
        let mut c = ExpertCache::new(2, Box::new(LruPolicy::new()));
        let k = ExpertKey::new(0, 0);
        assert!(!c.access(k));
        c.insert(k, &ctx_with(&eam));
        assert!(c.access(k));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn reinsert_resident_key_is_noop() {
        let eam = Eam::new(2, 2);
        let mut c = ExpertCache::new(1, Box::new(LruPolicy::new()));
        let k = ExpertKey::new(0, 0);
        c.insert(k, &ctx_with(&eam));
        assert!(c.insert(k, &ctx_with(&eam)).is_none());
        assert_eq!(c.len(), 1);
        assert_eq!(c.evictions(), 0);
    }

    #[test]
    fn remove_keeps_index_consistent() {
        let eam = Eam::new(2, 4);
        let mut c = ExpertCache::new(3, Box::new(LruPolicy::new()));
        let (a, b, d) = (ExpertKey::new(0, 0), ExpertKey::new(0, 1), ExpertKey::new(0, 2));
        c.insert(a, &ctx_with(&eam));
        c.insert(b, &ctx_with(&eam));
        c.insert(d, &ctx_with(&eam));
        assert!(c.remove(a));
        assert!(!c.remove(a));
        assert!(c.contains(b) && c.contains(d));
        assert_eq!(c.len(), 2);
        // after swap-remove, access to the moved key still works
        assert!(c.access(d));
    }

    #[test]
    fn zero_capacity_cache_accepts_nothing() {
        let eam = Eam::new(1, 1);
        let mut c = ExpertCache::new(0, Box::new(LruPolicy::new()));
        assert!(c.insert(ExpertKey::new(0, 0), &ctx_with(&eam)).is_none());
        assert_eq!(c.len(), 0);
        assert!(!c.contains(ExpertKey::new(0, 0)));
    }
}
