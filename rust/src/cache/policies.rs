//! Cache replacement policies (paper Alg. 2 + §8.4 baselines).

use std::collections::HashMap;

use crate::cache::CacheCtx;
use crate::model::ExpertKey;
use crate::prefetch::EPSILON;

/// Replacement policy plugged into [`crate::cache::ExpertCache`].
pub trait Policy {
    fn name(&self) -> &'static str;
    /// Pick the victim's index in `entries` (must be `< entries.len()`).
    fn victim(&mut self, entries: &[ExpertKey], ctx: &CacheCtx) -> usize;
    fn on_access(&mut self, _key: ExpertKey) {}
    fn on_miss(&mut self, _key: ExpertKey) {}
    fn on_insert(&mut self, _key: ExpertKey) {}
    fn on_evict(&mut self, _key: ExpertKey) {}
}

// ---------------------------------------------------------------- Algorithm 2

/// The paper's activation-aware replacement (Alg. 2): evict the cached
/// expert with minimal `(ratio_in_cur_eam + ε) · (1 − layer/L)`.
///
/// Two awareness terms (§6.1): experts frequently activated by the sequence
/// being processed are kept (temporal locality across iterations); experts
/// in early layers are kept (prefetching cannot cover them, §6.1 reason 2).
#[derive(Debug, Default)]
pub struct ActivationPolicy {
    /// Optionally disable one of the two terms (§8.4 priority breakdown).
    pub use_ratio: bool,
    pub use_layer_decay: bool,
}

impl ActivationPolicy {
    pub fn new() -> ActivationPolicy {
        ActivationPolicy {
            use_ratio: true,
            use_layer_decay: true,
        }
    }

    /// Ablated variant for the §8.4 breakdown benches.
    pub fn with_terms(use_ratio: bool, use_layer_decay: bool) -> ActivationPolicy {
        ActivationPolicy {
            use_ratio,
            use_layer_decay,
        }
    }
}

impl Policy for ActivationPolicy {
    fn name(&self) -> &'static str {
        "activation"
    }

    fn victim(&mut self, entries: &[ExpertKey], ctx: &CacheCtx) -> usize {
        let mut min_p = f64::INFINITY;
        let mut idx = 0;
        for (i, e) in entries.iter().enumerate() {
            let ratio = if self.use_ratio {
                ctx.cur_eam.ratio(e.layer as usize, e.expert as usize) as f64
            } else {
                0.0
            };
            let decay = if self.use_layer_decay {
                1.0 - e.layer as f64 / ctx.n_layers as f64
            } else {
                1.0
            };
            let p = (ratio + EPSILON) * decay;
            if p < min_p {
                min_p = p;
                idx = i;
            }
        }
        idx
    }
}

// ------------------------------------------------------------------------ LRU

/// Least-recently-used (CUDA unified memory / Sentinel / DeepUM).
#[derive(Debug, Default)]
pub struct LruPolicy {
    clock: u64,
    last: HashMap<ExpertKey, u64>,
}

impl LruPolicy {
    pub fn new() -> LruPolicy {
        LruPolicy::default()
    }
    fn tick(&mut self, key: ExpertKey) {
        self.clock += 1;
        self.last.insert(key, self.clock);
    }
}

impl Policy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }
    fn victim(&mut self, entries: &[ExpertKey], _ctx: &CacheCtx) -> usize {
        entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| self.last.get(e).copied().unwrap_or(0))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
    fn on_access(&mut self, key: ExpertKey) {
        self.tick(key);
    }
    fn on_insert(&mut self, key: ExpertKey) {
        self.tick(key);
    }
    fn on_evict(&mut self, key: ExpertKey) {
        self.last.remove(&key);
    }
}

// ------------------------------------------------------------------------ LFU

/// Least-frequently-used (BrainStorm). The frequency counter covers only
/// the cache residency period — it resets on eviction, which is exactly the
/// cross-iteration blindness §8.4 demonstrates.
#[derive(Debug, Default)]
pub struct LfuPolicy {
    counts: HashMap<ExpertKey, u64>,
}

impl LfuPolicy {
    pub fn new() -> LfuPolicy {
        LfuPolicy::default()
    }
}

impl Policy for LfuPolicy {
    fn name(&self) -> &'static str {
        "lfu"
    }
    fn victim(&mut self, entries: &[ExpertKey], _ctx: &CacheCtx) -> usize {
        entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| self.counts.get(e).copied().unwrap_or(0))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
    fn on_access(&mut self, key: ExpertKey) {
        *self.counts.entry(key).or_insert(0) += 1;
    }
    fn on_insert(&mut self, key: ExpertKey) {
        *self.counts.entry(key).or_insert(0) += 1;
    }
    fn on_evict(&mut self, key: ExpertKey) {
        // counter reset on eviction — reuse across residencies is lost
        self.counts.remove(&key);
    }
}

// -------------------------------------------------------------- Neighbor-aware

/// ZeRO-Infinity's neighbor-aware policy: experts adjacent by id in the same
/// layer are kept together (parameters are fetched in contiguous blocks).
/// Victim = entry with the fewest resident id-neighbors; LRU tie-break.
#[derive(Debug, Default)]
pub struct NeighborPolicy {
    lru: LruPolicy,
}

impl NeighborPolicy {
    pub fn new() -> NeighborPolicy {
        NeighborPolicy::default()
    }
}

impl Policy for NeighborPolicy {
    fn name(&self) -> &'static str {
        "neighbor"
    }
    fn victim(&mut self, entries: &[ExpertKey], _ctx: &CacheCtx) -> usize {
        let resident: std::collections::HashSet<ExpertKey> = entries.iter().copied().collect();
        let score = |e: &ExpertKey| -> u32 {
            let mut s = 0;
            if e.expert > 0 && resident.contains(&ExpertKey {
                layer: e.layer,
                expert: e.expert - 1,
            }) {
                s += 1;
            }
            if resident.contains(&ExpertKey {
                layer: e.layer,
                expert: e.expert + 1,
            }) {
                s += 1;
            }
            s
        };
        entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| (score(e), self.lru.last.get(e).copied().unwrap_or(0)))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
    fn on_access(&mut self, key: ExpertKey) {
        self.lru.on_access(key);
    }
    fn on_insert(&mut self, key: ExpertKey) {
        self.lru.on_insert(key);
    }
    fn on_evict(&mut self, key: ExpertKey) {
        self.lru.on_evict(key);
    }
}

// --------------------------------------------------------------------- Oracle

/// Belady's optimal replacement from a known future access sequence
/// (§8.4's ORACLE upper bound, "theoretical best through trace analysis").
///
/// Construct with the full access trace; an internal cursor advances on
/// every `on_access`/`on_miss`, so victims are chosen by true next-use.
#[derive(Debug)]
pub struct OraclePolicy {
    /// Per-expert sorted future access positions.
    future: HashMap<ExpertKey, Vec<u64>>,
    /// Per-expert cursor into `future`.
    cursor: HashMap<ExpertKey, usize>,
    now: u64,
}

impl OraclePolicy {
    pub fn from_trace(trace: &[ExpertKey]) -> OraclePolicy {
        let mut future: HashMap<ExpertKey, Vec<u64>> = HashMap::new();
        for (t, k) in trace.iter().enumerate() {
            future.entry(*k).or_default().push(t as u64);
        }
        OraclePolicy {
            future,
            cursor: HashMap::new(),
            now: 0,
        }
    }

    fn next_use(&self, key: &ExpertKey) -> u64 {
        match self.future.get(key) {
            None => u64::MAX,
            Some(times) => {
                let c = self.cursor.get(key).copied().unwrap_or(0);
                times[c..]
                    .iter()
                    .find(|&&t| t >= self.now)
                    .copied()
                    .unwrap_or(u64::MAX)
            }
        }
    }

    fn advance(&mut self, key: ExpertKey) {
        let c = self.cursor.entry(key).or_insert(0);
        if let Some(times) = self.future.get(&key) {
            while *c < times.len() && times[*c] <= self.now {
                *c += 1;
            }
        }
        self.now += 1;
    }
}

impl Policy for OraclePolicy {
    fn name(&self) -> &'static str {
        "oracle"
    }
    fn victim(&mut self, entries: &[ExpertKey], _ctx: &CacheCtx) -> usize {
        entries
            .iter()
            .enumerate()
            .max_by_key(|(_, e)| self.next_use(e))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
    fn on_access(&mut self, key: ExpertKey) {
        self.advance(key);
    }
    fn on_miss(&mut self, key: ExpertKey) {
        self.advance(key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheCtx, ExpertCache};
    use crate::trace::Eam;

    fn k(l: usize, e: usize) -> ExpertKey {
        ExpertKey::new(l, e)
    }

    #[test]
    fn activation_policy_evicts_low_ratio_late_layer() {
        let mut eam = Eam::new(4, 4);
        eam.record(0, 0, 10); // L0E0 hot
        eam.record(3, 1, 1); // L3E1 cold-ish, late layer
        eam.record(1, 2, 5); // L1E2 warm
        let ctx = CacheCtx {
            cur_eam: &eam,
            n_layers: 4,
        };
        let mut p = ActivationPolicy::new();
        let entries = vec![k(0, 0), k(3, 1), k(1, 2)];
        // L3E1: ratio 1.0 but decay 0.25; L0E0: ratio 1.0 decay 1.0;
        // L1E2: ratio 1.0 decay 0.75 — victim is the late-layer one.
        assert_eq!(p.victim(&entries, &ctx), 1);
    }

    #[test]
    fn activation_policy_prefers_early_layers_at_equal_ratio() {
        let eam = Eam::new(4, 4); // all ratios zero
        let ctx = CacheCtx {
            cur_eam: &eam,
            n_layers: 4,
        };
        let mut p = ActivationPolicy::new();
        let entries = vec![k(0, 0), k(2, 0), k(3, 0)];
        assert_eq!(p.victim(&entries, &ctx), 2, "latest layer evicted first");
    }

    #[test]
    fn activation_ablations_change_choice() {
        let mut eam = Eam::new(4, 4);
        eam.record(3, 0, 10); // late layer, hot (ratio 1.0 in its row)
        eam.record(0, 1, 1); // early layer, cold (ratio 0.1 in its row)
        eam.record(0, 3, 9); // make layer-0 row sum 10 so E1's ratio is low
        let ctx = CacheCtx {
            cur_eam: &eam,
            n_layers: 4,
        };
        let entries = vec![k(3, 0), k(0, 1)];
        // ratio-only: evicts the cold one (index 1)
        let mut ratio_only = ActivationPolicy::with_terms(true, false);
        assert_eq!(ratio_only.victim(&entries, &ctx), 1);
        // decay-only: evicts the late one (index 0)
        let mut decay_only = ActivationPolicy::with_terms(false, true);
        assert_eq!(decay_only.victim(&entries, &ctx), 0);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx {
            cur_eam: &eam,
            n_layers: 1,
        };
        let mut c = ExpertCache::new(2, Box::new(LruPolicy::new()));
        c.insert(k(0, 0), &ctx);
        c.insert(k(0, 1), &ctx);
        c.access(k(0, 0)); // 0 is now MRU
        let ev = c.insert(k(0, 2), &ctx).unwrap();
        assert_eq!(ev, k(0, 1));
    }

    #[test]
    fn lfu_evicts_least_frequent_and_resets() {
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx {
            cur_eam: &eam,
            n_layers: 1,
        };
        let mut c = ExpertCache::new(2, Box::new(LfuPolicy::new()));
        c.insert(k(0, 0), &ctx);
        for _ in 0..5 {
            c.access(k(0, 0));
        }
        c.insert(k(0, 1), &ctx);
        let ev = c.insert(k(0, 2), &ctx).unwrap();
        assert_eq!(ev, k(0, 1), "lower-count entry evicted");
        // k(0,1)'s counter was reset on eviction; re-inserting it now makes
        // it count 1 vs k(0,2)'s 1 — the freshly reset entry loses the
        // cross-residency history LFU would have needed (§8.4's point).
        let ev2 = c.insert(k(0, 1), &ctx).unwrap();
        assert_eq!(ev2, k(0, 2), "victim is the other count-1 entry");
        assert!(c.contains(k(0, 0)), "hot expert survives");
    }

    #[test]
    fn neighbor_keeps_contiguous_runs() {
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx {
            cur_eam: &eam,
            n_layers: 1,
        };
        let mut p = NeighborPolicy::new();
        // 0,1,2 contiguous; 5 isolated
        let entries = vec![k(0, 0), k(0, 1), k(0, 2), k(0, 5)];
        assert_eq!(p.victim(&entries, &ctx), 3, "isolated expert evicted");
    }

    #[test]
    fn oracle_is_belady() {
        // trace: A B C A B  with capacity 2: at inserting C, evict the one
        // used farthest in future = C? no — cached {A,B}; A next at 3, B at
        // 4 -> evict B.
        let trace = vec![k(0, 0), k(0, 1), k(0, 2), k(0, 0), k(0, 1)];
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx {
            cur_eam: &eam,
            n_layers: 1,
        };
        let mut c = ExpertCache::new(2, Box::new(OraclePolicy::from_trace(&trace)));
        // replay
        c.access(trace[0]);
        c.insert(trace[0], &ctx);
        c.access(trace[1]);
        c.insert(trace[1], &ctx);
        c.access(trace[2]);
        let ev = c.insert(trace[2], &ctx).unwrap();
        assert_eq!(ev, k(0, 1), "B (next use later) is the Belady victim");
        assert!(c.access(trace[3]), "A must still be cached");
    }

    #[test]
    fn oracle_beats_lru_on_looping_trace() {
        // classic LRU-adversarial loop: 0 1 2 0 1 2 ... with capacity 2.
        let mut trace = Vec::new();
        for _ in 0..30 {
            for e in 0..3 {
                trace.push(k(0, e));
            }
        }
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx {
            cur_eam: &eam,
            n_layers: 1,
        };
        let run = |policy: Box<dyn Policy>| -> f64 {
            let mut c = ExpertCache::new(2, policy);
            for &key in &trace {
                if !c.access(key) {
                    c.insert(key, &ctx);
                }
            }
            c.hit_ratio()
        };
        let lru = run(Box::new(LruPolicy::new()));
        let oracle = run(Box::new(OraclePolicy::from_trace(&trace)));
        assert!(oracle > lru, "oracle {oracle} must beat lru {lru}");
        assert!(lru < 0.05, "LRU thrashes the loop");
        assert!(oracle > 0.4, "oracle keeps one hot line");
    }
}
