//! Cache replacement policies (paper Alg. 2 + §8.4 baselines).
//!
//! Two implementations of the paper's activation-aware priority exist:
//!
//! * [`ActivationPolicy`] — the straightforward O(capacity) scan, kept as
//!   the differential-testing reference and for the §8.4 ablations.
//! * [`IndexedActivationPolicy`] — an incrementally maintained lazy-deletion
//!   min-heap keyed on `(ratio + ε)·(1 − l/L)`. Heap entries are invalidated
//!   only for rows whose activation ratios actually changed (tracked via
//!   [`crate::trace::Eam::row_version`]), so the steady-state victim pick is
//!   O(log n) instead of a full scan. Decisions are identical to the scan
//!   (same priority expression, same `(priority, key)` tie-break) — pinned
//!   by differential proptests in `tests/properties.rs`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cache::CacheCtx;
use crate::model::ExpertKey;
use crate::prefetch::EPSILON;
use crate::util::{DetMap, DetSet};

/// Replacement policy plugged into [`crate::cache::ExpertCache`].
pub trait Policy {
    fn name(&self) -> &'static str;
    /// Pick the victim among `entries` (must return one of them). Keys in
    /// `excluded` are skipped (eviction protection, §6.2) unless every
    /// entry is excluded, in which case the exclusion is ignored.
    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        ctx: &CacheCtx,
    ) -> ExpertKey;
    fn on_access(&mut self, _key: ExpertKey) {}
    fn on_miss(&mut self, _key: ExpertKey) {}
    fn on_insert(&mut self, _key: ExpertKey) {}
    fn on_evict(&mut self, _key: ExpertKey) {}
}

/// First-strictly-smaller scan over `entries` with exclusion handling:
/// pass 0 skips excluded keys; if that leaves no candidate, pass 1 ignores
/// the exclusion (the caller guaranteed eviction must happen).
fn pick_min<K: PartialOrd>(
    entries: &[ExpertKey],
    excluded: Option<&DetSet<ExpertKey>>,
    mut score: impl FnMut(&ExpertKey) -> K,
) -> ExpertKey {
    debug_assert!(!entries.is_empty());
    let mut best: Option<(K, ExpertKey)> = None;
    for pass in 0..2 {
        for e in entries {
            if pass == 0 {
                if let Some(x) = excluded {
                    if x.contains(e) {
                        continue;
                    }
                }
            }
            let s = score(e);
            match &best {
                None => best = Some((s, *e)),
                Some((bs, _)) => {
                    if s < *bs {
                        best = Some((s, *e));
                    }
                }
            }
        }
        if best.is_some() {
            break;
        }
    }
    best.expect("non-empty entries always yield a victim").1 // moelint: allow(panic-free, callers guarantee entries is non-empty; the scan loop always sets best on its first pass)
}

// ---------------------------------------------------------------- Algorithm 2

/// The Alg. 2 priority of one cached expert under the current EAM:
/// `(ratio_in_cur_eam + ε) · (1 − layer/L)`. Shared by the scan and the
/// indexed policy so both compute bit-identical values.
#[inline]
fn activation_priority(use_ratio: bool, use_layer_decay: bool, e: ExpertKey, ctx: &CacheCtx) -> f64 {
    let ratio = if use_ratio {
        ctx.cur_eam.ratio(e.layer as usize, e.expert as usize) as f64
    } else {
        0.0
    };
    let decay = if use_layer_decay {
        1.0 - e.layer as f64 / ctx.n_layers as f64
    } else {
        1.0
    };
    (ratio + EPSILON) * decay
}

/// The paper's activation-aware replacement (Alg. 2): evict the cached
/// expert with minimal `(ratio_in_cur_eam + ε) · (1 − layer/L)`; ties break
/// toward the smaller [`ExpertKey`].
///
/// Two awareness terms (§6.1): experts frequently activated by the sequence
/// being processed are kept (temporal locality across iterations); experts
/// in early layers are kept (prefetching cannot cover them, §6.1 reason 2).
///
/// This is the O(capacity) reference scan; the serving stack uses
/// [`IndexedActivationPolicy`], which makes identical decisions.
#[derive(Debug, Default)]
pub struct ActivationPolicy {
    /// Optionally disable one of the two terms (§8.4 priority breakdown).
    pub use_ratio: bool,
    pub use_layer_decay: bool,
}

impl ActivationPolicy {
    pub fn new() -> ActivationPolicy {
        ActivationPolicy {
            use_ratio: true,
            use_layer_decay: true,
        }
    }

    /// Ablated variant for the §8.4 breakdown benches.
    pub fn with_terms(use_ratio: bool, use_layer_decay: bool) -> ActivationPolicy {
        ActivationPolicy {
            use_ratio,
            use_layer_decay,
        }
    }
}

impl Policy for ActivationPolicy {
    fn name(&self) -> &'static str {
        "activation"
    }

    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        ctx: &CacheCtx,
    ) -> ExpertKey {
        let (r, d) = (self.use_ratio, self.use_layer_decay);
        pick_min(entries, excluded, |e| (activation_priority(r, d, *e, ctx), *e))
    }
}

// ------------------------------------------------- Algorithm 2, O(log n) form

/// Sentinel priority for freshly inserted keys whose real priority has not
/// been computed yet (no [`CacheCtx`] is available inside `on_insert`); it
/// sorts first and is resolved lazily at the next victim pick.
const NEEDS_PRIORITY: f64 = f64::NEG_INFINITY;

#[derive(Debug, Clone, Copy)]
struct VictimEntry {
    p: f64,
    key: ExpertKey,
    /// Generation stamp; an entry is live iff it matches the key's current
    /// generation (lazy deletion).
    gen: u64,
}

impl PartialEq for VictimEntry {
    fn eq(&self, other: &Self) -> bool {
        self.p == other.p && self.key == other.key
    }
}
impl Eq for VictimEntry {}
impl PartialOrd for VictimEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for VictimEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // ascending (priority, key) — wrapped in `Reverse` for a min-heap;
        // priorities are finite or the NEG_INFINITY sentinel, never NaN
        self.p
            .partial_cmp(&other.p)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| self.key.cmp(&other.key))
    }
}

/// Heap-indexed Alg. 2 replacement: a lazy-deletion min-heap over
/// `(priority, key)` plus per-layer resident lists.
///
/// The priority of a cached expert depends on the current EAM only through
/// its own row (`ratio = count/row_sum`), so heap entries stay valid until
/// that row mutates. Each victim pick first re-keys the residents of rows
/// whose `(eam id, row version)` moved since the last pick, then pops the
/// minimum, skipping stale and excluded entries. Steady-state cost (rows
/// unchanged, e.g. an insert burst within one layer's execution):
/// O(log n) per eviction vs the scan's O(capacity).
#[derive(Debug, Default)]
pub struct IndexedActivationPolicy {
    pub use_ratio: bool,
    pub use_layer_decay: bool,
    heap: BinaryHeap<Reverse<VictimEntry>>,
    /// Resident keys → current generation.
    gen: DetMap<ExpertKey, u64>,
    next_gen: u64,
    /// Resident keys grouped by layer (for row-scoped invalidation).
    by_layer: Vec<Vec<ExpertKey>>,
    /// Key → position in its `by_layer` bucket (O(1) swap-remove).
    pos: DetMap<ExpertKey, usize>,
    /// Per-layer `(eam id, row version)` the live priorities were computed
    /// under; a mismatch means that row's ratios may have changed.
    snap: Vec<(u64, u64)>,
    /// Stale heap entries awaiting lazy deletion.
    stale: usize,
    /// Reusable stash for excluded-but-live entries popped mid-search.
    scratch: Vec<Reverse<VictimEntry>>,
}

impl IndexedActivationPolicy {
    pub fn new() -> IndexedActivationPolicy {
        IndexedActivationPolicy::with_terms(true, true)
    }

    /// Ablated variant (§8.4 breakdown), mirroring
    /// [`ActivationPolicy::with_terms`].
    pub fn with_terms(use_ratio: bool, use_layer_decay: bool) -> IndexedActivationPolicy {
        IndexedActivationPolicy {
            use_ratio,
            use_layer_decay,
            ..Default::default()
        }
    }

    /// Re-key the residents of every layer whose EAM row moved since the
    /// last victim pick. Touches only changed rows — the "invalidated only
    /// for rows whose ratios changed" contract.
    fn refresh_changed_rows(&mut self, ctx: &CacheCtx) {
        let eam = ctx.cur_eam;
        let id = eam.id();
        if self.snap.len() < self.by_layer.len() {
            // (0, _) can never match a live EAM id (ids start at 1)
            self.snap.resize(self.by_layer.len(), (0, 0));
        }
        for l in 0..self.by_layer.len() {
            let ver = if l < eam.layers() { eam.row_version(l) } else { 0 };
            if self.snap[l] == (id, ver) {
                continue;
            }
            self.snap[l] = (id, ver);
            for i in 0..self.by_layer[l].len() {
                let key = self.by_layer[l][i];
                let g = self.next_gen;
                self.next_gen += 1;
                if self.gen.insert(key, g).is_some() {
                    self.stale += 1;
                }
                let p = activation_priority(self.use_ratio, self.use_layer_decay, key, ctx);
                self.heap.push(Reverse(VictimEntry { p, key, gen: g }));
            }
        }
    }

    /// Drop stale entries in place once they dominate, keeping pops
    /// amortized O(log n) under heavy churn (no allocation: `retain`
    /// filters the heap's own buffer).
    fn maybe_compact(&mut self) {
        if self.stale > 64 && self.stale > 4 * self.gen.len() {
            let gen = &self.gen;
            self.heap
                .retain(|Reverse(v)| gen.get(&v.key).is_some_and(|&g| g == v.gen));
            self.stale = 0;
        }
    }
}

impl Policy for IndexedActivationPolicy {
    fn name(&self) -> &'static str {
        "activation"
    }

    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        ctx: &CacheCtx,
    ) -> ExpertKey {
        debug_assert!(!entries.is_empty());
        if self.gen.len() != entries.len() {
            // the caller is not driving the insert/evict callbacks (direct
            // Policy use on an ad-hoc slice) — fall back to the scan
            let (r, d) = (self.use_ratio, self.use_layer_decay);
            return pick_min(entries, excluded, |e| (activation_priority(r, d, *e, ctx), *e));
        }
        self.refresh_changed_rows(ctx);
        self.scratch.clear();
        let winner = loop {
            let Some(Reverse(top)) = self.heap.pop() else {
                break None;
            };
            match self.gen.get(&top.key) {
                Some(&g) if g == top.gen => {}
                _ => {
                    self.stale = self.stale.saturating_sub(1);
                    continue;
                }
            }
            if top.p == NEEDS_PRIORITY {
                // freshly inserted key: resolve its real priority now
                let p = activation_priority(self.use_ratio, self.use_layer_decay, top.key, ctx);
                self.heap.push(Reverse(VictimEntry { p, ..top }));
                continue;
            }
            if excluded.is_some_and(|x| x.contains(&top.key)) {
                self.scratch.push(Reverse(top));
                continue;
            }
            break Some(top);
        };
        // protected entries popped along the way stay resident — restore
        while let Some(e) = self.scratch.pop() {
            self.heap.push(e);
        }
        match winner {
            Some(top) => {
                debug_assert!(entries.contains(&top.key));
                // the key remains resident until the cache calls on_evict
                self.heap.push(Reverse(top));
                self.maybe_compact();
                top.key
            }
            None => {
                // every resident entry was excluded: exclusion is void
                let (r, d) = (self.use_ratio, self.use_layer_decay);
                pick_min(entries, None, |e| (activation_priority(r, d, *e, ctx), *e))
            }
        }
    }

    fn on_insert(&mut self, key: ExpertKey) {
        let l = key.layer as usize;
        if self.by_layer.len() <= l {
            self.by_layer.resize_with(l + 1, Vec::new);
        }
        let g = self.next_gen;
        self.next_gen += 1;
        if self.gen.insert(key, g).is_some() {
            self.stale += 1;
        } else {
            self.pos.insert(key, self.by_layer[l].len());
            self.by_layer[l].push(key);
        }
        self.heap.push(Reverse(VictimEntry {
            p: NEEDS_PRIORITY,
            key,
            gen: g,
        }));
    }

    fn on_evict(&mut self, key: ExpertKey) {
        if self.gen.remove(&key).is_some() {
            self.stale += 1;
        }
        if let Some(i) = self.pos.remove(&key) {
            let bucket = &mut self.by_layer[key.layer as usize];
            bucket.swap_remove(i);
            if i < bucket.len() {
                self.pos.insert(bucket[i], i);
            }
        }
    }
}

// ------------------------------------------------------------------------ LRU

/// Least-recently-used (CUDA unified memory / Sentinel / DeepUM).
#[derive(Debug, Default)]
pub struct LruPolicy {
    clock: u64,
    last: DetMap<ExpertKey, u64>,
}

impl LruPolicy {
    pub fn new() -> LruPolicy {
        LruPolicy::default()
    }
    fn tick(&mut self, key: ExpertKey) {
        self.clock += 1;
        self.last.insert(key, self.clock);
    }
}

impl Policy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }
    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        _ctx: &CacheCtx,
    ) -> ExpertKey {
        pick_min(entries, excluded, |e| self.last.get(e).copied().unwrap_or(0))
    }
    fn on_access(&mut self, key: ExpertKey) {
        self.tick(key);
    }
    fn on_insert(&mut self, key: ExpertKey) {
        self.tick(key);
    }
    fn on_evict(&mut self, key: ExpertKey) {
        self.last.remove(&key);
    }
}

// ------------------------------------------------------------------------ LFU

/// Least-frequently-used (BrainStorm). The frequency counter covers only
/// the cache residency period — it resets on eviction, which is exactly the
/// cross-iteration blindness §8.4 demonstrates.
#[derive(Debug, Default)]
pub struct LfuPolicy {
    counts: DetMap<ExpertKey, u64>,
}

impl LfuPolicy {
    pub fn new() -> LfuPolicy {
        LfuPolicy::default()
    }
}

impl Policy for LfuPolicy {
    fn name(&self) -> &'static str {
        "lfu"
    }
    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        _ctx: &CacheCtx,
    ) -> ExpertKey {
        pick_min(entries, excluded, |e| self.counts.get(e).copied().unwrap_or(0))
    }
    fn on_access(&mut self, key: ExpertKey) {
        *self.counts.entry(key).or_insert(0) += 1;
    }
    fn on_insert(&mut self, key: ExpertKey) {
        *self.counts.entry(key).or_insert(0) += 1;
    }
    fn on_evict(&mut self, key: ExpertKey) {
        // counter reset on eviction — reuse across residencies is lost
        self.counts.remove(&key);
    }
}

// -------------------------------------------------------------- Neighbor-aware

/// ZeRO-Infinity's neighbor-aware policy: experts adjacent by id in the same
/// layer are kept together (parameters are fetched in contiguous blocks).
/// Victim = entry with the fewest resident id-neighbors; LRU tie-break.
#[derive(Debug, Default)]
pub struct NeighborPolicy {
    lru: LruPolicy,
    /// Reusable residency set for the victim scan.
    resident: DetSet<ExpertKey>,
}

impl NeighborPolicy {
    pub fn new() -> NeighborPolicy {
        NeighborPolicy::default()
    }
}

impl Policy for NeighborPolicy {
    fn name(&self) -> &'static str {
        "neighbor"
    }
    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        _ctx: &CacheCtx,
    ) -> ExpertKey {
        self.resident.clear();
        self.resident.extend(entries.iter().copied());
        let resident = &self.resident;
        let last = &self.lru.last;
        pick_min(entries, excluded, |e| {
            let mut s = 0u32;
            if e.expert > 0
                && resident.contains(&ExpertKey {
                    layer: e.layer,
                    expert: e.expert - 1,
                })
            {
                s += 1;
            }
            if resident.contains(&ExpertKey {
                layer: e.layer,
                expert: e.expert + 1,
            }) {
                s += 1;
            }
            (s, last.get(e).copied().unwrap_or(0))
        })
    }
    fn on_access(&mut self, key: ExpertKey) {
        self.lru.on_access(key);
    }
    fn on_insert(&mut self, key: ExpertKey) {
        self.lru.on_insert(key);
    }
    fn on_evict(&mut self, key: ExpertKey) {
        self.lru.on_evict(key);
    }
}

// --------------------------------------------------------------------- Oracle

/// Belady's optimal replacement from a known future access sequence
/// (§8.4's ORACLE upper bound, "theoretical best through trace analysis").
///
/// Construct with the full access trace; an internal cursor advances on
/// every `on_access`/`on_miss`, so victims are chosen by true next-use.
#[derive(Debug)]
pub struct OraclePolicy {
    /// Per-expert sorted future access positions.
    future: DetMap<ExpertKey, Vec<u64>>,
    /// Per-expert cursor into `future`.
    cursor: DetMap<ExpertKey, usize>,
    now: u64,
}

impl OraclePolicy {
    pub fn from_trace(trace: &[ExpertKey]) -> OraclePolicy {
        let mut future: DetMap<ExpertKey, Vec<u64>> = DetMap::default();
        for (t, k) in trace.iter().enumerate() {
            future.entry(*k).or_default().push(t as u64);
        }
        OraclePolicy {
            future,
            cursor: DetMap::default(),
            now: 0,
        }
    }

    fn next_use(&self, key: &ExpertKey) -> u64 {
        match self.future.get(key) {
            None => u64::MAX,
            Some(times) => {
                let c = self.cursor.get(key).copied().unwrap_or(0);
                times[c..]
                    .iter()
                    .find(|&&t| t >= self.now)
                    .copied()
                    .unwrap_or(u64::MAX)
            }
        }
    }

    fn advance(&mut self, key: ExpertKey) {
        let c = self.cursor.entry(key).or_insert(0);
        if let Some(times) = self.future.get(&key) {
            while *c < times.len() && times[*c] <= self.now {
                *c += 1;
            }
        }
        self.now += 1;
    }
}

impl Policy for OraclePolicy {
    fn name(&self) -> &'static str {
        "oracle"
    }
    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        _ctx: &CacheCtx,
    ) -> ExpertKey {
        // Belady evicts the entry used farthest in the future = min of the
        // reversed next-use time
        pick_min(entries, excluded, |e| Reverse(self.next_use(e)))
    }
    fn on_access(&mut self, key: ExpertKey) {
        self.advance(key);
    }
    fn on_miss(&mut self, key: ExpertKey) {
        self.advance(key);
    }
}

// ------------------------------------------------------------- shared machinery

/// Lazy-deletion min-heap over `(priority, key)` shared by the O(log n)
/// zoo policies (LFU-DA, SLRU, GDSF). Same idiom as the heap inside
/// [`IndexedActivationPolicy`] — generation stamps for O(1) invalidation,
/// `NEEDS_PRIORITY` sentinels resolved at victim time, an exclusion stash,
/// periodic in-place compaction — without that policy's EAM-row tracking.
#[derive(Debug, Default)]
struct LazyMinHeap {
    heap: BinaryHeap<Reverse<VictimEntry>>,
    /// Tracked keys → current generation (an entry is live iff it matches).
    gen: DetMap<ExpertKey, u64>,
    next_gen: u64,
    /// Stale heap entries awaiting lazy deletion.
    stale: usize,
    /// Reusable stash for excluded-but-live entries popped mid-search.
    scratch: Vec<Reverse<VictimEntry>>,
}

impl LazyMinHeap {
    /// Number of tracked (live) keys.
    fn len(&self) -> usize {
        self.gen.len()
    }

    /// Insert or re-key `key` at priority `p` (supersedes any live entry).
    fn update(&mut self, key: ExpertKey, p: f64) {
        let g = self.next_gen;
        self.next_gen += 1;
        if self.gen.insert(key, g).is_some() {
            self.stale += 1;
        }
        self.heap.push(Reverse(VictimEntry { p, key, gen: g }));
    }

    /// Stop tracking `key` (its heap entries become stale).
    fn remove(&mut self, key: ExpertKey) {
        if self.gen.remove(&key).is_some() {
            self.stale += 1;
        }
    }

    /// Sorted tracked keys (deterministic re-key sweeps).
    fn sorted_keys(&self) -> Vec<ExpertKey> {
        let mut keys: Vec<ExpertKey> = self.gen.keys().copied().collect();
        keys.sort_unstable();
        keys
    }

    /// Drop stale entries in place once they dominate (no allocation:
    /// `retain` filters the heap's own buffer).
    fn maybe_compact(&mut self) {
        if self.stale > 64 && self.stale > 4 * self.gen.len() {
            let gen = &self.gen;
            self.heap
                .retain(|Reverse(v)| gen.get(&v.key).is_some_and(|&g| g == v.gen));
            self.stale = 0;
        }
    }

    /// Pop the live minimum: stale entries are discarded, `NEEDS_PRIORITY`
    /// sentinels are resolved through `resolve` and re-pushed (same
    /// generation), excluded live entries are stashed and restored. The
    /// winner is pushed back — it stays resident until the cache calls
    /// `on_evict`. `None` iff every live entry is excluded.
    fn min_entry(
        &mut self,
        excluded: Option<&DetSet<ExpertKey>>,
        mut resolve: impl FnMut(ExpertKey) -> f64,
    ) -> Option<VictimEntry> {
        self.scratch.clear();
        let winner = loop {
            let Some(Reverse(top)) = self.heap.pop() else {
                break None;
            };
            match self.gen.get(&top.key) {
                Some(&g) if g == top.gen => {}
                _ => {
                    self.stale = self.stale.saturating_sub(1);
                    continue;
                }
            }
            if top.p == NEEDS_PRIORITY {
                let p = resolve(top.key);
                self.heap.push(Reverse(VictimEntry { p, ..top }));
                continue;
            }
            if excluded.is_some_and(|x| x.contains(&top.key)) {
                self.scratch.push(Reverse(top));
                continue;
            }
            break Some(top);
        };
        // excluded entries popped along the way stay resident — restore
        while let Some(e) = self.scratch.pop() {
            self.heap.push(e);
        }
        winner.map(|top| {
            self.heap.push(Reverse(top));
            self.maybe_compact();
            top
        })
    }
}

// --------------------------------------------------------------------- LFU-DA

/// LFU with dynamic aging (squid-style): priority `K = freq + age`, where
/// `age` jumps to the evicted entry's K. This fixes the counter-reset
/// weakness §8.4 demonstrates for plain LFU — a re-inserted entry starts at
/// `K = 1 + age`, immediately competitive with long-resident entries, so a
/// stale-hot entry cannot pin its slot forever.
///
/// O(log n) victim picks via [`LazyMinHeap`]; decisions are pinned against
/// a naive reference scan by a differential proptest.
#[derive(Debug, Default)]
pub struct LfuDaPolicy {
    age: u64,
    freq: DetMap<ExpertKey, u64>,
    /// Cached `K = freq + age` as of the key's last touch (the heap
    /// priority, and the value `age` jumps to on eviction).
    kval: DetMap<ExpertKey, u64>,
    heap: LazyMinHeap,
    /// Victim chosen by the last `victim()` call and its K; consumed by
    /// `on_evict` to advance the age (a bare `remove()` is a deletion, not
    /// an eviction decision, and must not age the cache).
    last_victim: Option<(ExpertKey, u64)>,
}

impl LfuDaPolicy {
    pub fn new() -> LfuDaPolicy {
        LfuDaPolicy::default()
    }

    fn touch(&mut self, key: ExpertKey) {
        let f = self.freq.entry(key).or_insert(0);
        *f += 1;
        let k = *f + self.age;
        self.kval.insert(key, k);
        // counts stay far below 2^53: u64 -> f64 is exact here
        self.heap.update(key, k as f64);
    }
}

impl Policy for LfuDaPolicy {
    fn name(&self) -> &'static str {
        "lfuda"
    }
    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        _ctx: &CacheCtx,
    ) -> ExpertKey {
        let key = if self.heap.len() == entries.len() {
            // no sentinels are ever pushed (K is computed at touch time),
            // so the resolve hook is unreachable
            match self.heap.min_entry(excluded, |_| 0.0) {
                Some(top) => top.key,
                // every resident entry excluded: exclusion is void
                None => pick_min(entries, None, |e| {
                    (self.kval.get(e).copied().unwrap_or(0), *e)
                }),
            }
        } else {
            // ad-hoc slice use (caller not driving callbacks) — reference scan
            pick_min(entries, excluded, |e| {
                (self.kval.get(e).copied().unwrap_or(0), *e)
            })
        };
        self.last_victim = Some((key, self.kval.get(&key).copied().unwrap_or(0)));
        key
    }
    fn on_access(&mut self, key: ExpertKey) {
        self.touch(key);
    }
    fn on_insert(&mut self, key: ExpertKey) {
        self.touch(key);
    }
    fn on_evict(&mut self, key: ExpertKey) {
        if let Some((vk, k)) = self.last_victim {
            if vk == key {
                // dynamic aging: the cache "ages" to the level the victim
                // had reached, so future inserts start competitive
                self.age = k;
                self.last_victim = None;
            }
        }
        self.freq.remove(&key);
        self.kval.remove(&key);
        self.heap.remove(key);
    }
}

// ----------------------------------------------------------------------- SLRU

/// Probation/protected scores live in disjoint bands: segment 1 entries
/// always outrank (survive) segment 0, and within a band the unique access
/// tick orders entries LRU-first. Ticks stay far below 2^40, so the packed
/// f64 is exact.
const SLRU_SEG_BASE: f64 = (1u64 << 40) as f64;

#[inline]
fn slru_score(seg: u8, tick: u64) -> f64 {
    seg as f64 * SLRU_SEG_BASE + tick as f64
}

/// Segmented LRU: new entries enter a *probation* segment; a re-reference
/// promotes to a *protected* segment capped at 4/5 of capacity (overflow
/// demotes the protected LRU back to probation MRU). Victims drain
/// probation LRU-first, so a one-pass scan cannot flush entries that were
/// ever re-referenced.
///
/// Not to be confused with [`crate::cache::ExpertCache`]'s eviction
/// *protection* (§6.2 prefetch pinning) — that is an exclusion filter
/// applied on top of any policy, while SLRU's protected *segment* is this
/// policy's own notion of re-referenced entries.
///
/// O(log n) via two [`LazyMinHeap`]s: the victim heap (packed
/// `segment · 2^40 + tick` scores) and a protected-segment heap keyed by
/// tick for O(log n) demotion.
#[derive(Debug)]
pub struct SlruPolicy {
    clock: u64,
    /// 0 = probation, 1 = protected segment.
    seg: DetMap<ExpertKey, u8>,
    tick: DetMap<ExpertKey, u64>,
    protected_count: usize,
    protected_budget: usize,
    heap: LazyMinHeap,
    /// Protected-segment entries by tick (demotion picks its minimum).
    prot_heap: LazyMinHeap,
}

impl SlruPolicy {
    /// `capacity` is the owning cache tier's slot count; the protected
    /// segment is budgeted at 4/5 of it (at least one slot).
    pub fn new(capacity: usize) -> SlruPolicy {
        SlruPolicy {
            clock: 0,
            seg: DetMap::default(),
            tick: DetMap::default(),
            protected_count: 0,
            protected_budget: (capacity * 4 / 5).clamp(1, capacity.max(1)),
            heap: LazyMinHeap::default(),
            prot_heap: LazyMinHeap::default(),
        }
    }

    fn place(&mut self, key: ExpertKey, seg: u8) {
        self.clock += 1;
        self.seg.insert(key, seg);
        self.tick.insert(key, self.clock);
        self.heap.update(key, slru_score(seg, self.clock));
        if seg == 1 {
            self.prot_heap.update(key, self.clock as f64);
        }
    }

    /// Demote the protected segment's LRU entry back to probation MRU.
    fn demote_lru(&mut self) {
        // the protected heap carries no sentinels and no exclusions
        if let Some(top) = self.prot_heap.min_entry(None, |_| 0.0) {
            self.prot_heap.remove(top.key);
            self.protected_count -= 1;
            self.place(top.key, 0);
        }
    }
}

impl Policy for SlruPolicy {
    fn name(&self) -> &'static str {
        "slru"
    }
    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        _ctx: &CacheCtx,
    ) -> ExpertKey {
        let seg = &self.seg;
        let tick = &self.tick;
        let scan = |e: &ExpertKey| {
            (
                seg.get(e).copied().unwrap_or(0),
                tick.get(e).copied().unwrap_or(0),
                *e,
            )
        };
        if self.heap.len() == entries.len() {
            match self.heap.min_entry(excluded, |_| 0.0) {
                Some(top) => top.key,
                None => pick_min(entries, None, scan),
            }
        } else {
            pick_min(entries, excluded, scan)
        }
    }
    fn on_access(&mut self, key: ExpertKey) {
        match self.seg.get(&key).copied() {
            // already protected: refresh recency within the segment
            Some(1) => self.place(key, 1),
            // probation hit: promote, demoting on segment overflow
            Some(0) => {
                self.protected_count += 1;
                self.place(key, 1);
                if self.protected_count > self.protected_budget {
                    // the just-promoted key holds the newest tick, so the
                    // demotion can never pick it back
                    self.demote_lru();
                }
            }
            // untracked (ad-hoc slice use without on_insert)
            _ => {}
        }
    }
    fn on_insert(&mut self, key: ExpertKey) {
        self.place(key, 0);
    }
    fn on_evict(&mut self, key: ExpertKey) {
        self.tick.remove(&key);
        self.heap.remove(key);
        if self.seg.remove(&key) == Some(1) {
            self.protected_count -= 1;
            self.prot_heap.remove(key);
        }
    }
}

// ----------------------------------------------------------------------- GDSF

/// GreedyDual-Size-Frequency: priority `H = age_at_last_touch +
/// freq · fetch_cost`, victim = min H, and the global age jumps to the
/// victim's H on eviction. With uniformly sized experts the size term is a
/// constant, leaving the *fetch cost* — [`CacheCtx::fetch_cost`], the
/// per-tier cost of re-fetching from the backing store — to weight
/// frequency against recency-of-touch: an expensive backing link (SSD)
/// makes GDSF hold frequent entries longer; a cheap one lets age win.
///
/// The fetch cost is only known at victim time (it rides on the context,
/// not the callbacks), so touches push `NEEDS_PRIORITY` sentinels that the
/// victim pick resolves under the current cost; if the cost itself changed
/// since the last pick, every tracked key is re-keyed first so the heap
/// always agrees with a reference scan under the current cost. (In serving
/// use the cost is a per-tier constant, so the sweep never triggers.)
///
/// `on_evict` after a `victim()` pick advances the age; a bare `remove()`
/// (upper tier stealing the slot) is a deletion and leaves the age alone.
#[derive(Debug, Default)]
pub struct GdsfPolicy {
    age: f64,
    freq: DetMap<ExpertKey, u64>,
    /// Global age captured at the key's last touch.
    snap: DetMap<ExpertKey, f64>,
    heap: LazyMinHeap,
    /// `fetch_cost` the live heap priorities were resolved under.
    last_cost: f64,
    /// Victim of the last `victim()` call and its H (consumed by `on_evict`).
    last_victim: Option<(ExpertKey, f64)>,
}

impl GdsfPolicy {
    pub fn new() -> GdsfPolicy {
        GdsfPolicy {
            // matches CacheCtx::new's default; any value works (priorities
            // are sentinels until first resolved)
            last_cost: 1.0,
            ..Default::default()
        }
    }

    fn touch(&mut self, key: ExpertKey) {
        *self.freq.entry(key).or_insert(0) += 1;
        self.snap.insert(key, self.age);
        self.heap.update(key, NEEDS_PRIORITY);
    }
}

impl Policy for GdsfPolicy {
    fn name(&self) -> &'static str {
        "gdsf"
    }
    fn victim(
        &mut self,
        entries: &[ExpertKey],
        excluded: Option<&DetSet<ExpertKey>>,
        ctx: &CacheCtx,
    ) -> ExpertKey {
        let fc = ctx.fetch_cost;
        if self.heap.len() != entries.len() {
            // ad-hoc slice use — reference scan
            let (snap, freq, age) = (&self.snap, &self.freq, self.age);
            let h = |e: &ExpertKey| {
                snap.get(e).copied().unwrap_or(age)
                    + freq.get(e).copied().unwrap_or(0) as f64 * fc
            };
            let key = pick_min(entries, excluded, |e| (h(e), *e));
            self.last_victim = Some((key, h(&key)));
            return key;
        }
        if fc != self.last_cost {
            // the cost changed under us: resolved priorities are stale for
            // every key, not just touched ones — re-key the whole heap
            // (sorted sweep for determinism)
            for key in self.heap.sorted_keys() {
                self.heap.update(key, NEEDS_PRIORITY);
            }
            self.last_cost = fc;
        }
        let (snap, freq, age) = (&self.snap, &self.freq, self.age);
        let resolve = |k: ExpertKey| {
            snap.get(&k).copied().unwrap_or(age) + freq.get(&k).copied().unwrap_or(0) as f64 * fc
        };
        match self.heap.min_entry(excluded, resolve) {
            Some(top) => {
                self.last_victim = Some((top.key, top.p));
                top.key
            }
            None => {
                // every resident entry excluded: exclusion is void
                let (snap, freq, age) = (&self.snap, &self.freq, self.age);
                let h = |e: &ExpertKey| {
                    snap.get(e).copied().unwrap_or(age)
                        + freq.get(e).copied().unwrap_or(0) as f64 * fc
                };
                let key = pick_min(entries, None, |e| (h(e), *e));
                self.last_victim = Some((key, h(&key)));
                key
            }
        }
    }
    fn on_access(&mut self, key: ExpertKey) {
        self.touch(key);
    }
    fn on_insert(&mut self, key: ExpertKey) {
        self.touch(key);
    }
    fn on_evict(&mut self, key: ExpertKey) {
        if let Some((vk, h)) = self.last_victim {
            if vk == key {
                // greedy-dual inflation: the floor rises to the evicted H
                self.age = h;
                self.last_victim = None;
            }
        }
        self.freq.remove(&key);
        self.snap.remove(&key);
        self.heap.remove(key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheCtx, CacheTier, ExpertCache};
    use crate::trace::Eam;

    fn k(l: usize, e: usize) -> ExpertKey {
        ExpertKey::new(l, e)
    }

    #[test]
    fn activation_policy_evicts_low_ratio_late_layer() {
        let mut eam = Eam::new(4, 4);
        eam.record(0, 0, 10); // L0E0 hot
        eam.record(3, 1, 1); // L3E1 cold-ish, late layer
        eam.record(1, 2, 5); // L1E2 warm
        let ctx = CacheCtx::new(&eam, 4);
        let mut p = ActivationPolicy::new();
        let entries = vec![k(0, 0), k(3, 1), k(1, 2)];
        // L3E1: ratio 1.0 but decay 0.25; L0E0: ratio 1.0 decay 1.0;
        // L1E2: ratio 1.0 decay 0.75 — victim is the late-layer one.
        assert_eq!(p.victim(&entries, None, &ctx), k(3, 1));
    }

    #[test]
    fn activation_policy_prefers_early_layers_at_equal_ratio() {
        let eam = Eam::new(4, 4); // all ratios zero
        let ctx = CacheCtx::new(&eam, 4);
        let mut p = ActivationPolicy::new();
        let entries = vec![k(0, 0), k(2, 0), k(3, 0)];
        assert_eq!(p.victim(&entries, None, &ctx), k(3, 0), "latest layer evicted first");
    }

    #[test]
    fn activation_ablations_change_choice() {
        let mut eam = Eam::new(4, 4);
        eam.record(3, 0, 10); // late layer, hot (ratio 1.0 in its row)
        eam.record(0, 1, 1); // early layer, cold (ratio 0.1 in its row)
        eam.record(0, 3, 9); // make layer-0 row sum 10 so E1's ratio is low
        let ctx = CacheCtx::new(&eam, 4);
        let entries = vec![k(3, 0), k(0, 1)];
        // ratio-only: evicts the cold one
        let mut ratio_only = ActivationPolicy::with_terms(true, false);
        assert_eq!(ratio_only.victim(&entries, None, &ctx), k(0, 1));
        // decay-only: evicts the late one
        let mut decay_only = ActivationPolicy::with_terms(false, true);
        assert_eq!(decay_only.victim(&entries, None, &ctx), k(3, 0));
    }

    #[test]
    fn activation_victim_respects_exclusion() {
        let eam = Eam::new(4, 4);
        let ctx = CacheCtx::new(&eam, 4);
        let mut p = ActivationPolicy::new();
        let entries = vec![k(0, 0), k(3, 0)];
        let protected: DetSet<ExpertKey> = [k(3, 0)].into_iter().collect();
        assert_eq!(p.victim(&entries, Some(&protected), &ctx), k(0, 0));
        // all-excluded: exclusion is void
        let all: DetSet<ExpertKey> = entries.iter().copied().collect();
        assert_eq!(p.victim(&entries, Some(&all), &ctx), k(3, 0));
    }

    /// Drive scan and indexed policies through identical callback streams
    /// and assert identical victims at every pick.
    #[test]
    fn indexed_matches_scan_under_mutation_and_protection() {
        let mut eam = Eam::new(4, 8);
        let mut scan = ActivationPolicy::new();
        let mut heap = IndexedActivationPolicy::new();
        let entries: Vec<ExpertKey> = (0..4).flat_map(|l| (0..3).map(move |e| k(l, e))).collect();
        for &e in &entries {
            scan.on_insert(e);
            heap.on_insert(e);
        }
        let mut protected: DetSet<ExpertKey> = DetSet::default();
        for step in 0..40u32 {
            // mutate a row between picks
            eam.record((step % 4) as usize, ((step * 3) % 8) as usize, 1 + step % 5);
            if step % 7 == 0 {
                protected.insert(entries[(step % entries.len() as u32) as usize]);
            }
            if step % 11 == 0 {
                protected.clear();
            }
            let ctx = CacheCtx::new(&eam, 4);
            let excl = if protected.is_empty() { None } else { Some(&protected) };
            let a = scan.victim(&entries, excl, &ctx);
            let b = heap.victim(&entries, excl, &ctx);
            assert_eq!(a, b, "diverged at step {step}");
        }
    }

    #[test]
    fn indexed_tracks_evictions_and_inserts() {
        let mut eam = Eam::new(2, 8);
        eam.record(0, 0, 10);
        let ctx = CacheCtx::new(&eam, 2);
        let mut c = ExpertCache::new(2, Box::new(IndexedActivationPolicy::new()));
        c.insert(k(0, 0), &ctx); // hot (ratio 1.0)
        c.insert(k(0, 1), &ctx); // cold
        let ev = c.insert(k(1, 0), &ctx).unwrap();
        assert_eq!(ev, k(0, 1), "cold expert evicted first");
        assert!(c.contains(k(0, 0)) && c.contains(k(1, 0)));
        // evicted key re-enters cleanly
        let ev2 = c.insert(k(0, 1), &ctx).unwrap();
        assert_eq!(ev2, k(1, 0), "late-layer zero-ratio expert goes next");
    }

    #[test]
    fn lru_evicts_least_recent() {
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx::new(&eam, 1);
        let mut c = ExpertCache::new(2, Box::new(LruPolicy::new()));
        c.insert(k(0, 0), &ctx);
        c.insert(k(0, 1), &ctx);
        c.access(k(0, 0)); // 0 is now MRU
        let ev = c.insert(k(0, 2), &ctx).unwrap();
        assert_eq!(ev, k(0, 1));
    }

    #[test]
    fn lfu_evicts_least_frequent_and_resets() {
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx::new(&eam, 1);
        let mut c = ExpertCache::new(2, Box::new(LfuPolicy::new()));
        c.insert(k(0, 0), &ctx);
        for _ in 0..5 {
            c.access(k(0, 0));
        }
        c.insert(k(0, 1), &ctx);
        let ev = c.insert(k(0, 2), &ctx).unwrap();
        assert_eq!(ev, k(0, 1), "lower-count entry evicted");
        // k(0,1)'s counter was reset on eviction; re-inserting it now makes
        // it count 1 vs k(0,2)'s 1 — the freshly reset entry loses the
        // cross-residency history LFU would have needed (§8.4's point).
        let ev2 = c.insert(k(0, 1), &ctx).unwrap();
        assert_eq!(ev2, k(0, 2), "victim is the other count-1 entry");
        assert!(c.contains(k(0, 0)), "hot expert survives");
    }

    #[test]
    fn neighbor_keeps_contiguous_runs() {
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx::new(&eam, 1);
        let mut p = NeighborPolicy::new();
        // 0,1,2 contiguous; 5 isolated
        let entries = vec![k(0, 0), k(0, 1), k(0, 2), k(0, 5)];
        assert_eq!(p.victim(&entries, None, &ctx), k(0, 5), "isolated expert evicted");
    }

    #[test]
    fn oracle_is_belady() {
        // trace: A B C A B  with capacity 2: at inserting C, cached {A,B};
        // A next at 3, B at 4 -> evict B.
        let trace = vec![k(0, 0), k(0, 1), k(0, 2), k(0, 0), k(0, 1)];
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx::new(&eam, 1);
        let mut c = ExpertCache::new(2, Box::new(OraclePolicy::from_trace(&trace)));
        // replay
        c.access(trace[0]);
        c.insert(trace[0], &ctx);
        c.access(trace[1]);
        c.insert(trace[1], &ctx);
        c.access(trace[2]);
        let ev = c.insert(trace[2], &ctx).unwrap();
        assert_eq!(ev, k(0, 1), "B (next use later) is the Belady victim");
        assert!(c.access(trace[3]), "A must still be cached");
    }

    #[test]
    fn lfuda_aging_lets_new_entries_displace_stale_hot_ones() {
        // Plain LFU would pin a once-hot entry forever; LFU-DA's age term
        // (K = freq + age, age := K(victim) on evict) lets a stream of
        // newcomers catch up with and displace it.
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx::new(&eam, 1);
        let mut c = ExpertCache::new(2, Box::new(LfuDaPolicy::new()));
        let hot = k(0, 0);
        c.insert(hot, &ctx);
        for _ in 0..4 {
            c.access(hot); // freq 5 -> K = 5 at age 0
        }
        // each one-shot newcomer evicts its predecessor (K = 1 + age) and
        // raises the age; by the 6th the age has climbed to 4, the newcomer
        // ties the hot entry at K = 5, and the key tie-break evicts hot.
        let mut hot_evicted_at = None;
        for e in 1..8 {
            if let Some(ev) = c.insert(k(0, e), &ctx) {
                if ev == hot {
                    hot_evicted_at = Some(e);
                    break;
                }
            }
        }
        assert_eq!(hot_evicted_at, Some(6), "aging displaced the stale hot entry");
    }

    #[test]
    fn slru_protects_reaccessed_entries_from_scan_flush() {
        // One-touch entries stay in probation and absorb a scan; the
        // re-accessed entry sits in the protected segment and survives.
        let eam = Eam::new(1, 16);
        let ctx = CacheCtx::new(&eam, 1);
        let mut c = ExpertCache::new(4, Box::new(SlruPolicy::new(4)));
        let a = k(0, 0);
        let b = k(0, 1);
        c.insert(a, &ctx);
        c.insert(b, &ctx);
        assert!(c.access(a), "a must hit"); // promotes a to protected
        for e in 2..8 {
            if !c.access(k(0, e)) {
                c.insert(k(0, e), &ctx);
            }
        }
        assert!(c.contains(a), "protected entry survives the scan");
        assert!(!c.contains(b), "one-touch probation entry is flushed");
    }

    #[test]
    fn slru_demotes_protected_lru_when_segment_overflows() {
        // capacity 5 -> protected budget 4; the 5th promotion demotes the
        // least-recently-promoted protected entry back to probation, where
        // the next insert evicts it.
        let eam = Eam::new(1, 16);
        let ctx = CacheCtx::new(&eam, 1);
        let mut c = ExpertCache::new(5, Box::new(SlruPolicy::new(5)));
        for e in 0..5 {
            c.insert(k(0, e), &ctx);
        }
        for e in 0..5 {
            assert!(c.access(k(0, e)), "warm-up access must hit");
        }
        // k(0,0) was promoted first, so the budget overflow demoted it; it
        // is now the only probation entry and the unique eviction candidate.
        let ev = c.insert(k(0, 5), &ctx).unwrap();
        assert_eq!(ev, k(0, 0), "demoted protected-LRU entry is evicted");
    }

    #[test]
    fn gdsf_fetch_cost_flips_frequency_vs_recency() {
        // GDSF scores H = age-at-touch + freq * fetch_cost: a cheap tier
        // (low cost) discounts frequency and evicts the hot-but-stale entry;
        // an expensive tier keeps it. Changing the cost between picks also
        // exercises the heap's re-key sweep.
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx::new(&eam, 1);
        let (a, b, d) = (k(0, 0), k(0, 1), k(0, 3));
        let mut p = GdsfPolicy::new();
        p.on_insert(a);
        p.on_access(a);
        p.on_access(a); // freq 3, snapped age 0
        p.on_insert(d); // freq 1, snapped age 0
        // cost 2.0: H_a = 0 + 3*2 = 6, H_d = 0 + 1*2 = 2 -> evict d
        let v = p.victim(&[a, d], None, &ctx.for_tier(CacheTier::Gpu, 2.0));
        assert_eq!(v, d);
        p.on_evict(d); // age := H(d) = 2
        p.on_insert(b); // freq 1, snapped age 2
        // cost 0.5: H_a = 0 + 1.5 = 1.5, H_b = 2 + 0.5 = 2.5 -> evict a
        let v = p.victim(&[a, b], None, &ctx.for_tier(CacheTier::Gpu, 0.5));
        assert_eq!(v, a, "cheap refills discount frequency");
        // cost 3.0: H_a = 0 + 9 = 9, H_b = 2 + 3 = 5 -> evict b
        let v = p.victim(&[a, b], None, &ctx.for_tier(CacheTier::Gpu, 3.0));
        assert_eq!(v, b, "expensive refills protect the frequent entry");
    }

    #[test]
    fn oracle_beats_lru_on_looping_trace() {
        // classic LRU-adversarial loop: 0 1 2 0 1 2 ... with capacity 2.
        let mut trace = Vec::new();
        for _ in 0..30 {
            for e in 0..3 {
                trace.push(k(0, e));
            }
        }
        let eam = Eam::new(1, 8);
        let ctx = CacheCtx::new(&eam, 1);
        let run = |policy: Box<dyn Policy>| -> f64 {
            let mut c = ExpertCache::new(2, policy);
            for &key in &trace {
                if !c.access(key) {
                    c.insert(key, &ctx);
                }
            }
            c.hit_ratio()
        };
        let lru = run(Box::new(LruPolicy::new()));
        let oracle = run(Box::new(OraclePolicy::from_trace(&trace)));
        assert!(oracle > lru, "oracle {oracle} must beat lru {lru}");
        assert!(lru < 0.05, "LRU thrashes the loop");
        assert!(oracle > 0.4, "oracle keeps one hot line");
    }
}
