//! Activation-aware expert prefetching (paper §5).
//!
//! * [`PrefetchQueue`] — the priority queue an I/O thread drains one expert
//!   at a time per PCIe link; supports re-enqueue-with-updated-priority and
//!   an in-flight dedup set (§5.3).
//! * [`Predictor`] — computes prefetch priorities from the current EAM and
//!   the EAMC (Alg. 1 `PREFETCH`, §5.2), plus the baseline strategies the
//!   paper compares against (§8.3): `TopK` (ZeRO-Infinity), `TracedTopK`
//!   (BrainStorm) and `None` (pure on-demand).

mod predictor;
mod queue;

pub use predictor::{Prediction, Predictor, PredictorKind, EPSILON};
pub use queue::{PrefetchQueue, MAX_PRIORITY};
