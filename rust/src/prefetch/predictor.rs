//! Prefetch priority computation (paper §5.2, Alg. 1 `PREFETCH`), plus the
//! baseline strategies evaluated in §8.3.

use crate::model::ExpertKey;
use crate::trace::{Eam, Eamc, EamcMatcher};

/// Small constant distinguishing zero-activation-ratio experts by layer
/// decay (Alg. 1 step 26).
pub const EPSILON: f64 = 1e-4;

/// Which prefetching strategy to run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PredictorKind {
    /// The paper's activation-aware predictor. `refine = false` disables
    /// continuous refinement (§8.3 ablation): a single one-shot prediction
    /// is made after the first MoE layer's router output.
    ActivationAware { refine: bool },
    /// ZeRO-Infinity: prefetch the top-K experts **by expert id** in the
    /// next layer (no activation awareness).
    TopK { k: usize },
    /// BrainStorm: aggregate usage frequency across all served sequences,
    /// prefetch the top-K most popular experts of the next layer.
    TracedTopK { k: usize },
    /// Pure on-demand fetching (PyTorch-UM / CUDA unified memory).
    NoPrefetch,
}

/// One prediction: experts to prefetch with their priorities.
#[derive(Debug, Clone, Default)]
pub struct Prediction {
    pub items: Vec<(ExpertKey, f64)>,
}

impl Prediction {
    /// The predicted expert set for one specific layer, best-first — used by
    /// the Fig. 9 accuracy benchmarks.
    pub fn for_layer(&self, layer: usize) -> Vec<ExpertKey> {
        let mut v: Vec<(ExpertKey, f64)> = self
            .items
            .iter()
            .filter(|(k, _)| k.layer as usize == layer)
            .cloned()
            .collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v.into_iter().map(|(k, _)| k).collect()
    }
}

/// Computes prefetch priorities. Owns the aggregated-frequency state needed
/// by the `TracedTopK` baseline (which is exactly the aggregation the paper
/// argues *loses* sequence-level information).
pub struct Predictor {
    kind: PredictorKind,
    layers: usize,
    experts: usize,
    /// Aggregated activation counts across all sequences (TracedTopK only).
    agg: Vec<u64>,
    /// Minimum predicted activation ratio an expert needs before the
    /// activation-aware strategy spends PCIe bandwidth on it. Algorithm 1
    /// scores every expert; transferring the long tail of near-zero-ratio
    /// entries is pure waste (they evict cached experts and block on-demand
    /// fetches behind in-flight junk). 0.0 = emit everything (accuracy
    /// probes use this).
    min_ratio: f64,
}

impl Predictor {
    pub fn new(kind: PredictorKind, layers: usize, experts: usize) -> Predictor {
        Predictor {
            kind,
            layers,
            experts,
            agg: vec![0; layers * experts],
            min_ratio: 0.0,
        }
    }

    /// Set the transfer-worthiness threshold (see `min_ratio`).
    pub fn with_min_ratio(mut self, r: f64) -> Predictor {
        self.min_ratio = r;
        self
    }

    pub fn kind(&self) -> PredictorKind {
        self.kind
    }

    /// Record an observed routing event (all strategies may call this; only
    /// `TracedTopK` consumes it).
    pub fn observe_route(&mut self, layer: usize, expert: usize, tokens: u32) {
        self.agg[layer * self.experts + expert] += tokens as u64;
    }

    /// Whether a prediction should be (re)computed after executing the
    /// router of `cur_layer` on generation iteration `iter`.
    pub fn should_predict(&self, cur_layer: usize, iter: usize) -> bool {
        match self.kind {
            PredictorKind::ActivationAware { refine } => refine || (iter == 0 && cur_layer == 0),
            PredictorKind::NoPrefetch => false,
            _ => true,
        }
    }

    /// Compute priorities for experts in layers after `cur_layer`
    /// (Alg. 1 `PREFETCH(m, cur_eam, eamc, cur_l, q)`).
    ///
    /// Results are appended to `out` (cleared first) to keep the serving hot
    /// path allocation-free after warm-up.
    ///
    /// `matcher` is the sequence's incremental matcher handle: when given
    /// (the serving hot path), the nearest-EAM lookup is an O(entries)
    /// argmax over maintained accumulators instead of [`Eamc::nearest`]'s
    /// allocating full scan. The caller is responsible for keeping the
    /// handle synced (attached to `eamc`'s current build and fed every
    /// routing event of `cur_eam`).
    pub fn predict(
        &self,
        cur_eam: &Eam,
        eamc: &Eamc,
        matcher: Option<&EamcMatcher>,
        cur_layer: usize,
        out: &mut Vec<(ExpertKey, f64)>,
    ) {
        out.clear();
        let l_total = self.layers;
        match self.kind {
            PredictorKind::NoPrefetch => {}
            PredictorKind::TopK { k } => {
                // next layer only, by expert id (no activation awareness)
                let fl = cur_layer + 1;
                if fl < l_total {
                    for e in 0..k.min(self.experts) {
                        out.push((ExpertKey::new(fl, e), 1.0 - e as f64 / (k as f64 + 1.0)));
                    }
                }
            }
            PredictorKind::TracedTopK { k } => {
                let fl = cur_layer + 1;
                if fl < l_total {
                    let row = &self.agg[fl * self.experts..(fl + 1) * self.experts];
                    let mut idx: Vec<usize> = (0..self.experts).collect();
                    idx.sort_by(|&a, &b| row[b].cmp(&row[a]).then(a.cmp(&b)));
                    let total: u64 = row.iter().sum::<u64>().max(1);
                    for (rank, &e) in idx.iter().take(k.min(self.experts)).enumerate() {
                        let p = row[e] as f64 / total as f64 + EPSILON * (k - rank) as f64;
                        out.push((ExpertKey::new(fl, e), p));
                    }
                }
            }
            PredictorKind::ActivationAware { .. } => {
                // Alg. 1 steps 16-21: most-similar stored EAM — via the
                // incremental matcher when a handle is threaded through,
                // via the full scan otherwise (offline probes, baselines).
                let best = match matcher {
                    Some(m) => {
                        debug_assert!(
                            m.is_synced(eamc.index()),
                            "matcher handle out of sync with EAMC build"
                        );
                        m.nearest().map(|(i, _)| eamc.entry(i))
                    }
                    None => eamc.nearest(cur_eam).map(|(e, _)| e),
                };
                let Some(p_eam) = best else {
                    return;
                };
                for fl in (cur_layer + 1)..l_total {
                    let n_token = p_eam.row_sum(fl);
                    if n_token == 0 {
                        continue;
                    }
                    // layer decay: linear, rate inversely proportional to L
                    let decay = 1.0 - (fl - cur_layer) as f64 / l_total as f64;
                    for e in 0..self.experts {
                        let ratio = p_eam.count(fl, e) as f64 / n_token as f64;
                        if ratio < self.min_ratio {
                            continue;
                        }
                        let p = (ratio + EPSILON) * decay;
                        out.push((ExpertKey::new(fl, e), p));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eamc_with_pattern() -> Eamc {
        // Two patterns over 4 layers x 8 experts: "task A" uses expert 2
        // everywhere, "task B" uses expert 5 everywhere.
        let mut a = Eam::new(4, 8);
        let mut b = Eam::new(4, 8);
        for l in 0..4 {
            a.record(l, 2, 10);
            b.record(l, 5, 10);
        }
        Eamc::construct(2, &[a, b], 7)
    }

    #[test]
    fn activation_aware_predicts_matching_pattern() {
        let eamc = eamc_with_pattern();
        let p = Predictor::new(PredictorKind::ActivationAware { refine: true }, 4, 8);
        let mut cur = Eam::new(4, 8);
        cur.record(0, 2, 4); // looks like task A
        let mut out = Vec::new();
        p.predict(&cur, &eamc, None, 0, &mut out);
        // future layers 1..4, all 8 experts each
        assert_eq!(out.len(), 3 * 8);
        // expert 2 in layer 1 must be the single highest priority
        let best = out
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert_eq!(best.0, ExpertKey::new(1, 2));
    }

    #[test]
    fn layer_decay_orders_same_ratio_experts() {
        let eamc = eamc_with_pattern();
        let p = Predictor::new(PredictorKind::ActivationAware { refine: true }, 4, 8);
        let mut cur = Eam::new(4, 8);
        cur.record(0, 2, 4);
        let mut out = Vec::new();
        p.predict(&cur, &eamc, None, 0, &mut out);
        let prio = |l: usize, e: usize| {
            out.iter()
                .find(|(k, _)| *k == ExpertKey::new(l, e))
                .unwrap()
                .1
        };
        assert!(prio(1, 2) > prio(2, 2));
        assert!(prio(2, 2) > prio(3, 2));
        // zero-ratio experts still ordered by decay thanks to EPSILON
        assert!(prio(1, 0) > prio(2, 0));
    }

    #[test]
    fn no_prediction_when_eamc_empty() {
        let eamc = Eamc::new(4, 4, 8);
        let p = Predictor::new(PredictorKind::ActivationAware { refine: true }, 4, 8);
        let cur = Eam::new(4, 8);
        let mut out = vec![(ExpertKey::new(0, 0), 1.0)];
        p.predict(&cur, &eamc, None, 0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn topk_by_id_ignores_activations() {
        let eamc = eamc_with_pattern();
        let p = Predictor::new(PredictorKind::TopK { k: 3 }, 4, 8);
        let mut cur = Eam::new(4, 8);
        cur.record(0, 5, 4); // task B — TopK doesn't care
        let mut out = Vec::new();
        p.predict(&cur, &eamc, None, 0, &mut out);
        let keys: Vec<ExpertKey> = out.iter().map(|(k, _)| *k).collect();
        assert_eq!(
            keys,
            vec![ExpertKey::new(1, 0), ExpertKey::new(1, 1), ExpertKey::new(1, 2)]
        );
    }

    #[test]
    fn traced_topk_follows_aggregate_frequency() {
        let eamc = eamc_with_pattern();
        let mut p = Predictor::new(PredictorKind::TracedTopK { k: 2 }, 4, 8);
        // history: expert 6 dominates layer 1, expert 3 second
        for _ in 0..30 {
            p.observe_route(1, 6, 2);
        }
        for _ in 0..10 {
            p.observe_route(1, 3, 2);
        }
        p.observe_route(1, 0, 1);
        let cur = Eam::new(4, 8);
        let mut out = Vec::new();
        p.predict(&cur, &eamc, None, 0, &mut out);
        let layer1 = Prediction { items: out }.for_layer(1);
        assert_eq!(layer1, vec![ExpertKey::new(1, 6), ExpertKey::new(1, 3)]);
    }

    #[test]
    fn refinement_flag_gates_repredictions() {
        let refine = Predictor::new(PredictorKind::ActivationAware { refine: true }, 4, 8);
        let oneshot = Predictor::new(PredictorKind::ActivationAware { refine: false }, 4, 8);
        assert!(refine.should_predict(2, 5));
        assert!(oneshot.should_predict(0, 0));
        assert!(!oneshot.should_predict(1, 0));
        assert!(!oneshot.should_predict(0, 1));
        let none = Predictor::new(PredictorKind::NoPrefetch, 4, 8);
        assert!(!none.should_predict(0, 0));
    }

    #[test]
    fn last_layer_predicts_nothing_for_next_layer_strategies() {
        let eamc = eamc_with_pattern();
        for kind in [PredictorKind::TopK { k: 4 }, PredictorKind::TracedTopK { k: 4 }] {
            let p = Predictor::new(kind, 4, 8);
            let cur = Eam::new(4, 8);
            let mut out = Vec::new();
            p.predict(&cur, &eamc, None, 3, &mut out);
            assert!(out.is_empty());
        }
    }

    #[test]
    fn prediction_for_layer_sorted_best_first() {
        let pred = Prediction {
            items: vec![
                (ExpertKey::new(1, 0), 0.1),
                (ExpertKey::new(1, 1), 0.9),
                (ExpertKey::new(2, 0), 0.5),
            ],
        };
        assert_eq!(
            pred.for_layer(1),
            vec![ExpertKey::new(1, 1), ExpertKey::new(1, 0)]
        );
    }
}
