//! The prefetching priority queue (paper §5.3).
//!
//! Semantics from the paper:
//! * enqueueing an expert already present **replaces** its priority (remove
//!   + re-enqueue), so the order always reflects the latest prediction;
//! * experts currently undergoing a memory copy are tracked in an in-flight
//!   set and skipped on enqueue to avoid duplicate transfers;
//! * on-demand fetches enter at [`MAX_PRIORITY`] and jump everything.
//!
//! Implementation: binary max-heap with lazy deletion — each key carries a
//! generation counter; stale heap entries are discarded at pop. Push and
//! pop are O(log n); priority updates don't rebuild the heap.

use std::collections::hash_map::Entry;
use std::collections::BinaryHeap;

use crate::model::ExpertKey;
use crate::util::{DetMap, DetSet};

/// Priority used for on-demand (blocking) fetches — jumps all prefetches.
pub const MAX_PRIORITY: f64 = f64::INFINITY;

#[derive(Debug)]
struct HeapItem {
    prio: f64,
    gen: u64,
    key: ExpertKey,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.prio == other.prio && self.key == other.key && self.gen == other.gen
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap by priority; tie-break deterministic: earlier layer, then
        // lower expert id, then newer generation. The order must be TOTAL:
        // the old `partial_cmp(..).unwrap_or(Equal)` made a NaN priority
        // compare Equal to everything, which violates transitivity and
        // silently corrupts the binary heap's pop order. A NaN now sorts
        // below every other priority (it is never worth a transfer) and the
        // key/gen tie-breaks keep the order total and antisymmetric.
        let a = if self.prio.is_nan() { f64::NEG_INFINITY } else { self.prio };
        let b = if other.prio.is_nan() { f64::NEG_INFINITY } else { other.prio };
        a.total_cmp(&b)
            .then_with(|| other.key.cmp(&self.key))
            .then_with(|| self.gen.cmp(&other.gen))
    }
}

/// Priority queue of expert prefetch requests.
#[derive(Debug, Default)]
pub struct PrefetchQueue {
    heap: BinaryHeap<HeapItem>,
    /// Latest (generation, priority) per enqueued key.
    live: DetMap<ExpertKey, (u64, f64)>,
    in_flight: DetSet<ExpertKey>,
    gen: u64,
    /// Lazy-deletion bookkeeping: stale entries currently in the heap.
    stale: usize,
}

impl PrefetchQueue {
    pub fn new() -> PrefetchQueue {
        PrefetchQueue::default()
    }

    /// Number of live (non-stale) queued requests.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Submit or update a prefetch request (Alg. 1 `q.submit`). Skips keys
    /// already being copied (§5.3 in-flight dedup). Returns whether the key
    /// is now queued.
    pub fn submit(&mut self, key: ExpertKey, prio: f64) -> bool {
        debug_assert!(!prio.is_nan(), "NaN prefetch priority for {key}");
        if self.in_flight.contains(&key) {
            return false;
        }
        self.gen += 1;
        match self.live.entry(key) {
            Entry::Occupied(mut o) => {
                // replace = old entry becomes stale in the heap
                self.stale += 1;
                o.insert((self.gen, prio));
            }
            Entry::Vacant(v) => {
                v.insert((self.gen, prio));
            }
        }
        self.heap.push(HeapItem {
            prio,
            gen: self.gen,
            key,
        });
        // per-iteration re-prioritization resubmits whole prediction sets
        // without ever popping; compacting here too keeps the heap within a
        // constant factor of the live set under pure submit/cancel churn
        self.maybe_compact();
        true
    }

    /// Pop the highest-priority live request and mark it in-flight.
    pub fn pop(&mut self) -> Option<(ExpertKey, f64)> {
        while let Some(item) = self.heap.pop() {
            match self.live.get(&item.key) {
                Some(&(gen, _)) if gen == item.gen => {
                    self.live.remove(&item.key);
                    self.in_flight.insert(item.key);
                    self.maybe_compact();
                    return Some((item.key, item.prio));
                }
                _ => {
                    self.stale = self.stale.saturating_sub(1);
                }
            }
        }
        None
    }

    /// Remove a queued request without transferring (e.g., the expert became
    /// resident through another tier's transfer).
    pub fn cancel(&mut self, key: ExpertKey) {
        if self.live.remove(&key).is_some() {
            self.stale += 1;
            self.maybe_compact();
        }
    }

    /// Mark a transfer finished; the key may be enqueued again afterwards.
    pub fn complete(&mut self, key: ExpertKey) {
        self.in_flight.remove(&key);
    }

    pub fn is_in_flight(&self, key: ExpertKey) -> bool {
        self.in_flight.contains(&key)
    }

    pub fn contains(&self, key: ExpertKey) -> bool {
        self.live.contains_key(&key)
    }

    pub fn priority_of(&self, key: ExpertKey) -> Option<f64> {
        self.live.get(&key).map(|&(_, p)| p)
    }

    /// Drop everything queued (sequence boundary).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.live.clear();
        self.stale = 0;
    }

    /// Heap housekeeping: drop stale entries in place when they dominate.
    /// Runs from `pop` *and* from `submit`/`cancel` — a workload that only
    /// re-prioritizes (submit/cancel churn with no pops, exactly what
    /// per-iteration re-prediction does) would otherwise grow the heap
    /// without bound. Keeps every operation amortized O(log n).
    /// `retain` filters the heap's own buffer — no allocation, so the
    /// serving hot path stays allocation-free through compactions too.
    fn maybe_compact(&mut self) {
        if self.stale > 64 && self.stale > 4 * self.live.len() {
            let live = &self.live;
            self.heap
                .retain(|it| live.get(&it.key).is_some_and(|&(g, _)| g == it.gen));
            self.stale = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(l: usize, e: usize) -> ExpertKey {
        ExpertKey::new(l, e)
    }

    #[test]
    fn pops_in_priority_order() {
        let mut q = PrefetchQueue::new();
        q.submit(k(0, 1), 0.3);
        q.submit(k(0, 2), 0.9);
        q.submit(k(1, 1), 0.5);
        assert_eq!(q.pop().unwrap().0, k(0, 2));
        assert_eq!(q.pop().unwrap().0, k(1, 1));
        assert_eq!(q.pop().unwrap().0, k(0, 1));
        assert!(q.pop().is_none());
    }

    #[test]
    fn resubmit_updates_priority() {
        let mut q = PrefetchQueue::new();
        q.submit(k(0, 1), 0.2);
        q.submit(k(0, 2), 0.5);
        q.submit(k(0, 1), 0.9); // upgrade
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap(), (k(0, 1), 0.9));
    }

    #[test]
    fn downgrade_also_works() {
        let mut q = PrefetchQueue::new();
        q.submit(k(0, 1), 0.9);
        q.submit(k(0, 2), 0.5);
        q.submit(k(0, 1), 0.1); // downgrade
        assert_eq!(q.pop().unwrap().0, k(0, 2));
        assert_eq!(q.pop().unwrap().0, k(0, 1));
    }

    #[test]
    fn max_priority_jumps_queue() {
        let mut q = PrefetchQueue::new();
        for e in 0..100 {
            q.submit(k(1, e), 0.99);
        }
        q.submit(k(5, 5), MAX_PRIORITY);
        assert_eq!(q.pop().unwrap().0, k(5, 5));
    }

    #[test]
    fn in_flight_dedup() {
        let mut q = PrefetchQueue::new();
        q.submit(k(0, 1), 0.5);
        let (key, _) = q.pop().unwrap();
        assert!(q.is_in_flight(key));
        assert!(!q.submit(key, 0.9), "in-flight keys are skipped (§5.3)");
        q.complete(key);
        assert!(q.submit(key, 0.9));
    }

    #[test]
    fn cancel_removes() {
        let mut q = PrefetchQueue::new();
        q.submit(k(0, 1), 0.5);
        q.submit(k(0, 2), 0.4);
        q.cancel(k(0, 1));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().0, k(0, 2));
        assert!(q.pop().is_none());
    }

    #[test]
    fn deterministic_tie_break() {
        let mut q = PrefetchQueue::new();
        q.submit(k(2, 0), 0.5);
        q.submit(k(1, 0), 0.5);
        q.submit(k(1, 7), 0.5);
        // earlier layer first, then lower expert id
        assert_eq!(q.pop().unwrap().0, k(1, 0));
        assert_eq!(q.pop().unwrap().0, k(1, 7));
        assert_eq!(q.pop().unwrap().0, k(2, 0));
    }

    #[test]
    fn heavy_churn_stays_consistent() {
        let mut q = PrefetchQueue::new();
        for round in 0..50 {
            for e in 0..64 {
                q.submit(k(0, e), (e as f64 + round as f64) % 7.0);
            }
        }
        assert_eq!(q.len(), 64);
        let mut last = f64::INFINITY;
        let mut n = 0;
        while let Some((_, p)) = q.pop() {
            assert!(p <= last + 1e-12);
            last = p;
            n += 1;
        }
        assert_eq!(n, 64);
    }

    #[test]
    fn submit_churn_without_pop_keeps_heap_bounded() {
        // regression: compaction used to run only from `pop`, so pure
        // re-prioritization churn accumulated stale heap entries forever
        let mut q = PrefetchQueue::new();
        for round in 0..1_000 {
            for e in 0..8 {
                q.submit(k(0, e), ((e + round) % 7) as f64 * 0.1);
            }
        }
        assert_eq!(q.len(), 8);
        assert!(
            q.heap.len() <= 4 * q.live.len() + 65,
            "heap {} entries for {} live keys",
            q.heap.len(),
            q.live.len()
        );
        // the queue still pops correctly after all that churn
        let mut last = f64::INFINITY;
        let mut n = 0;
        while let Some((_, p)) = q.pop() {
            assert!(p <= last + 1e-12);
            last = p;
            n += 1;
        }
        assert_eq!(n, 8);
    }

    #[test]
    fn cancel_churn_keeps_heap_bounded() {
        let mut q = PrefetchQueue::new();
        for _ in 0..1_000 {
            q.submit(k(1, 0), 0.5);
            q.submit(k(1, 1), 0.4);
            q.cancel(k(1, 0));
            q.cancel(k(1, 1));
        }
        assert!(q.is_empty());
        assert!(
            q.heap.len() <= 65,
            "cancel churn left {} heap entries with nothing live",
            q.heap.len()
        );
        assert!(q.pop().is_none());
    }

    #[test]
    fn heap_order_is_total_under_nan() {
        use std::cmp::Ordering;
        let item = |prio: f64, gen: u64, key| HeapItem { prio, gen, key };
        let nan = item(f64::NAN, 1, k(0, 0));
        let fin = item(0.1, 2, k(0, 1));
        // NaN sorts below every finite priority, both directions agree
        assert_eq!(nan.cmp(&fin), Ordering::Less);
        assert_eq!(fin.cmp(&nan), Ordering::Greater);
        // two NaNs fall through to the deterministic key/gen tie-break
        let nan2 = item(f64::NAN, 3, k(0, 2));
        assert_eq!(nan.cmp(&nan2), nan2.cmp(&nan).reverse());
        assert_eq!(nan.cmp(&item(f64::NAN, 1, k(0, 0))), Ordering::Equal);
        // and a max-heap with a NaN member still pops sanely
        let mut q = PrefetchQueue::new();
        q.submit(k(0, 1), 0.9);
        q.submit(k(0, 2), 0.5);
        // inject the NaN below the public (debug-asserted) API
        q.gen += 1;
        q.live.insert(k(0, 3), (q.gen, f64::NAN));
        q.heap.push(HeapItem {
            prio: f64::NAN,
            gen: q.gen,
            key: k(0, 3),
        });
        assert_eq!(q.pop().unwrap().0, k(0, 1));
        assert_eq!(q.pop().unwrap().0, k(0, 2));
        assert_eq!(q.pop().unwrap().0, k(0, 3), "NaN pops last, not lost");
        assert!(q.pop().is_none());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "NaN prefetch priority")]
    fn nan_submit_asserts_in_debug() {
        let mut q = PrefetchQueue::new();
        q.submit(k(0, 0), f64::NAN);
    }

    #[test]
    fn clear_empties_queue_but_not_in_flight() {
        let mut q = PrefetchQueue::new();
        q.submit(k(0, 0), 1.0);
        let (key, _) = q.pop().unwrap();
        q.submit(k(0, 1), 1.0);
        q.clear();
        assert!(q.is_empty());
        assert!(q.is_in_flight(key));
    }
}
