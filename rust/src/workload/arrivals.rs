//! Request arrival processes modelled after the Azure serverless trace
//! characteristics (§8.2): Poisson for smooth load, Gamma-interarrival for
//! bursty (CV > 1) load.

use crate::util::Rng;
use crate::workload::SequenceActivation;

/// One inference request: an arrival instant plus the routing trace of the
/// sequence it carries.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub arrival: f64,
    pub seq: SequenceActivation,
}

/// Inter-arrival generator.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Poisson with `rps` requests/second.
    Poisson { rps: f64 },
    /// Gamma-distributed inter-arrivals: mean `1/rps`, coefficient of
    /// variation `cv` (cv > 1 = burstier than Poisson, matching the Azure
    /// trace's burst structure).
    Bursty { rps: f64, cv: f64 },
}

impl ArrivalProcess {
    pub fn rps(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rps } => rps,
            ArrivalProcess::Bursty { rps, .. } => rps,
        }
    }

    /// Next inter-arrival gap in seconds.
    pub fn next_gap(&self, rng: &mut Rng) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rps } => rng.exp(rps),
            ArrivalProcess::Bursty { rps, cv } => {
                // Gamma with mean 1/rps, CV=cv: shape k = 1/cv^2,
                // scale = 1/(rps*k).
                let k = 1.0 / (cv * cv);
                rng.gamma(k, 1.0 / (rps * k))
            }
        }
    }

    /// Generate arrival timestamps covering `[0, duration)`.
    pub fn timestamps(&self, duration: f64, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::new();
        let mut t = 0.0;
        loop {
            t += self.next_gap(rng);
            if t >= duration {
                break;
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Rng::new(1);
        let p = ArrivalProcess::Poisson { rps: 5.0 };
        let ts = p.timestamps(2000.0, &mut rng);
        let rate = ts.len() as f64 / 2000.0;
        assert!((rate - 5.0).abs() < 0.25, "rate {rate}");
    }

    #[test]
    fn timestamps_sorted_within_window() {
        let mut rng = Rng::new(2);
        let p = ArrivalProcess::Bursty { rps: 3.0, cv: 2.0 };
        let ts = p.timestamps(100.0, &mut rng);
        for w in ts.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(ts.iter().all(|&t| t < 100.0));
    }

    #[test]
    fn bursty_has_higher_variance() {
        let mut rng = Rng::new(3);
        let gaps = |p: ArrivalProcess, rng: &mut Rng| -> (f64, f64) {
            let xs: Vec<f64> = (0..20_000).map(|_| p.next_gap(rng)).collect();
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
            (m, v.sqrt() / m)
        };
        let (m_p, cv_p) = gaps(ArrivalProcess::Poisson { rps: 2.0 }, &mut rng);
        let (m_b, cv_b) = gaps(ArrivalProcess::Bursty { rps: 2.0, cv: 3.0 }, &mut rng);
        assert!((m_p - 0.5).abs() < 0.03);
        assert!((m_b - 0.5).abs() < 0.06);
        assert!((cv_p - 1.0).abs() < 0.1, "poisson cv {cv_p}");
        assert!(cv_b > 2.0, "bursty cv {cv_b}");
    }
}
