//! Request arrival processes modelled after the Azure serverless trace
//! characteristics (§8.2): Poisson for smooth load, Gamma-interarrival for
//! bursty (CV > 1) load.

use crate::util::Rng;
use crate::workload::SequenceActivation;

/// Priority tier of a request. Ordered: `Batch < Normal < Interactive`
/// (derived `Ord` follows variant order), so schedulers can compare tiers
/// directly. The default is [`Priority::Normal`], which preserves the
/// pre-priority serving behavior: when every request carries the default
/// class, priority admission degenerates to FIFO and preemption never
/// fires (pinned by the scheduler differential tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Throughput-oriented background work; first to be preempted.
    Batch,
    #[default]
    Normal,
    /// Latency-sensitive traffic; may preempt lower tiers under load.
    Interactive,
}

impl Priority {
    pub fn by_name(s: &str) -> Option<Priority> {
        match s {
            "batch" => Some(Priority::Batch),
            "normal" => Some(Priority::Normal),
            "interactive" => Some(Priority::Interactive),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Normal => "normal",
            Priority::Interactive => "interactive",
        }
    }
}

/// Service class of a request: a priority tier plus an optional SLO
/// deadline. The default class (`Normal`, no SLO) reproduces the
/// class-unaware serving behavior exactly.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RequestClass {
    pub priority: Priority,
    /// Target completion latency in seconds from arrival. Under priority
    /// admission, requests with less remaining slack are admitted first
    /// within a tier; `None` sorts after every finite slack.
    pub slo: Option<f64>,
}

impl RequestClass {
    pub fn interactive() -> RequestClass {
        RequestClass {
            priority: Priority::Interactive,
            slo: None,
        }
    }

    pub fn batch() -> RequestClass {
        RequestClass {
            priority: Priority::Batch,
            slo: None,
        }
    }

    pub fn with_slo(mut self, slo: f64) -> RequestClass {
        self.slo = Some(slo);
        self
    }

    /// Remaining slack until the SLO deadline at time `now` (arrival given);
    /// `+inf` when no SLO is set.
    pub fn slack(&self, arrival: f64, now: f64) -> f64 {
        match self.slo {
            Some(s) => arrival + s - now,
            None => f64::INFINITY,
        }
    }
}

/// One inference request: an arrival instant plus the routing trace of the
/// sequence it carries and its service class.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub arrival: f64,
    pub seq: SequenceActivation,
    pub class: RequestClass,
}

impl Request {
    /// Request with the default class (`Normal` priority, no SLO).
    pub fn new(id: u64, arrival: f64, seq: SequenceActivation) -> Request {
        Request {
            id,
            arrival,
            seq,
            class: RequestClass::default(),
        }
    }

    pub fn with_class(mut self, class: RequestClass) -> Request {
        self.class = class;
        self
    }
}

/// Inter-arrival generator.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Poisson with `rps` requests/second.
    Poisson { rps: f64 },
    /// Gamma-distributed inter-arrivals: mean `1/rps`, coefficient of
    /// variation `cv` (cv > 1 = burstier than Poisson, matching the Azure
    /// trace's burst structure).
    Bursty { rps: f64, cv: f64 },
}

impl ArrivalProcess {
    pub fn rps(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rps } => rps,
            ArrivalProcess::Bursty { rps, .. } => rps,
        }
    }

    /// Next inter-arrival gap in seconds.
    pub fn next_gap(&self, rng: &mut Rng) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rps } => rng.exp(rps),
            ArrivalProcess::Bursty { rps, cv } => {
                // Gamma with mean 1/rps, CV=cv: shape k = 1/cv^2,
                // scale = 1/(rps*k).
                let k = 1.0 / (cv * cv);
                rng.gamma(k, 1.0 / (rps * k))
            }
        }
    }

    /// Generate arrival timestamps covering `[0, duration)`.
    pub fn timestamps(&self, duration: f64, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::new();
        let mut t = 0.0;
        loop {
            t += self.next_gap(rng);
            if t >= duration {
                break;
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_tiers_are_ordered() {
        assert!(Priority::Batch < Priority::Normal);
        assert!(Priority::Normal < Priority::Interactive);
        assert_eq!(Priority::default(), Priority::Normal);
        for p in [Priority::Batch, Priority::Normal, Priority::Interactive] {
            assert_eq!(Priority::by_name(p.name()), Some(p));
        }
        assert_eq!(Priority::by_name("urgent"), None);
    }

    #[test]
    fn default_class_preserves_legacy_semantics() {
        let c = RequestClass::default();
        assert_eq!(c.priority, Priority::Normal);
        assert_eq!(c.slo, None);
        assert_eq!(c.slack(1.0, 100.0), f64::INFINITY);
        let slo = RequestClass::interactive().with_slo(0.5);
        assert!((slo.slack(2.0, 2.1) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Rng::new(1);
        let p = ArrivalProcess::Poisson { rps: 5.0 };
        let ts = p.timestamps(2000.0, &mut rng);
        let rate = ts.len() as f64 / 2000.0;
        assert!((rate - 5.0).abs() < 0.25, "rate {rate}");
    }

    #[test]
    fn timestamps_sorted_within_window() {
        let mut rng = Rng::new(2);
        let p = ArrivalProcess::Bursty { rps: 3.0, cv: 2.0 };
        let ts = p.timestamps(100.0, &mut rng);
        for w in ts.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(ts.iter().all(|&t| t < 100.0));
    }

    #[test]
    fn bursty_has_higher_variance() {
        let mut rng = Rng::new(3);
        let gaps = |p: ArrivalProcess, rng: &mut Rng| -> (f64, f64) {
            let xs: Vec<f64> = (0..20_000).map(|_| p.next_gap(rng)).collect();
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
            (m, v.sqrt() / m)
        };
        let (m_p, cv_p) = gaps(ArrivalProcess::Poisson { rps: 2.0 }, &mut rng);
        let (m_b, cv_b) = gaps(ArrivalProcess::Bursty { rps: 2.0, cv: 3.0 }, &mut rng);
        assert!((m_p - 0.5).abs() < 0.03);
        assert!((m_b - 0.5).abs() < 0.06);
        assert!((cv_p - 1.0).abs() < 0.1, "poisson cv {cv_p}");
        assert!(cv_b > 2.0, "bursty cv {cv_b}");
    }
}
