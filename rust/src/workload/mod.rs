//! Workload generation: synthetic activation traces with the paper's
//! measured sparsity/locality structure, plus Azure-style arrivals.
//!
//! Substitution (DESIGN.md §3): the paper drives FLAN/BIGBench/MMLU requests
//! through real checkpoints; we have neither. Instead, a **task-cluster
//! activation model** generates per-sequence routing decisions: each dataset
//! has `n_tasks` latent tasks; each task draws a per-MoE-layer expert
//! preference distribution from a symmetric Dirichlet with small
//! concentration `alpha` (routers are trained to specialize experts per
//! input type — §4.3's theoretical argument). A sequence samples one task
//! and routes its tokens from the task's per-layer categorical with a small
//! uniform noise floor. Low `alpha` ⇒ few effective experts per task-layer
//! ⇒ the 3-20% activation sparsity and 30-56% reuse the paper measures
//! (§3) emerge naturally; tests assert those calibration bands.

mod arrivals;
mod dataset;

pub use arrivals::{ArrivalProcess, Priority, Request, RequestClass};
pub use dataset::{DatasetPreset, DATASETS};

use crate::model::ModelSpec;
use crate::trace::Eam;
use crate::util::{Pool, Rng};

/// Latent task: per-layer expert preference distributions.
#[derive(Debug, Clone)]
pub struct TaskProfile {
    /// `per_layer[l][e]` = probability task tokens route to expert `e` at
    /// MoE layer `l`.
    pub per_layer: Vec<Vec<f64>>,
}

/// The routing trace of one sequence through generative inference.
///
/// Iteration 0 is the prefill (all `prompt_len` tokens routed at every
/// layer); iterations `1..=gen_len` are single-token decode steps — matching
/// §2.1's description of the KV-cache inference procedure.
#[derive(Debug, Clone)]
pub struct SequenceActivation {
    pub task: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    /// `routes[iter][layer]` = (expert, token count) pairs, sorted by expert.
    pub routes: Vec<Vec<Vec<(u16, u32)>>>,
}

impl SequenceActivation {
    pub fn iterations(&self) -> usize {
        self.routes.len()
    }

    /// Total tokens processed (prompt + generated).
    pub fn total_tokens(&self) -> usize {
        self.prompt_len + self.gen_len
    }

    /// The complete EAM of this sequence (what offline tracing records).
    pub fn to_eam(&self, layers: usize, experts: usize) -> Eam {
        let mut m = Eam::new(layers, experts);
        for iter in &self.routes {
            for (l, row) in iter.iter().enumerate() {
                for &(e, c) in row {
                    m.record(l, e as usize, c);
                }
            }
        }
        m
    }
}

/// Workload generator bound to one model geometry + dataset preset.
pub struct Workload {
    pub spec_layers: usize,
    pub spec_experts: usize,
    pub preset: DatasetPreset,
    tasks: Vec<TaskProfile>,
    rng: Rng,
}

impl Workload {
    pub fn new(spec: &ModelSpec, preset: DatasetPreset, seed: u64) -> Workload {
        let mut rng = Rng::new(seed);
        let mut tasks: Vec<TaskProfile> = (0..preset.n_tasks)
            .map(|_| TaskProfile {
                per_layer: (0..spec.n_layers)
                    .map(|_| rng.dirichlet(spec.experts_per_layer, preset.alpha))
                    .collect(),
            })
            .collect();
        // confusable pairs: task 2i+1 shares task 2i's early-layer profiles
        let shared = preset.shared_prefix_layers.min(spec.n_layers);
        for i in (1..tasks.len()).step_by(2) {
            for l in 0..shared {
                tasks[i].per_layer[l] = tasks[i - 1].per_layer[l].clone();
            }
        }
        let tasks = tasks;
        Workload {
            spec_layers: spec.n_layers,
            spec_experts: spec.experts_per_layer,
            preset,
            tasks,
            rng,
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Generate one sequence: sample a task, then route every token of every
    /// iteration through the task's per-layer categorical (with noise).
    pub fn gen_sequence(&mut self) -> SequenceActivation {
        // advance the generator's own sequential stream (cheap clone-out
        // keeps the shared `gen_sequence_with` core borrowable on `&self`)
        let mut rng = self.rng.clone();
        let s = self.gen_sequence_with(&mut rng);
        self.rng = rng;
        s
    }

    pub fn gen_sequence_for_task(&mut self, task: usize) -> SequenceActivation {
        let mut rng = self.rng.clone();
        let s = self.gen_sequence_for_task_with(task, &mut rng);
        self.rng = rng;
        s
    }

    /// Core generator drawing from an explicit stream — the task profiles
    /// are immutable, so any number of pool workers can generate sequences
    /// concurrently from their own [`Rng::for_stream`] generators.
    pub fn gen_sequence_with(&self, rng: &mut Rng) -> SequenceActivation {
        let task = rng.below(self.tasks.len());
        self.gen_sequence_for_task_with(task, rng)
    }

    pub fn gen_sequence_for_task_with(&self, task: usize, rng: &mut Rng) -> SequenceActivation {
        let prompt_len = self.preset.prompt_min
            + rng.below(self.preset.prompt_max - self.preset.prompt_min + 1);
        // geometric-ish generation length
        let mut gen_len = 1;
        while gen_len < self.preset.gen_max && rng.f64() > 1.0 / self.preset.gen_mean as f64 {
            gen_len += 1;
        }
        let profile = &self.tasks[task];
        let mut routes = Vec::with_capacity(1 + gen_len);
        // prefill iteration routes all prompt tokens
        routes.push(route_tokens(
            profile,
            prompt_len as u32,
            self.preset.noise,
            self.spec_experts,
            rng,
        ));
        for _ in 0..gen_len {
            routes.push(route_tokens(
                profile,
                1,
                self.preset.noise,
                self.spec_experts,
                rng,
            ));
        }
        SequenceActivation {
            task,
            prompt_len,
            gen_len,
            routes,
        }
    }

    /// Generate the offline EAM dataset used for EAMC construction (§4.2
    /// "we choose the validation dataset or the fine-tuning dataset").
    pub fn gen_eam_dataset(&mut self, n: usize) -> Vec<Eam> {
        (0..n)
            .map(|_| {
                let s = self.gen_sequence();
                s.to_eam(self.spec_layers, self.spec_experts)
            })
            .collect()
    }

    /// Pool-parallel offline dataset generation. Sequence `i` draws from
    /// the SplitMix64-derived stream `Rng::for_stream(stream_seed, i)`, so
    /// the dataset is a pure function of `(workload, stream_seed, n)` —
    /// bitwise identical at any thread count, and `par(n)` is a prefix of
    /// `par(m)` for `n < m`. (This is a *different* dataset than the
    /// sequential [`Workload::gen_eam_dataset`], whose single stream cannot
    /// be split without serializing.)
    pub fn gen_eam_dataset_par(&self, pool: &Pool, n: usize, stream_seed: u64) -> Vec<Eam> {
        pool.map_range(n, |i| {
            let mut rng = Rng::for_stream(stream_seed, i as u64);
            self.gen_sequence_with(&mut rng)
                .to_eam(self.spec_layers, self.spec_experts)
        })
    }
}

/// Route `tokens` tokens at every layer from `profile` (+uniform noise).
fn route_tokens(
    profile: &TaskProfile,
    tokens: u32,
    noise: f64,
    experts: usize,
    rng: &mut Rng,
) -> Vec<Vec<(u16, u32)>> {
    profile
        .per_layer
        .iter()
        .map(|dist| {
            let mut counts: std::collections::BTreeMap<u16, u32> = std::collections::BTreeMap::new();
            for _ in 0..tokens {
                let e = if rng.f64() < noise {
                    rng.below(experts)
                } else {
                    rng.categorical(dist)
                };
                *counts.entry(e as u16).or_insert(0) += 1;
            }
            counts.into_iter().collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec::preset("switch-base-128").unwrap()
    }

    #[test]
    fn sequences_are_deterministic_per_seed() {
        let s = spec();
        let p = DatasetPreset::by_name("flan").unwrap();
        let mut a = Workload::new(&s, p.clone(), 9);
        let mut b = Workload::new(&s, p, 9);
        let sa = a.gen_sequence();
        let sb = b.gen_sequence();
        assert_eq!(sa.task, sb.task);
        assert_eq!(sa.routes, sb.routes);
    }

    #[test]
    fn route_counts_conserve_tokens() {
        let s = spec();
        let p = DatasetPreset::by_name("mixed").unwrap();
        let mut w = Workload::new(&s, p, 3);
        let seq = w.gen_sequence();
        // prefill row sums = prompt_len at every layer
        for row in &seq.routes[0] {
            let sum: u32 = row.iter().map(|&(_, c)| c).sum();
            assert_eq!(sum, seq.prompt_len as u32);
        }
        // decode rows route exactly one token
        for iter in &seq.routes[1..] {
            for row in iter {
                let sum: u32 = row.iter().map(|&(_, c)| c).sum();
                assert_eq!(sum, 1);
            }
        }
    }

    #[test]
    fn eam_row_invariant_holds() {
        // §4.2: sum_j M[i][j] = n for every layer i.
        let s = spec();
        let p = DatasetPreset::by_name("flan").unwrap();
        let mut w = Workload::new(&s, p, 4);
        let seq = w.gen_sequence();
        let eam = seq.to_eam(s.n_layers, s.experts_per_layer);
        let n = seq.total_tokens() as u32;
        for l in 0..s.n_layers {
            assert_eq!(eam.row_sum(l), n);
        }
    }

    #[test]
    fn calibration_sparse_activation_band() {
        // Paper §3: single sequences activate ~3-20% of experts and reuse
        // 30%+ of them. Check the generator reproduces that band on
        // switch-base-128 geometry.
        let s = spec();
        let p = DatasetPreset::by_name("mixed").unwrap();
        let mut w = Workload::new(&s, p, 5);
        let mut act = 0.0;
        let mut reuse = 0.0;
        let n = 50;
        for _ in 0..n {
            let seq = w.gen_sequence();
            let eam = seq.to_eam(s.n_layers, s.experts_per_layer);
            act += eam.activation_fraction();
            reuse += eam.reuse_fraction();
        }
        act /= n as f64;
        reuse /= n as f64;
        assert!(
            (0.02..=0.25).contains(&act),
            "single-sequence activation fraction {act} outside paper band"
        );
        assert!(
            reuse >= 0.30,
            "reuse fraction {reuse} below paper's 30% floor"
        );
    }

    #[test]
    fn same_task_sequences_are_similar_different_tasks_are_not() {
        let s = spec();
        let p = DatasetPreset::by_name("flan").unwrap();
        let mut w = Workload::new(&s, p, 6);
        let a1 = w.gen_sequence_for_task(0).to_eam(s.n_layers, s.experts_per_layer);
        let a2 = w.gen_sequence_for_task(0).to_eam(s.n_layers, s.experts_per_layer);
        let b = w.gen_sequence_for_task(1).to_eam(s.n_layers, s.experts_per_layer);
        let d_same = a1.distance(&a2);
        let d_diff = a1.distance(&b);
        assert!(
            d_same < d_diff,
            "same-task distance {d_same} must beat cross-task {d_diff}"
        );
        assert!(d_same < 0.5);
        assert!(d_diff > 0.5);
    }

    #[test]
    fn confusable_pairs_share_early_layers_only() {
        let s = spec();
        let p = DatasetPreset::by_name("mixed").unwrap();
        let w = Workload::new(&s, p.clone(), 8);
        let shared = p.shared_prefix_layers;
        assert_eq!(w.tasks[0].per_layer[0], w.tasks[1].per_layer[0]);
        assert_eq!(
            w.tasks[0].per_layer[shared - 1],
            w.tasks[1].per_layer[shared - 1]
        );
        assert_ne!(w.tasks[0].per_layer[shared], w.tasks[1].per_layer[shared]);
        // unpaired tasks stay independent
        assert_ne!(w.tasks[0].per_layer[0], w.tasks[2].per_layer[0]);
    }

    #[test]
    fn par_dataset_is_thread_invariant_and_prefix_stable() {
        let s = spec();
        let p = DatasetPreset::by_name("mixed").unwrap();
        let w = Workload::new(&s, p, 11);
        let base = w.gen_eam_dataset_par(&Pool::serial(), 12, 0xDA7A);
        for threads in [2, 8] {
            let got = w.gen_eam_dataset_par(&Pool::new(threads), 12, 0xDA7A);
            assert_eq!(got, base, "threads={threads}");
        }
        // per-index streams make shorter runs prefixes of longer ones
        let longer = w.gen_eam_dataset_par(&Pool::new(4), 20, 0xDA7A);
        assert_eq!(&longer[..12], &base[..]);
    }

    #[test]
    fn eam_dataset_size() {
        let s = spec();
        let p = DatasetPreset::by_name("mmlu").unwrap();
        let mut w = Workload::new(&s, p, 7);
        let ds = w.gen_eam_dataset(20);
        assert_eq!(ds.len(), 20);
    }
}
