//! Dataset presets standing in for the paper's request datasets (§8.1).
//!
//! Parameters are chosen so the resulting activation statistics land in the
//! bands the paper reports (§3) — see the calibration tests in
//! `workload/mod.rs`. Presets differ in task count and concentration, which
//! is what drives the per-dataset latency differences in Fig. 8.

/// One request-dataset preset.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetPreset {
    pub name: &'static str,
    /// Number of latent tasks (distinct activation patterns).
    pub n_tasks: usize,
    /// Dirichlet concentration of per-task expert preferences; lower =
    /// sparser, stickier activations.
    pub alpha: f64,
    /// Probability a token ignores its task profile (routing noise).
    pub noise: f64,
    pub prompt_min: usize,
    pub prompt_max: usize,
    /// Mean / max generated tokens (geometric length model).
    pub gen_mean: usize,
    pub gen_max: usize,
    /// Tasks are generated in *confusable pairs* sharing their expert
    /// preferences for the first `shared_prefix_layers` MoE layers and
    /// diverging deeper. This reflects real MoE routing, where early layers
    /// process surface features shared across task families — and it is
    /// precisely what makes one-shot prediction ambiguous and continuous
    /// refinement (§5.2, §8.3) valuable.
    pub shared_prefix_layers: usize,
}

/// All presets.
pub const DATASETS: &[DatasetPreset] = &[
    // FLAN: many instruction-following task families.
    DatasetPreset {
        name: "flan",
        n_tasks: 60,
        alpha: 0.055,
        noise: 0.06,
        prompt_min: 16,
        prompt_max: 96,
        gen_mean: 24,
        gen_max: 64,
        shared_prefix_layers: 4,
    },
    // BIGBench: fewer, more exotic tasks; slightly peakier routing.
    DatasetPreset {
        name: "bigbench",
        n_tasks: 40,
        alpha: 0.045,
        noise: 0.05,
        prompt_min: 24,
        prompt_max: 128,
        gen_mean: 20,
        gen_max: 64,
        shared_prefix_layers: 4,
    },
    // MMLU: 57 subjects, short multiple-choice answers.
    DatasetPreset {
        name: "mmlu",
        n_tasks: 57,
        alpha: 0.07,
        noise: 0.08,
        prompt_min: 32,
        prompt_max: 160,
        gen_mean: 8,
        gen_max: 24,
        shared_prefix_layers: 6,
    },
    // Mixed chatbot emulation (the default workload in §8.1).
    DatasetPreset {
        name: "mixed",
        n_tasks: 120,
        alpha: 0.06,
        noise: 0.07,
        prompt_min: 16,
        prompt_max: 128,
        gen_mean: 24,
        gen_max: 64,
        shared_prefix_layers: 5,
    },
    // NLLB-style translation: dominated by one language pair, activation
    // "exhibits a high degree of similarity" (§8.3).
    DatasetPreset {
        name: "translation",
        n_tasks: 8,
        alpha: 0.04,
        noise: 0.04,
        prompt_min: 16,
        prompt_max: 96,
        gen_mean: 32,
        gen_max: 96,
        shared_prefix_layers: 2,
    },
];

impl DatasetPreset {
    pub fn by_name(name: &str) -> Option<DatasetPreset> {
        DATASETS.iter().find(|d| d.name == name).cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_unique_and_resolvable() {
        let mut names: Vec<&str> = DATASETS.iter().map(|d| d.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), DATASETS.len());
        for d in DATASETS {
            assert_eq!(DatasetPreset::by_name(d.name).unwrap(), d.clone());
        }
        assert!(DatasetPreset::by_name("imagenet").is_none());
    }

    #[test]
    fn parameters_sane() {
        for d in DATASETS {
            assert!(d.n_tasks > 0);
            assert!(d.alpha > 0.0 && d.alpha < 1.0);
            assert!((0.0..0.5).contains(&d.noise));
            assert!(d.prompt_min <= d.prompt_max);
            assert!(d.gen_mean <= d.gen_max);
            assert!(d.shared_prefix_layers <= 8);
        }
    }
}
