//! Expert Activation Matrix Collection (paper §4.2-§4.3).

use std::collections::VecDeque;

use crate::trace::matcher::MatcherIndex;
use crate::trace::{kmeans_medoids_with, Eam};
use crate::util::Pool;

/// Counters exposed for the §8.5 experiments (adaptation speed, overhead).
#[derive(Debug, Clone, Default)]
pub struct EamcStats {
    /// Completed-sequence EAMs observed since the last (re)construction.
    pub observed_since_build: usize,
    /// Number of (re)constructions performed.
    pub builds: usize,
    /// Sequences flagged as poorly predicted (candidates for rebuild).
    pub poor_predictions: usize,
}

/// Fixed-capacity collection of representative EAMs.
///
/// Built offline from a relevant dataset by k-means (capacity = k) and
/// queried online with `nearest()` during generation. Handles distribution
/// shift (§4.3) by recording recently observed EAMs and re-clustering once
/// enough poorly-predicted sequences accumulate.
pub struct Eamc {
    capacity: usize,
    layers: usize,
    experts: usize,
    eams: Vec<Eam>,
    /// Per-entry row-normalized unit vectors in **sparse CSR** form: one
    /// flat (expert, weight) arena per entry plus row offsets. EAM rows are
    /// 3-20% dense (the premise of the paper), so sparse storage shrinks a
    /// 300-entry switch-large EAMC from 3.6MB of dense f32 (memory-bound
    /// ~230us per lookup) to a few hundred KB of contiguous data — reaching
    /// the paper's ~21us lookup (§8.5; EXPERIMENTS.md §Perf).
    sparse: Vec<SparseEam>,
    /// Inverted index over `sparse` for the incremental serving-path
    /// matcher (`trace::matcher`): `(layer, expert) → [(entry, weight)]`.
    index: MatcherIndex,
    /// Sliding window (ring) of recently completed sequence EAMs, fuel for
    /// online reconstruction. At capacity the oldest slot is recycled via
    /// `Eam::copy_from`, keeping `observe` allocation-free.
    recent: VecDeque<Eam>,
    recent_cap: usize,
    /// Rebuild once this many poorly-predicted sequences are seen.
    rebuild_threshold: usize,
    stats: EamcStats,
    seed: u64,
}

impl Eamc {
    /// Empty collection; `nearest()` returns `None` until populated.
    pub fn new(capacity: usize, layers: usize, experts: usize) -> Eamc {
        Eamc {
            capacity,
            layers,
            experts,
            eams: Vec::new(),
            sparse: Vec::new(),
            index: MatcherIndex::empty(layers, experts),
            recent: VecDeque::new(),
            recent_cap: 512,
            rebuild_threshold: 100,
            stats: EamcStats::default(),
            seed: 0x5EED,
        }
    }

    /// Offline construction (§4.2): cluster `dataset` EAMs into `capacity`
    /// groups and keep the medoids. Runs the clustering on
    /// [`Pool::from_env`] (`MOE_POOL_THREADS` overrides); the result is
    /// bitwise identical at any thread count (see `trace::kmeans`).
    pub fn construct(capacity: usize, dataset: &[Eam], seed: u64) -> Eamc {
        Eamc::construct_with(capacity, dataset, seed, &Pool::from_env())
    }

    /// [`Eamc::construct`] on an explicit worker pool (the offline-path
    /// benches and differential tests pin thread counts this way).
    pub fn construct_with(capacity: usize, dataset: &[Eam], seed: u64, pool: &Pool) -> Eamc {
        assert!(!dataset.is_empty());
        let layers = dataset[0].layers();
        let experts = dataset[0].experts();
        let mut c = Eamc::new(capacity, layers, experts);
        c.seed = seed;
        c.rebuild_from_with(dataset, pool);
        c
    }

    /// Serving-path reconstruction (triggered from [`Eamc::observe`]): runs
    /// serially — spawning workers mid-serving would trade tail latency for
    /// a rebuild that is off the per-token critical path anyway, and the
    /// serial pool produces the identical collection by construction.
    fn rebuild_from(&mut self, dataset: &[Eam]) {
        self.rebuild_from_with(dataset, &Pool::serial());
    }

    fn rebuild_from_with(&mut self, dataset: &[Eam], pool: &Pool) {
        let r = kmeans_medoids_with(
            dataset,
            self.capacity,
            50,
            self.seed.wrapping_add(self.stats.builds as u64),
            pool,
        );
        self.eams = r.medoids.iter().map(|&i| dataset[i].clone()).collect();
        self.sparse = pool.map(&self.eams, |_, m| sparse_unit_rows(m));
        self.stats.builds += 1;
        self.stats.observed_since_build = 0;
        self.stats.poor_predictions = 0;
        self.rebuild_index();
    }

    /// Rebuild the inverted posting lists from `sparse` (called once per
    /// (re)construction — never on the serving path).
    fn rebuild_index(&mut self) {
        let (l, e) = (self.layers, self.experts);
        let mut cells: Vec<Vec<(u32, f32)>> = vec![Vec::new(); l * e];
        for (i, s) in self.sparse.iter().enumerate() {
            for li in 0..l {
                let (a, b) = (s.offsets[li] as usize, s.offsets[li + 1] as usize);
                for &(idx, v) in &s.data[a..b] {
                    cells[li * e + idx as usize].push((i as u32, v));
                }
            }
        }
        self.index =
            MatcherIndex::from_cells(l, e, self.sparse.len(), self.stats.builds as u64, &cells);
    }

    /// The inverted index of the current build (for matcher handles).
    pub fn index(&self) -> &MatcherIndex {
        &self.index
    }

    /// Monotonic (re)construction counter; matcher handles attached to an
    /// older build must re-sync.
    pub fn build_id(&self) -> u64 {
        self.stats.builds as u64
    }

    /// Stored entry by index (pairs with `nearest_entry` / matcher output).
    pub fn entry(&self, i: usize) -> &Eam {
        &self.eams[i]
    }

    pub fn len(&self) -> usize {
        self.eams.len()
    }

    pub fn is_empty(&self) -> bool {
        self.eams.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn experts(&self) -> usize {
        self.experts
    }

    pub fn stats(&self) -> &EamcStats {
        &self.stats
    }

    pub fn iter(&self) -> impl Iterator<Item = &Eam> {
        self.eams.iter()
    }

    /// Memory footprint of the stored EAMs (§8.5: <= 1.8 MB for 300 EAMs of
    /// switch-large geometry... with u32 cells; the paper stores u16).
    pub fn bytes(&self) -> usize {
        self.eams.iter().map(|e| e.bytes()).sum()
    }

    /// Footprint of the sparse lookup structure actually touched per
    /// `nearest()` call (§8.5 overhead accounting).
    pub fn lookup_bytes(&self) -> usize {
        self.sparse
            .iter()
            .map(|s| s.offsets.len() * 4 + s.data.len() * std::mem::size_of::<(u16, f32)>())
            .sum()
    }

    /// Alg. 1 steps 16-21: the stored EAM with minimal partial distance to
    /// the current (in-progress) EAM. `None` when the collection is empty.
    ///
    /// This is the serving-path hot call — §8.5 reports ~21us at 300 EAMs.
    /// The query's rows are normalized **once**; each stored entry then
    /// costs one dot product per traced row against its precomputed unit
    /// vector (see `benches/perf_hotpath.rs`).
    pub fn nearest(&self, cur: &Eam) -> Option<(&Eam, f64)> {
        self.nearest_entry(cur).map(|(i, d)| (&self.eams[i], d))
    }

    /// [`Eamc::nearest`] returning the entry *index* (the form the
    /// incremental matcher mirrors and the differential tests compare).
    pub fn nearest_entry(&self, cur: &Eam) -> Option<(usize, f64)> {
        if self.eams.is_empty() {
            return None;
        }
        let (l, e) = (self.layers, self.experts);
        // normalize the query once
        let q = unit_rows(cur);
        let q_rows: Vec<usize> = (0..l).filter(|&li| cur.row_sum(li) > 0).collect();
        if q_rows.is_empty() {
            // nothing traced yet: Eq. 1 over zero rows is 0 for everything
            return Some((0, 0.0));
        }
        let mut best = 0usize;
        let mut best_sim = f32::NEG_INFINITY;
        for (i, entry) in self.sparse.iter().enumerate() {
            let mut sim = 0.0f32;
            for &li in &q_rows {
                let qrow = &q[li * e..(li + 1) * e];
                let (s, t) = (entry.offsets[li] as usize, entry.offsets[li + 1] as usize);
                // sparse dot: only the entry's active experts contribute
                for &(idx, v) in &entry.data[s..t] {
                    sim += v * qrow[idx as usize];
                }
            }
            if sim > best_sim {
                best_sim = sim;
                best = i;
            }
        }
        let best_d = 1.0 - best_sim as f64 / q_rows.len() as f64;
        Some((best, best_d))
    }

    /// Reference (f64, no incremental state) truncated-cosine partial
    /// distance from `cur` to stored entry `i` — the arbiter both the full
    /// scan and the incremental matcher are tested against.
    pub fn distance_to_entry(&self, cur: &Eam, i: usize) -> f64 {
        let mut rows = 0usize;
        let mut sim = 0.0f64;
        let entry = &self.sparse[i];
        for li in 0..self.layers {
            if cur.row_sum(li) == 0 {
                continue;
            }
            rows += 1;
            let row = cur.row(li);
            let norm2: u64 = row.iter().map(|&c| c as u64 * c as u64).sum();
            let (s, t) = (entry.offsets[li] as usize, entry.offsets[li + 1] as usize);
            let mut dot = 0.0f64;
            for &(idx, v) in &entry.data[s..t] {
                dot += v as f64 * row[idx as usize] as f64;
            }
            sim += dot / (norm2 as f64).sqrt();
        }
        if rows == 0 {
            0.0
        } else {
            1.0 - sim / rows as f64
        }
    }

    /// Online path (§4.3): feed back the completed EAM of a served sequence
    /// together with whether its prefetch accuracy was satisfactory.
    /// Reconstructs the collection from the recent window once
    /// `rebuild_threshold` poorly-predicted sequences accumulate.
    ///
    /// Returns `true` if a reconstruction happened.
    ///
    /// O(1) amortized: the recent window is a ring (`VecDeque`) whose
    /// oldest slot is recycled in place once full, and a reconstruction
    /// clusters the window in place instead of cloning it first.
    pub fn observe(&mut self, completed: &Eam, well_predicted: bool) -> bool {
        self.stats.observed_since_build += 1;
        if !well_predicted {
            self.stats.poor_predictions += 1;
        }
        if self.recent.len() == self.recent_cap {
            // recycle the oldest slot's buffers instead of shifting O(n)
            let mut slot = self.recent.pop_front().expect("ring at capacity");
            slot.copy_from(completed);
            self.recent.push_back(slot);
        } else {
            self.recent.push_back(completed.clone());
        }
        if self.stats.poor_predictions >= self.rebuild_threshold && !self.recent.is_empty() {
            // take the window out so the re-clustering can borrow it as a
            // slice while `self` is mutated (no clone of the dataset)
            let mut recent = std::mem::take(&mut self.recent);
            self.rebuild_from(recent.make_contiguous());
            self.recent = recent;
            true
        } else {
            false
        }
    }

    /// Lower the rebuild threshold (tests / drift experiments).
    pub fn set_rebuild_threshold(&mut self, t: usize) {
        self.rebuild_threshold = t;
    }

    /// Shrink/grow the recent-window ring (tests / drift experiments).
    /// Oldest entries are dropped if the window is over the new capacity.
    pub fn set_recent_capacity(&mut self, cap: usize) {
        self.recent_cap = cap.max(1);
        while self.recent.len() > self.recent_cap {
            self.recent.pop_front();
        }
    }
}

/// CSR-style sparse row-normalized EAM: flat (expert, weight) arena + row
/// offsets (length L+1).
struct SparseEam {
    offsets: Vec<u32>,
    data: Vec<(u16, f32)>,
}

/// Per-row truncation width: cosine similarity is dominated by the largest
/// activation ratios (the expert "head"); keeping the top-8 weights per row
/// preserves the nearest-match decision while cutting lookup work ~4x. The
/// tail of near-zero weights is routing noise by construction.
const SPARSE_TOP_K: usize = 8;

fn sparse_unit_rows(m: &Eam) -> SparseEam {
    let (l, e) = (m.layers(), m.experts());
    let mut offsets = Vec::with_capacity(l + 1);
    let mut data = Vec::new();
    offsets.push(0);
    for li in 0..l {
        let row = m.row(li);
        let norm: f32 = row.iter().map(|&c| (c as f32) * (c as f32)).sum::<f32>().sqrt();
        if norm > 0.0 {
            let mut pairs: Vec<(u16, u32)> = (0..e)
                .filter(|&k| row[k] > 0)
                .map(|k| (k as u16, row[k]))
                .collect();
            if pairs.len() > SPARSE_TOP_K {
                pairs.sort_by(|a, b| b.1.cmp(&a.1));
                pairs.truncate(SPARSE_TOP_K);
                pairs.sort_by_key(|p| p.0);
            }
            for (k, c) in pairs {
                data.push((k, c as f32 / norm));
            }
        }
        offsets.push(data.len() as u32);
    }
    SparseEam { offsets, data }
}

/// Row-normalized unit vectors of an EAM (zero rows stay zero).
fn unit_rows(m: &Eam) -> Vec<f32> {
    let (l, e) = (m.layers(), m.experts());
    let mut out = vec![0.0f32; l * e];
    for li in 0..l {
        let row = m.row(li);
        let norm: f32 = row.iter().map(|&c| (c as f32) * (c as f32)).sum::<f32>().sqrt();
        if norm > 0.0 {
            for k in 0..e {
                out[li * e + k] = row[k] as f32 / norm;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_hot(layers: usize, experts: usize, hot: usize, tokens: u32) -> Eam {
        let mut m = Eam::new(layers, experts);
        for l in 0..layers {
            m.record(l, hot, tokens);
        }
        m
    }

    fn dataset(hots: &[usize]) -> Vec<Eam> {
        hots.iter().map(|&h| one_hot(4, 8, h, 5)).collect()
    }

    #[test]
    fn construct_respects_capacity() {
        let ds = dataset(&[0, 0, 0, 3, 3, 3, 7, 7, 7]);
        let c = Eamc::construct(3, &ds, 1);
        assert_eq!(c.len(), 3);
        assert_eq!(c.capacity(), 3);
    }

    #[test]
    fn nearest_finds_matching_pattern() {
        let ds = dataset(&[0, 0, 0, 3, 3, 3, 7, 7, 7]);
        let c = Eamc::construct(3, &ds, 1);
        let mut cur = Eam::new(4, 8);
        cur.record(0, 3, 2); // first layer routed to expert 3
        let (m, d) = c.nearest(&cur).unwrap();
        assert!(d < 1e-9);
        assert!(m.count(1, 3) > 0, "matched EAM should predict expert 3 deeper");
    }

    #[test]
    fn nearest_empty_is_none() {
        let c = Eamc::new(4, 2, 2);
        let cur = Eam::new(2, 2);
        assert!(c.nearest(&cur).is_none());
    }

    #[test]
    fn observe_triggers_rebuild_on_drift() {
        let ds = dataset(&[0, 0, 0, 0]);
        let mut c = Eamc::construct(2, &ds, 2);
        c.set_rebuild_threshold(5);
        // a new distribution routes to expert 6
        let mut rebuilt = false;
        for _ in 0..5 {
            rebuilt |= c.observe(&one_hot(4, 8, 6, 5), false);
        }
        assert!(rebuilt, "rebuild should fire at the threshold");
        // after rebuild, the new pattern is representable
        let mut cur = Eam::new(4, 8);
        cur.record(0, 6, 1);
        let (_, d) = c.nearest(&cur).unwrap();
        assert!(d < 1e-9, "post-rebuild distance {d}");
        assert_eq!(c.stats().builds, 2);
    }

    #[test]
    fn well_predicted_observations_do_not_rebuild() {
        let ds = dataset(&[0, 0, 0]);
        let mut c = Eamc::construct(2, &ds, 3);
        c.set_rebuild_threshold(5);
        for _ in 0..50 {
            assert!(!c.observe(&one_hot(4, 8, 0, 5), true));
        }
        assert_eq!(c.stats().builds, 1);
    }

    #[test]
    fn nearest_matches_naive_distance_partial() {
        // the unit-vector fast path must agree with Eam::distance_partial
        let mut ds = Vec::new();
        for h in [0usize, 2, 5, 7] {
            let mut m = Eam::new(4, 8);
            for l in 0..4 {
                m.record(l, h, 3 + l as u32);
                m.record(l, (h + 1) % 8, 1);
            }
            ds.push(m);
        }
        let c = Eamc::construct(4, &ds, 9);
        let mut cur = Eam::new(4, 8);
        cur.record(0, 5, 2);
        cur.record(1, 5, 1);
        let (fast, fd) = c.nearest(&cur).unwrap();
        let (naive, nd) = c
            .iter()
            .map(|m| (m, cur.distance_partial(m)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!((fd - nd).abs() < 1e-5, "fast {fd} vs naive {nd}");
        assert_eq!(fast.row(0), naive.row(0));
    }

    #[test]
    fn bytes_footprint() {
        let ds = dataset(&[0, 1, 2, 3]);
        let c = Eamc::construct(4, &ds, 4);
        assert_eq!(c.bytes(), 4 * 4 * 8 * 4); // 4 EAMs x L4 x E8 x u32
    }
}
