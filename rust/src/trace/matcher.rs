//! Incremental EAMC matching (the serving-path replacement for
//! [`Eamc::nearest`]'s full scan).
//!
//! [`Eamc::nearest`] recomputes, on every call, the truncated-cosine
//! similarity between the in-progress EAM and **all** stored entries —
//! allocating a fresh `L×E` unit-row buffer each time. But between two
//! lookups of the same sequence, only the cells that routing just touched
//! changed. This module exploits that:
//!
//! * [`MatcherIndex`] — an inverted index built from the EAMC's sparse
//!   rows: `(layer, expert) → [(entry_id, unit_weight)]` posting lists in
//!   CSR form. Rebuilt only when the EAMC itself is (re)constructed.
//! * [`EamcMatcher`] — a per-sequence handle holding cosine accumulators.
//!   [`EamcMatcher::record`] folds one routing event into the accumulators
//!   of exactly the entries whose posting lists mention the touched cell;
//!   [`EamcMatcher::nearest`] is then a scan-free argmax over `n` floats —
//!   no dot products, no normalization work, no allocation.
//!
//! ## The math
//!
//! For entry `i` and traced query row `l`, the similarity term is
//! `dot(q_l, s_il) / ‖q_l‖` where `s_il` is the entry's precomputed unit
//! row. The numerator `raw[i][l] = Σ_e q_l[e]·s_il[e]` is a sum of
//! products over the entry's stored experts, so adding `c` tokens to cell
//! `(l, e)` adds `c·s_il[e]` — a walk over one posting list. The
//! denominator changes for **every** entry touched in row `l` when the row
//! norm moves, so `record` retracts the row's old `raw/‖q‖` contributions,
//! applies the posting-list deltas, and re-adds at the new norm — touching
//! only entries with nonzero overlap in that row. Row norms are kept as
//! exact integer sums of squares (`Σ count²` in u64), so the incremental
//! norm is bit-identical to a from-scratch computation.
//!
//! Decisions match [`Eamc::nearest`] up to f32-vs-f64 summation order;
//! differential proptests in `tests/properties.rs` pin the agreement.

use crate::trace::Eamc;

/// Inverted index over an EAMC build: posting lists from `(layer, expert)`
/// cells to the entries whose (truncated, row-normalized) rows contain
/// them. Owned by [`Eamc`], shared read-only by all matcher handles.
#[derive(Debug, Clone)]
pub struct MatcherIndex {
    layers: usize,
    experts: usize,
    entries: usize,
    /// Identifies the EAMC (re)construction this index describes; matcher
    /// handles re-sync when it moves.
    build_id: u64,
    /// CSR offsets, length `layers * experts + 1`.
    off: Vec<u32>,
    /// Flat `(entry_id, unit_weight)` arena.
    post: Vec<(u32, f32)>,
}

impl MatcherIndex {
    /// Index of an empty collection (no entries; all posting lists empty).
    pub fn empty(layers: usize, experts: usize) -> MatcherIndex {
        MatcherIndex {
            layers,
            experts,
            entries: 0,
            build_id: 0,
            off: vec![0; layers * experts + 1],
            post: Vec::new(),
        }
    }

    /// Build from per-cell posting lists (`cells[layer * experts + expert]`).
    pub(crate) fn from_cells(
        layers: usize,
        experts: usize,
        entries: usize,
        build_id: u64,
        cells: &[Vec<(u32, f32)>],
    ) -> MatcherIndex {
        debug_assert_eq!(cells.len(), layers * experts);
        let total: usize = cells.iter().map(|c| c.len()).sum();
        let mut off = Vec::with_capacity(layers * experts + 1);
        let mut post = Vec::with_capacity(total);
        off.push(0u32);
        for cell in cells {
            post.extend_from_slice(cell);
            off.push(post.len() as u32);
        }
        MatcherIndex {
            layers,
            experts,
            entries,
            build_id,
            off,
            post,
        }
    }

    #[inline]
    pub fn layers(&self) -> usize {
        self.layers
    }

    #[inline]
    pub fn experts(&self) -> usize {
        self.experts
    }

    #[inline]
    pub fn entries(&self) -> usize {
        self.entries
    }

    #[inline]
    pub fn build_id(&self) -> u64 {
        self.build_id
    }

    /// Posting list of one `(layer, expert)` cell.
    #[inline]
    pub fn posting(&self, layer: usize, expert: usize) -> &[(u32, f32)] {
        let c = layer * self.experts + expert;
        &self.post[self.off[c] as usize..self.off[c + 1] as usize]
    }

    /// Bytes held by the index (overhead accounting, §8.5).
    pub fn bytes(&self) -> usize {
        self.off.len() * std::mem::size_of::<u32>()
            + self.post.len() * std::mem::size_of::<(u32, f32)>()
    }
}

/// Per-sequence incremental matcher handle. One lives per active sequence
/// slot in the engine and is recycled across batches ([`EamcMatcher::attach`]
/// re-syncs to the current EAMC build and clears the query state without
/// reallocating when geometry is unchanged).
#[derive(Debug, Default)]
pub struct EamcMatcher {
    layers: usize,
    experts: usize,
    /// Number of EAMC entries the accumulators cover.
    n: usize,
    build_id: u64,
    attached: bool,
    /// Query counts, `layers * experts` (mirror of the sequence's cur_eam).
    q_counts: Vec<u32>,
    /// Exact per-row `Σ count²` (u64 ⇒ no incremental drift).
    q_norm2: Vec<u64>,
    /// Rows with nonzero counts so far.
    traced_rows: usize,
    /// Un-normalized per-row dot products, `raw[layer * n + entry]`.
    raw: Vec<f64>,
    /// Normalized similarity per entry: `Σ_rows raw / ‖q_row‖`.
    sim: Vec<f64>,
    /// Per-row arena of entry ids with nonzero `raw` (capacity `n` each).
    touched: Vec<u32>,
    touched_len: Vec<u32>,
}

impl EamcMatcher {
    /// Detached handle; call [`EamcMatcher::attach`] before use.
    pub fn new() -> EamcMatcher {
        EamcMatcher::default()
    }

    /// Sync to `eamc`'s current build and start a fresh (empty) query.
    /// Reuses all buffers when the geometry is unchanged.
    pub fn attach(&mut self, eamc: &Eamc) {
        self.attach_index(eamc.index());
    }

    /// [`EamcMatcher::attach`] against a standalone index.
    pub fn attach_index(&mut self, index: &MatcherIndex) {
        let (l, e, n) = (index.layers(), index.experts(), index.entries());
        if self.layers != l || self.experts != e || self.n != n {
            self.layers = l;
            self.experts = e;
            self.n = n;
            self.q_counts = vec![0; l * e];
            self.q_norm2 = vec![0; l];
            self.raw = vec![0.0; l * n];
            self.sim = vec![0.0; n];
            self.touched = vec![0; l * n];
            self.touched_len = vec![0; l];
            self.traced_rows = 0;
        } else {
            self.reset();
        }
        self.build_id = index.build_id();
        self.attached = true;
    }

    /// Whether the handle is synced to `index`'s build.
    pub fn is_synced(&self, index: &MatcherIndex) -> bool {
        self.attached
            && self.build_id == index.build_id()
            && self.n == index.entries()
            && self.layers == index.layers()
            && self.experts == index.experts()
    }

    /// Clear the query state (sequence boundary) without touching the
    /// attachment. O(touched entries), allocation-free.
    pub fn reset(&mut self) {
        for li in 0..self.layers {
            let base = li * self.n;
            let tl = self.touched_len[li] as usize;
            for j in 0..tl {
                let i = self.touched[base + j] as usize;
                self.raw[base + i] = 0.0;
            }
            self.touched_len[li] = 0;
            self.q_norm2[li] = 0;
        }
        self.q_counts.fill(0);
        self.sim.fill(0.0);
        self.traced_rows = 0;
    }

    /// Fold one routing event (Alg. 1 steps 6-7) into the accumulators.
    /// Cost: O(|posting list| + |entries overlapping row `layer`|); no
    /// allocation, no full scans.
    pub fn record(&mut self, index: &MatcherIndex, layer: usize, expert: usize, tokens: u32) {
        debug_assert!(
            self.is_synced(index),
            "matcher not attached to this EAMC build"
        );
        debug_assert!(layer < self.layers && expert < self.experts);
        if tokens == 0 {
            return;
        }
        let n = self.n;
        let cell = layer * self.experts + expert;
        let old_c = self.q_counts[cell] as u64;
        let c = tokens as u64;
        let old_n2 = self.q_norm2[layer];
        let new_n2 = old_n2 + 2 * c * old_c + c * c;
        let base = layer * n;
        let mut tl = self.touched_len[layer] as usize;
        // retract this row's contributions at the old norm
        if old_n2 == 0 {
            self.traced_rows += 1;
        } else {
            let inv = 1.0 / (old_n2 as f64).sqrt();
            for j in 0..tl {
                let i = self.touched[base + j] as usize;
                self.sim[i] -= self.raw[base + i] * inv;
            }
        }
        // fold the delta into the overlapped entries' raw dot products
        for &(i, w) in index.posting(layer, expert) {
            let r = &mut self.raw[base + i as usize];
            if *r == 0.0 {
                self.touched[base + tl] = i;
                tl += 1;
            }
            *r += tokens as f64 * w as f64;
        }
        self.touched_len[layer] = tl as u32;
        // re-apply at the new norm
        let inv = 1.0 / (new_n2 as f64).sqrt();
        for j in 0..tl {
            let i = self.touched[base + j] as usize;
            self.sim[i] += self.raw[base + i] * inv;
        }
        self.q_counts[cell] += tokens;
        self.q_norm2[layer] = new_n2;
    }

    /// Argmax over the maintained similarities: `(entry index, partial
    /// distance)`, mirroring [`Eamc::nearest`]'s conventions (`None` for an
    /// empty collection; entry 0 at distance 0 when nothing is traced yet).
    pub fn nearest(&self) -> Option<(usize, f64)> {
        if self.n == 0 {
            return None;
        }
        if self.traced_rows == 0 {
            return Some((0, 0.0));
        }
        let mut best = 0usize;
        let mut best_sim = f64::NEG_INFINITY;
        for (i, &s) in self.sim.iter().enumerate() {
            if s > best_sim {
                best_sim = s;
                best = i;
            }
        }
        Some((best, 1.0 - best_sim / self.traced_rows as f64))
    }

    /// Number of rows the query has traced so far.
    pub fn traced_rows(&self) -> usize {
        self.traced_rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Eam;

    fn one_hot(layers: usize, experts: usize, hot: usize, tokens: u32) -> Eam {
        let mut m = Eam::new(layers, experts);
        for l in 0..layers {
            m.record(l, hot, tokens);
        }
        m
    }

    fn eamc3() -> Eamc {
        let ds: Vec<Eam> = [0usize, 3, 7]
            .iter()
            .flat_map(|&h| (0..3).map(move |_| one_hot(4, 8, h, 5)))
            .collect();
        Eamc::construct(3, &ds, 1)
    }

    #[test]
    fn empty_and_untraced_conventions_match_nearest() {
        let empty = Eamc::new(4, 2, 2);
        let mut m = EamcMatcher::new();
        m.attach(&empty);
        assert!(m.nearest().is_none());

        let c = eamc3();
        m.attach(&c);
        let (i, d) = m.nearest().unwrap();
        assert_eq!(i, 0);
        assert_eq!(d, 0.0);
    }

    #[test]
    fn incremental_tracks_full_scan_decision() {
        let c = eamc3();
        let mut m = EamcMatcher::new();
        m.attach(&c);
        let mut cur = Eam::new(4, 8);
        for (l, e, t) in [(0, 3, 2u32), (1, 3, 1), (1, 4, 1), (2, 3, 5)] {
            m.record(c.index(), l, e, t);
            cur.record(l, e, t);
            let (fi, fd) = m.nearest().unwrap();
            let (si, sd) = c.nearest_entry(&cur).unwrap();
            assert_eq!(fi, si, "decision diverged after record ({l},{e},{t})");
            assert!((fd - sd).abs() < 1e-5, "distance {fd} vs {sd}");
        }
    }

    #[test]
    fn reset_restores_fresh_query() {
        let c = eamc3();
        let mut m = EamcMatcher::new();
        m.attach(&c);
        m.record(c.index(), 0, 7, 9);
        assert_eq!(m.nearest().unwrap().0, c.nearest_entry(&one_hot(4, 8, 7, 9)).unwrap().0);
        m.reset();
        assert_eq!(m.traced_rows(), 0);
        let (i, d) = m.nearest().unwrap();
        assert_eq!((i, d), (0, 0.0));
        // and the accumulators really are clean: a different pattern wins
        m.record(c.index(), 0, 0, 4);
        let mut cur = Eam::new(4, 8);
        cur.record(0, 0, 4);
        assert_eq!(m.nearest().unwrap().0, c.nearest_entry(&cur).unwrap().0);
    }

    #[test]
    fn attach_resyncs_after_rebuild() {
        let ds = vec![one_hot(4, 8, 0, 5); 4];
        let mut c = Eamc::construct(2, &ds, 2);
        c.set_rebuild_threshold(3);
        let mut m = EamcMatcher::new();
        m.attach(&c);
        assert!(m.is_synced(c.index()));
        for _ in 0..3 {
            c.observe(&one_hot(4, 8, 6, 5), false);
        }
        assert!(!m.is_synced(c.index()), "rebuild must invalidate handles");
        m.attach(&c);
        assert!(m.is_synced(c.index()));
        m.record(c.index(), 0, 6, 2);
        let mut cur = Eam::new(4, 8);
        cur.record(0, 6, 2);
        assert_eq!(m.nearest().unwrap().0, c.nearest_entry(&cur).unwrap().0);
    }

    #[test]
    fn index_bytes_and_postings_cover_entries() {
        let c = eamc3();
        let idx = c.index();
        assert_eq!(idx.entries(), 3);
        assert!(idx.bytes() > 0);
        // every entry appears in at least one posting list
        let mut seen = vec![false; idx.entries()];
        for l in 0..idx.layers() {
            for e in 0..idx.experts() {
                for &(i, w) in idx.posting(l, e) {
                    assert!(w > 0.0);
                    seen[i as usize] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
