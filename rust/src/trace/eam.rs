//! Expert Activation Matrix (paper §4.2).

use std::sync::atomic::{AtomicU64, Ordering};

/// Source of process-unique EAM identities (see [`Eam::id`]).
static EAM_IDS: AtomicU64 = AtomicU64::new(1);

fn next_eam_id() -> u64 {
    EAM_IDS.fetch_add(1, Ordering::Relaxed)
}

/// An `L x E` matrix where cell `[l][e]` counts the tokens routed to expert
/// `e` at MoE layer `l` while processing **one** sequence (prompt + all
/// generated tokens). Maintaining counts *per sequence* — not aggregated —
/// is the paper's key tracing insight: aggregation across sequences washes
/// out sparse activation and temporal locality (§4.1).
#[derive(Debug)]
pub struct Eam {
    layers: usize,
    experts: usize,
    counts: Vec<u32>,
    /// Per-row token totals, kept incrementally so distance and ratio
    /// computations are O(E) per row with no re-summation.
    row_sums: Vec<u32>,
    /// Process-unique identity; a fresh id is assigned on construction and
    /// on clone, so `(id, row_version)` pairs never collide across objects.
    id: u64,
    /// Monotonic per-row mutation counters. Consumers that cache values
    /// derived from a row (e.g. the indexed eviction policy's priorities)
    /// invalidate exactly the rows whose version moved.
    row_versions: Vec<u64>,
}

impl Clone for Eam {
    fn clone(&self) -> Eam {
        Eam {
            layers: self.layers,
            experts: self.experts,
            counts: self.counts.clone(),
            row_sums: self.row_sums.clone(),
            // a clone is a distinct object that mutates independently, so it
            // must not share the original's (id, version) identity
            id: next_eam_id(),
            row_versions: self.row_versions.clone(),
        }
    }
}

/// Logical equality: same geometry and counts (identity fields excluded).
impl PartialEq for Eam {
    fn eq(&self, other: &Eam) -> bool {
        self.layers == other.layers
            && self.experts == other.experts
            && self.counts == other.counts
    }
}

impl Eam {
    /// All-zero EAM (Alg. 1 step 2: `NEWEAM(n_layers, n_experts, 0)`).
    pub fn new(layers: usize, experts: usize) -> Eam {
        Eam {
            layers,
            experts,
            counts: vec![0; layers * experts],
            row_sums: vec![0; layers],
            id: next_eam_id(),
            row_versions: vec![0; layers],
        }
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn experts(&self) -> usize {
        self.experts
    }

    /// Record `tokens` routed to `expert` at `layer` (Alg. 1 steps 6-7).
    pub fn record(&mut self, layer: usize, expert: usize, tokens: u32) {
        debug_assert!(layer < self.layers && expert < self.experts);
        self.counts[layer * self.experts + expert] += tokens;
        self.row_sums[layer] += tokens;
        self.row_versions[layer] += 1;
    }

    /// Process-unique identity of this matrix object (changes on clone).
    #[inline]
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Monotonic mutation counter for one row; unchanged version on the
    /// same [`Eam::id`] guarantees the row's counts are unchanged.
    #[inline]
    pub fn row_version(&self, layer: usize) -> u64 {
        self.row_versions[layer]
    }

    #[inline]
    pub fn count(&self, layer: usize, expert: usize) -> u32 {
        self.counts[layer * self.experts + expert]
    }

    #[inline]
    pub fn row(&self, layer: usize) -> &[u32] {
        &self.counts[layer * self.experts..(layer + 1) * self.experts]
    }

    #[inline]
    pub fn row_sum(&self, layer: usize) -> u32 {
        self.row_sums[layer]
    }

    /// Activation ratio of one expert within its layer: `M[l][e] / sum(M[l])`
    /// — the prior used by both prefetch (Alg. 1 step 25) and cache (Alg. 2
    /// step 7) priorities. Returns 0 for an untraced layer.
    #[inline]
    pub fn ratio(&self, layer: usize, expert: usize) -> f32 {
        let s = self.row_sums[layer];
        if s == 0 {
            0.0
        } else {
            self.count(layer, expert) as f32 / s as f32
        }
    }

    /// Reset all counts to zero (reused buffers in the serving hot path).
    pub fn clear(&mut self) {
        self.counts.fill(0);
        self.row_sums.fill(0);
        for v in self.row_versions.iter_mut() {
            *v += 1;
        }
    }

    /// Copy `other`'s counts into this matrix, reusing the existing buffers
    /// when geometries match (the EAMC recent-window ring recycles slots
    /// this way to keep `observe` allocation-free at capacity).
    pub fn copy_from(&mut self, other: &Eam) {
        if self.layers == other.layers && self.experts == other.experts {
            self.counts.copy_from_slice(&other.counts);
            self.row_sums.copy_from_slice(&other.row_sums);
            for v in self.row_versions.iter_mut() {
                *v += 1;
            }
        } else {
            self.layers = other.layers;
            self.experts = other.experts;
            self.counts = other.counts.clone();
            self.row_sums = other.row_sums.clone();
            self.row_versions = vec![0; other.layers];
            // versions restarted at 0: a fresh id keeps the documented
            // "(id, row_version) pins the row contents" invariant
            self.id = next_eam_id();
        }
    }

    /// Total tokens recorded across one layer-row — equal for all traced
    /// layers of a complete trace (the §4.2 invariant `sum_j M[i][j] = n`).
    pub fn tokens(&self) -> u32 {
        self.row_sums.iter().copied().max().unwrap_or(0)
    }

    /// Fraction of experts with nonzero activation (the paper's "sparse
    /// activation" measurement: 3-20% for small batches).
    pub fn activation_fraction(&self) -> f64 {
        let active = self.counts.iter().filter(|&&c| c > 0).count();
        active as f64 / (self.layers * self.experts) as f64
    }

    /// Fraction of *activated* experts used more than once ("temporal
    /// locality": 30-56% in the paper's study).
    pub fn reuse_fraction(&self) -> f64 {
        let active = self.counts.iter().filter(|&&c| c > 0).count();
        if active == 0 {
            return 0.0;
        }
        let reused = self.counts.iter().filter(|&&c| c > 1).count();
        reused as f64 / active as f64
    }

    /// Paper Eq. 1: `1 - (1/L) * sum_l cos(M1[l]/sum, M2[l]/sum)`.
    ///
    /// Row conventions for degenerate rows: two empty rows are identical
    /// (cos = 1); one empty row is maximally dissimilar (cos = 0). The
    /// normalization makes the distance independent of sequence length,
    /// and the per-row cosine captures positional (per-expert) differences
    /// — the two requirements stated in §4.2.
    pub fn distance(&self, other: &Eam) -> f64 {
        debug_assert_eq!(self.layers, other.layers);
        debug_assert_eq!(self.experts, other.experts);
        let mut sim_sum = 0.0f64;
        for l in 0..self.layers {
            sim_sum += row_cosine(self.row(l), other.row(l));
        }
        1.0 - sim_sum / self.layers as f64
    }

    /// Distance restricted to the rows this (partial) EAM has traced so far.
    ///
    /// Used during generation (Alg. 1 `EAMDISTANCE`): the current EAM only
    /// has counts up to the executing layer of the first iterations, and
    /// untraced layers must not dilute the match against complete prior
    /// EAMs. Falls back to 0 distance against everything when nothing is
    /// traced yet (the EAMC's first entry then wins arbitrarily).
    pub fn distance_partial(&self, prior: &Eam) -> f64 {
        let mut sim_sum = 0.0f64;
        let mut rows = 0usize;
        for l in 0..self.layers {
            if self.row_sums[l] > 0 {
                sim_sum += row_cosine(self.row(l), prior.row(l));
                rows += 1;
            }
        }
        if rows == 0 {
            0.0
        } else {
            1.0 - sim_sum / rows as f64
        }
    }

    /// Remove `other`'s counts from this matrix — the continuous-batching
    /// retire path subtracts a finished sequence's EAM from the combined
    /// batch EAM so cache decisions reflect only the *currently active*
    /// sequences. Precondition: `other` is cell-wise ≤ `self` (it was
    /// previously accumulated in). Rows that actually change bump their
    /// version so derived caches (the indexed eviction policy) invalidate.
    pub fn subtract(&mut self, other: &Eam) {
        debug_assert_eq!(self.layers, other.layers);
        debug_assert_eq!(self.experts, other.experts);
        for l in 0..self.layers {
            if other.row_sums[l] == 0 {
                continue;
            }
            let base = l * self.experts;
            for e in 0..self.experts {
                let c = other.counts[base + e];
                debug_assert!(
                    self.counts[base + e] >= c,
                    "subtract underflow at ({l},{e}): {} < {c}",
                    self.counts[base + e]
                );
                self.counts[base + e] -= c;
            }
            self.row_sums[l] -= other.row_sums[l];
            self.row_versions[l] += 1;
        }
    }

    /// Add `other`'s counts into this matrix — the inverse of
    /// [`Eam::subtract`]. Used when a preempted sequence resumes: its saved
    /// per-sequence EAM re-enters the combined batch EAM so cache decisions
    /// again see its working set. Rows that change bump their version.
    pub fn add(&mut self, other: &Eam) {
        debug_assert_eq!(self.layers, other.layers);
        debug_assert_eq!(self.experts, other.experts);
        for l in 0..self.layers {
            if other.row_sums[l] == 0 {
                continue;
            }
            let base = l * self.experts;
            for e in 0..self.experts {
                self.counts[base + e] += other.counts[base + e];
            }
            self.row_sums[l] += other.row_sums[l];
            self.row_versions[l] += 1;
        }
    }

    /// Memory footprint of the counts (for the §8.5 overhead accounting).
    pub fn bytes(&self) -> usize {
        self.counts.len() * std::mem::size_of::<u32>()
    }
}

/// Cosine similarity between two count rows. Normalization by the row sum
/// (as in Eq. 1) cancels inside cosine, so we compute it on raw counts.
#[inline]
fn row_cosine(a: &[u32], b: &[u32]) -> f64 {
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for i in 0..a.len() {
        let (x, y) = (a[i] as f64, b[i] as f64);
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    match (na > 0.0, nb > 0.0) {
        (true, true) => dot / (na.sqrt() * nb.sqrt()),
        (false, false) => 1.0,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eam_from(rows: &[&[u32]]) -> Eam {
        let mut m = Eam::new(rows.len(), rows[0].len());
        for (l, row) in rows.iter().enumerate() {
            for (e, &c) in row.iter().enumerate() {
                m.record(l, e, c);
            }
        }
        m
    }

    #[test]
    fn add_inverts_subtract() {
        let base = eam_from(&[&[3, 0, 2, 0], &[1, 1, 1, 1]]);
        let part = eam_from(&[&[1, 0, 2, 0], &[0, 1, 0, 1]]);
        let mut m = base.clone();
        let v0 = m.row_version(0);
        m.subtract(&part);
        m.add(&part);
        assert_eq!(m, base);
        assert!(m.row_version(0) > v0, "changed rows must bump versions");
        assert_eq!(m.row_sum(0), base.row_sum(0));
    }

    #[test]
    fn record_and_ratio() {
        let mut m = Eam::new(2, 4);
        m.record(0, 1, 3);
        m.record(0, 2, 1);
        assert_eq!(m.count(0, 1), 3);
        assert_eq!(m.row_sum(0), 4);
        assert!((m.ratio(0, 1) - 0.75).abs() < 1e-6);
        assert_eq!(m.ratio(1, 0), 0.0); // untraced layer
    }

    #[test]
    fn distance_identical_is_zero() {
        let m = eam_from(&[&[1, 2, 0], &[0, 3, 1]]);
        assert!(m.distance(&m) < 1e-9);
    }

    #[test]
    fn distance_scale_invariant() {
        // Eq. 1 requirement (ii): independent of token count.
        let a = eam_from(&[&[1, 2, 0], &[0, 3, 1]]);
        let b = eam_from(&[&[10, 20, 0], &[0, 30, 10]]);
        assert!(a.distance(&b) < 1e-9);
    }

    #[test]
    fn distance_disjoint_is_one() {
        let a = eam_from(&[&[1, 0], &[1, 0]]);
        let b = eam_from(&[&[0, 1], &[0, 1]]);
        assert!((a.distance(&b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn distance_symmetric() {
        let a = eam_from(&[&[1, 2, 3], &[4, 0, 1]]);
        let b = eam_from(&[&[2, 2, 0], &[1, 1, 1]]);
        assert!((a.distance(&b) - b.distance(&a)).abs() < 1e-12);
    }

    #[test]
    fn empty_rows_conventions() {
        let a = eam_from(&[&[1, 0], &[0, 0]]);
        let b = eam_from(&[&[1, 0], &[0, 0]]);
        assert!(a.distance(&b) < 1e-9); // both empty second rows: identical
        let c = eam_from(&[&[1, 0], &[0, 1]]);
        // second rows: one empty vs nonempty -> sim 0 for that layer
        assert!((a.distance(&c) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn partial_distance_ignores_untraced_layers() {
        let mut cur = Eam::new(3, 2);
        cur.record(0, 0, 5); // only layer 0 traced
        let prior_match = eam_from(&[&[3, 0], &[0, 9], &[9, 0]]);
        let prior_miss = eam_from(&[&[0, 3], &[0, 9], &[9, 0]]);
        assert!(cur.distance_partial(&prior_match) < 1e-9);
        assert!((cur.distance_partial(&prior_miss) - 1.0).abs() < 1e-9);
        // full distance would be diluted by untraced layers:
        assert!(cur.distance(&prior_match) > 0.1);
    }

    #[test]
    fn partial_distance_empty_cur_is_zero() {
        let cur = Eam::new(2, 2);
        let prior = eam_from(&[&[1, 0], &[0, 1]]);
        assert_eq!(cur.distance_partial(&prior), 0.0);
    }

    #[test]
    fn sparsity_and_reuse_metrics() {
        let m = eam_from(&[&[4, 1, 0, 0], &[0, 2, 0, 0]]);
        // active: 3 of 8 cells
        assert!((m.activation_fraction() - 3.0 / 8.0).abs() < 1e-9);
        // reused (count>1): 2 of 3 active
        assert!((m.reuse_fraction() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn clear_resets() {
        let mut m = eam_from(&[&[1, 2], &[3, 4]]);
        m.clear();
        assert_eq!(m.row_sum(0), 0);
        assert_eq!(m.tokens(), 0);
        assert_eq!(m.activation_fraction(), 0.0);
    }

    #[test]
    fn bytes_accounting() {
        let m = Eam::new(24, 128);
        assert_eq!(m.bytes(), 24 * 128 * 4);
    }

    #[test]
    fn row_versions_track_mutations_per_row() {
        let mut m = Eam::new(3, 4);
        let v0 = m.row_version(0);
        let v1 = m.row_version(1);
        m.record(0, 2, 5);
        assert!(m.row_version(0) > v0, "mutated row bumps");
        assert_eq!(m.row_version(1), v1, "untouched row stays");
        let before = m.row_version(1);
        m.clear();
        assert!(m.row_version(1) > before, "clear bumps every row");
    }

    #[test]
    fn identity_is_unique_across_clones() {
        let a = eam_from(&[&[1, 2], &[3, 4]]);
        let b = a.clone();
        assert_ne!(a.id(), b.id());
        assert_eq!(a, b, "logical equality ignores identity");
    }

    #[test]
    fn subtract_reverses_accumulation_and_bumps_changed_rows() {
        let a = eam_from(&[&[1, 2], &[0, 7]]);
        let b = eam_from(&[&[0, 4], &[1, 1]]);
        let mut sum = Eam::new(2, 2);
        // accumulate both, then retire `a`
        for m in [&a, &b] {
            for l in 0..2 {
                for e in 0..2 {
                    let c = m.count(l, e);
                    if c > 0 {
                        sum.record(l, e, c);
                    }
                }
            }
        }
        sum.subtract(&a);
        assert_eq!(sum, b);
        assert_eq!(sum.row_sum(1), 2);
        // a row the subtrahend never touched keeps its version
        let mut big = eam_from(&[&[3, 0], &[5, 5]]);
        let mut sub = Eam::new(2, 2);
        sub.record(1, 0, 2);
        let v0 = big.row_version(0);
        let v1 = big.row_version(1);
        big.subtract(&sub);
        assert_eq!(big.row_version(0), v0, "untouched row stays");
        assert!(big.row_version(1) > v1, "changed row bumps");
        assert_eq!(big.count(1, 0), 3);
    }

    #[test]
    fn copy_from_matches_and_bumps_versions() {
        let src = eam_from(&[&[1, 2], &[0, 7]]);
        let mut dst = Eam::new(2, 2);
        let v = dst.row_version(0);
        dst.copy_from(&src);
        assert_eq!(dst, src);
        assert_eq!(dst.row_sum(1), 7);
        assert!(dst.row_version(0) > v);
        // geometry mismatch falls back to reallocation
        let mut other = Eam::new(1, 3);
        other.copy_from(&src);
        assert_eq!(other, src);
    }
}
