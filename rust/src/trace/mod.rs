//! Sequence-level expert activation tracing (paper §4).
//!
//! * [`Eam`] — Expert Activation Matrix: an `L x E` count matrix recording
//!   how many tokens each expert processed for **one** sequence.
//! * [`Eamc`] — Expert Activation Matrix Collection: a fixed-capacity set of
//!   representative EAMs built by k-means clustering under the paper's
//!   per-layer normalized-cosine distance (Eq. 1), with online
//!   reconstruction to handle distribution shift (§4.3).
//! * [`EamcMatcher`] — per-sequence incremental matcher over an inverted
//!   [`MatcherIndex`], turning the serving-path `nearest()` lookup into a
//!   delta update + allocation-free argmax (EXPERIMENTS.md §Perf).

mod eam;
mod eamc;
mod kmeans;
mod matcher;

pub use eam::Eam;
pub use eamc::{Eamc, EamcStats};
pub use kmeans::{kmeans_medoids, kmeans_medoids_with, KMeansResult};
pub use matcher::{EamcMatcher, MatcherIndex};
