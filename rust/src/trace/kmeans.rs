//! K-means clustering of EAMs under the paper's Eq. 1 distance (§4.2).
//!
//! Centroids live in the space of row-normalized `L x E` f32 matrices;
//! assignments use Eq. 1 (average per-layer cosine distance); after
//! convergence each cluster is represented by its **medoid** — the member
//! EAM closest to the centroid — because the EAMC must store real observed
//! activation patterns, not synthetic averages.

use crate::trace::Eam;
use crate::util::Rng;

/// A centroid: per-layer normalized activation rows (f32, length L*E).
struct Centroid {
    layers: usize,
    experts: usize,
    rows: Vec<f32>,
}

impl Centroid {
    fn from_eam(eam: &Eam) -> Centroid {
        let (l, e) = (eam.layers(), eam.experts());
        let mut rows = vec![0.0f32; l * e];
        for li in 0..l {
            let s = eam.row_sum(li);
            if s > 0 {
                for ei in 0..e {
                    rows[li * e + ei] = eam.count(li, ei) as f32 / s as f32;
                }
            }
        }
        Centroid {
            layers: l,
            experts: e,
            rows,
        }
    }

    /// Eq. 1 distance from a centroid to an EAM.
    fn distance(&self, eam: &Eam) -> f64 {
        let e = self.experts;
        let mut sim = 0.0f64;
        for l in 0..self.layers {
            let crow = &self.rows[l * e..(l + 1) * e];
            let erow = eam.row(l);
            let mut dot = 0.0f64;
            let mut nc = 0.0f64;
            let mut ne = 0.0f64;
            for i in 0..e {
                let (x, y) = (crow[i] as f64, erow[i] as f64);
                dot += x * y;
                nc += x * x;
                ne += y * y;
            }
            sim += match (nc > 0.0, ne > 0.0) {
                (true, true) => dot / (nc.sqrt() * ne.sqrt()),
                (false, false) => 1.0,
                _ => 0.0,
            };
        }
        1.0 - sim / self.layers as f64
    }

    /// Mean of the members' normalized rows.
    fn from_members(members: &[&Eam]) -> Centroid {
        let (l, e) = (members[0].layers(), members[0].experts());
        let mut rows = vec![0.0f32; l * e];
        for m in members {
            for li in 0..l {
                let s = m.row_sum(li);
                if s > 0 {
                    for ei in 0..e {
                        rows[li * e + ei] += m.count(li, ei) as f32 / s as f32;
                    }
                }
            }
        }
        let n = members.len() as f32;
        for v in rows.iter_mut() {
            *v /= n;
        }
        Centroid {
            layers: l,
            experts: e,
            rows,
        }
    }
}

/// Result of clustering: medoid indices into the input slice, plus the final
/// cluster assignment of every input.
pub struct KMeansResult {
    pub medoids: Vec<usize>,
    pub assignment: Vec<usize>,
    pub iterations: usize,
}

/// Cluster `eams` into `k` groups, returning medoid indices (§4.2 "the EAM
/// that is closest to the centroid is stored in the EAMC").
///
/// k-means++ seeding, at most `max_iters` Lloyd iterations, deterministic
/// given `seed`. If `k >= eams.len()`, every input is its own medoid.
pub fn kmeans_medoids(eams: &[Eam], k: usize, max_iters: usize, seed: u64) -> KMeansResult {
    assert!(!eams.is_empty(), "kmeans over empty input");
    let k = k.min(eams.len());
    if k == eams.len() {
        return KMeansResult {
            medoids: (0..eams.len()).collect(),
            assignment: (0..eams.len()).collect(),
            iterations: 0,
        };
    }
    let mut rng = Rng::new(seed);

    // k-means++ init.
    let mut centroids: Vec<Centroid> = Vec::with_capacity(k);
    let first = rng.below(eams.len());
    centroids.push(Centroid::from_eam(&eams[first]));
    let mut d2: Vec<f64> = eams.iter().map(|m| centroids[0].distance(m).powi(2)).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let idx = if total <= 1e-18 {
            rng.below(eams.len())
        } else {
            let mut u = rng.f64() * total;
            let mut pick = eams.len() - 1;
            for (i, &w) in d2.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        let c = Centroid::from_eam(&eams[idx]);
        for (i, m) in eams.iter().enumerate() {
            d2[i] = d2[i].min(c.distance(m).powi(2));
        }
        centroids.push(c);
    }

    // Lloyd iterations.
    let mut assignment = vec![0usize; eams.len()];
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        let mut changed = false;
        for (i, m) in eams.iter().enumerate() {
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for (c, cen) in centroids.iter().enumerate() {
                let d = cen.distance(m);
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        if !changed && it > 0 {
            break;
        }
        for c in 0..k {
            let members: Vec<&Eam> = eams
                .iter()
                .enumerate()
                .filter(|(i, _)| assignment[*i] == c)
                .map(|(_, m)| m)
                .collect();
            if !members.is_empty() {
                centroids[c] = Centroid::from_members(&members);
            } else {
                // Re-seed an empty cluster on the farthest point.
                let far = (0..eams.len())
                    .max_by(|&a, &b| {
                        let da = centroids[assignment[a]].distance(&eams[a]);
                        let db = centroids[assignment[b]].distance(&eams[b]);
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids[c] = Centroid::from_eam(&eams[far]);
            }
        }
    }

    // Medoid extraction.
    let mut medoids = Vec::with_capacity(k);
    for c in 0..k {
        let mut best = None;
        let mut bd = f64::INFINITY;
        for (i, m) in eams.iter().enumerate() {
            if assignment[i] == c {
                let d = centroids[c].distance(m);
                if d < bd {
                    bd = d;
                    best = Some(i);
                }
            }
        }
        if let Some(i) = best {
            medoids.push(i);
        }
    }
    medoids.sort();
    medoids.dedup();

    KMeansResult {
        medoids,
        assignment,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an EAM activating expert `hot` on every layer.
    fn one_hot(layers: usize, experts: usize, hot: usize, tokens: u32) -> Eam {
        let mut m = Eam::new(layers, experts);
        for l in 0..layers {
            m.record(l, hot, tokens);
        }
        m
    }

    #[test]
    fn separates_obvious_clusters() {
        let mut eams = Vec::new();
        for i in 0..10 {
            eams.push(one_hot(4, 8, 0, 5 + i));
        }
        for i in 0..10 {
            eams.push(one_hot(4, 8, 7, 3 + i));
        }
        let r = kmeans_medoids(&eams, 2, 50, 1);
        assert_eq!(r.medoids.len(), 2);
        // All of the first 10 share an assignment; all of the last 10 share
        // the other.
        let a0 = r.assignment[0];
        assert!(r.assignment[..10].iter().all(|&a| a == a0));
        let a1 = r.assignment[10];
        assert_ne!(a0, a1);
        assert!(r.assignment[10..].iter().all(|&a| a == a1));
        // Medoids come from different clusters.
        let hot = |i: usize| (0..8).find(|&e| eams[r.medoids[i]].count(0, e) > 0).unwrap();
        let mut hots = vec![hot(0), hot(1)];
        hots.sort();
        assert_eq!(hots, vec![0, 7]);
    }

    #[test]
    fn k_ge_n_is_identity() {
        let eams = vec![one_hot(2, 4, 0, 1), one_hot(2, 4, 1, 1)];
        let r = kmeans_medoids(&eams, 10, 10, 0);
        assert_eq!(r.medoids, vec![0, 1]);
    }

    #[test]
    fn deterministic() {
        let eams: Vec<Eam> = (0..20).map(|i| one_hot(4, 8, i % 4, 2)).collect();
        let a = kmeans_medoids(&eams, 4, 30, 9);
        let b = kmeans_medoids(&eams, 4, 30, 9);
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn medoids_are_valid_indices_and_unique() {
        let eams: Vec<Eam> = (0..30).map(|i| one_hot(4, 16, i % 5, 1 + (i as u32 % 3))).collect();
        let r = kmeans_medoids(&eams, 5, 30, 3);
        for &m in &r.medoids {
            assert!(m < eams.len());
        }
        let mut uniq = r.medoids.clone();
        uniq.dedup();
        assert_eq!(uniq.len(), r.medoids.len());
    }

    #[test]
    fn identical_inputs_dont_crash() {
        let eams: Vec<Eam> = (0..10).map(|_| one_hot(2, 4, 1, 3)).collect();
        let r = kmeans_medoids(&eams, 3, 20, 5);
        assert!(!r.medoids.is_empty());
    }
}
